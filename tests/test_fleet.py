"""Verifier fleet (repro.fleet): prefix-locality routing, owner-gated
verdict delivery, lossless migration, and the chaos guarantee — kill a
verifier mid-stream and every committed stream stays byte-identical to
the single-verifier golden run (DESIGN.md §10)."""
import types

import jax
import pytest

from repro.cluster import ClusterConfig, ClusterRuntime, build_fleet
from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.fleet import FleetRouter, FleetRuntime, build_verifier_fleet
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_pair():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = bundle.init(jax.random.PRNGKey(0))
    dparams = bundle.init(jax.random.PRNGKey(1))
    return cfg, tparams, dparams


def _mini_router(cfg, tparams, n=2, page_size=4, max_slots=4):
    """Tiny fleet with small pages so short prompts fill whole pages
    (prefix-index entries) — routing probes have something to hit."""
    verifiers = {}
    for i in range(n):
        eng = VerificationEngine(cfg, tparams, max_slots=max_slots,
                                 max_len=64, page_size=page_size)
        verifiers[f"v{i}"] = WISPServer(eng, COEFFS, network=NetworkModel())
    return FleetRouter(verifiers)


# -- routing -----------------------------------------------------------------

def test_route_least_loaded_fallback(dense_pair):
    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    a = router.open_session(0, [5, 6, 7, 8], now=0.0)
    b = router.open_session(1, [9, 10, 11, 12], now=0.0)
    assert {a, b} == {"v0", "v1"}        # no coverage: spread by load
    assert router.owner == {0: a, 1: b}


def test_route_prefers_prefix_locality_over_load(dense_pair):
    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    warm = [3, 4, 5, 6, 7, 8, 9, 10]      # two full 4-token pages
    host = router.open_session(0, warm, now=0.0)
    router.close_session(0, now=0.0)      # publishes the prefix pages
    other = "v1" if host == "v0" else "v0"
    # load the warm verifier heavier than the cold one...
    for sid in (101, 102):
        router.owner[sid] = host
        router.verifiers[host].open_session(
            sid, [30 + sid, 31 + sid], queue_on_full=True, now=0.0)
    assert router._load(host) > router._load(other)
    # ...and locality still wins over least-loaded
    assert router.route(warm) == host
    # a cold prompt falls back to the less loaded verifier
    assert router.route([40, 41, 42, 43]) == other


def test_routing_probe_is_read_only(dense_pair):
    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    warm = [3, 4, 5, 6, 7, 8, 9, 10]
    host = router.open_session(0, warm, now=0.0)
    router.close_session(0, now=0.0)
    alloc = router.verifiers[host].engine.kv.allocator
    hits0, refs0 = alloc.hits, alloc.refcount.copy()
    for _ in range(3):
        assert router.route(warm) == host
    assert alloc.hits == hits0            # probe never counted as a hit
    assert (alloc.refcount == refs0).all()  # ...and never retained a page


# -- owner-gated, idempotent verdict delivery --------------------------------

def test_deliver_verdict_owner_and_dedup_gates(dense_pair):
    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    vid = router.open_session(0, [5, 6, 7, 8], now=0.0)
    other = "v1" if vid == "v0" else "v0"
    router.dispatcher.track((0, 0), vid, eta=0.01, now=0.0)
    v = types.SimpleNamespace(session_id=0, round_index=0)
    assert not router.deliver_verdict(other, v)   # not the owner
    assert router.deliver_verdict(vid, v)         # first wins
    # an owner-sent duplicate of a committed round is a REPLAY (lost-ack
    # recovery, DESIGN.md §14): forwarded to the device's round gate,
    # counted separately from the non-owner drop
    assert router.deliver_verdict(vid, v)
    assert router.stats["dropped_verdicts"] == 1
    assert router.stats["replayed_verdicts"] == 1


# -- lossless restore --------------------------------------------------------

def test_restore_session_rebuilds_engine_state(dense_pair):
    cfg, tparams, _ = dense_pair
    eng = VerificationEngine(cfg, tparams, max_slots=2, max_len=64)
    srv = WISPServer(eng, COEFFS, network=NetworkModel())
    committed = [5, 6, 7, 8, 11, 12, 13]
    replayed = srv.restore_session(3, committed, rounds=2)
    s = srv.sessions[3]
    assert replayed == len(committed) - 1
    assert s.committed_len == len(committed)
    assert s.rounds == 2                  # (sid, round) keying resumes here
    # the engine slot invariant the verify hot path depends on:
    # fed = committed_len - 1, last_token = the final committed token
    assert int(eng.fed[s.slot]) == len(committed) - 1
    assert int(eng.last_token[s.slot]) == committed[-1]
    with pytest.raises(ValueError):
        srv.restore_session(3, committed)          # already live
    with pytest.raises(ValueError):
        srv.restore_session(4, [9])                # nothing to replay


def test_migrate_session_moves_ownership(dense_pair):
    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    sid = 0
    src = router.open_session(sid, [5, 6, 7, 8], now=0.0)
    committed = [5, 6, 7, 8, 21, 22, 23]
    dst, replayed = router.migrate_session(sid, committed, rounds=1, now=0.1)
    assert dst != src and router.owner[sid] == dst
    assert replayed == len(committed) - 1
    assert sid in router.verifiers[dst].sessions
    assert sid not in router.verifiers[src].sessions
    migrated = [ev for _, ev in router.pop_events() if ev.kind == "MIGRATED"]
    assert len(migrated) == 1 and migrated[0].src == src \
        and migrated[0].dst == dst


def test_restore_session_preserves_spec_context(dense_pair):
    """A restored session must resume with the dead owner's adaptive-
    speculation context (DESIGN.md §11), not cold-start defaults: alpha
    feeds Algorithm 1's accept-length forecast and spec_k is the edge
    controller's last draft-length cap."""
    cfg, tparams, _ = dense_pair
    eng = VerificationEngine(cfg, tparams, max_slots=2, max_len=64)
    srv = WISPServer(eng, COEFFS, network=NetworkModel())
    srv.restore_session(3, [5, 6, 7, 8, 11], rounds=2, alpha=0.85, spec_k=3)
    s = srv.sessions[3]
    assert s.alpha == pytest.approx(0.85)
    assert s.spec_k == 3
    # defaults stay the legacy cold-start values for old callers
    srv2 = WISPServer(
        VerificationEngine(cfg, tparams, max_slots=2, max_len=64),
        COEFFS, network=NetworkModel())
    srv2.restore_session(3, [5, 6, 7, 8, 11], rounds=2)
    assert srv2.sessions[3].alpha == pytest.approx(0.6)
    assert srv2.sessions[3].spec_k == 0


def test_migration_carries_adaptive_spec_context(dense_pair):
    """The router's soft-state replica of (alpha, spec_k) refreshes on
    every submit while the owner is alive, so migrating a session off a
    dead verifier restores the context as of the LAST submitted round —
    not the 0.6/0 cold-start a fresh session would get."""
    import numpy as np

    cfg, tparams, _ = dense_pair
    router = _mini_router(cfg, tparams)
    sid, now = 0, 0.0
    src = router.open_session(sid, [5, 6, 7, 8], now=now)
    stream = [ev.token for _, ev in router.pop_events()
              if ev.kind == "FIRST_TOKEN"]
    g = np.random.default_rng(0)

    def one_round(k):
        nonlocal now
        toks = g.integers(0, cfg.vocab, size=k).astype(np.int32)
        qlog = (g.normal(size=(k, cfg.vocab)) * 1.5).astype(np.float32)
        router.submit(sid, toks, qlog, now=now, t_draft=0.01,
                      t_network=0.005)
        while router.queue_depth(src):
            for v in router.step(src, now):
                stream.extend(int(t) for t in toks[: v.accept_len])
                stream.append(int(v.token))
            now += 0.005
        router.pop_events()

    one_round(3)
    s_src = router.verifiers[src].sessions[sid]
    alpha_snap = s_src.alpha              # post-round-1 EWMA estimate
    one_round(2)                          # submit refreshes the replica
    committed = [5, 6, 7, 8] + stream
    dst, _ = router.migrate_session(sid, committed, rounds=2, now=now)
    s_dst = router.verifiers[dst].sessions[sid]
    # the replica was snapshotted at the round-2 submit: alpha as of the
    # round-1 verdict, spec_k = the round-2 draft-length cap
    assert s_dst.alpha == pytest.approx(alpha_snap)
    assert s_dst.alpha != pytest.approx(0.6)
    assert s_dst.spec_k == 2


# -- chaos: kill a verifier mid-stream ---------------------------------------

CHAOS_CCFG = dict(devices=4, rounds=3, k_max=4, max_len=256, seed=0,
                  prefill_mode="chunked", prefill_chunk_tokens=16)


def _edges(cfg, dparams, ccfg, fleet):
    return [
        EdgeDevice(cfg, dparams, k_max=ccfg.k_max, max_len=ccfg.max_len,
                   seed=100 + sp.idx, draft_speed=sp.draft_speed)
        for sp in fleet
    ]


def _golden_run(cfg, tparams, dparams):
    """Single-verifier reference: streams are policy-invariant, so one
    golden run serves every chaos variant."""
    ccfg = ClusterConfig(**CHAOS_CCFG)
    engine = VerificationEngine(cfg, tparams, max_slots=ccfg.devices,
                                max_len=ccfg.max_len)
    server = WISPServer(engine, COEFFS, network=NetworkModel(),
                        prefill="chunked",
                        prefill_chunk_tokens=ccfg.prefill_chunk_tokens)
    fleet = build_fleet(ccfg, cfg.vocab)
    edges = _edges(cfg, dparams, ccfg, fleet)
    ClusterRuntime(server, edges, fleet, ccfg, vocab=cfg.vocab).run()
    return [list(d.response_tokens) for d in edges]


def _fleet_run(cfg, tparams, dparams, *, policy, schedule=None, verifiers=2,
               **extra):
    """Chaos variants declare verifier faults through the unified seeded
    `FaultSchedule` DSL (``kill=IDX@T0[+DUR]`` / ``straggle=...``);
    legacy ``fail_at``/``straggle`` tuples ride through ``extra`` to pin
    the deprecation shim."""
    ccfg = ClusterConfig(**CHAOS_CCFG, verifiers=verifiers,
                         fault_schedule=schedule, **extra)
    router = build_verifier_fleet(
        cfg, tparams, ccfg.verifiers, COEFFS, max_slots=ccfg.devices,
        max_len=ccfg.max_len, policy=policy, network=NetworkModel(),
        prefill="chunked", prefill_chunk_tokens=ccfg.prefill_chunk_tokens,
        heartbeat_timeout=ccfg.heartbeat_timeout,
        hedge_factor=ccfg.hedge_factor, hedge_guard=ccfg.hedge_guard,
    )
    fleet = build_fleet(ccfg, cfg.vocab)
    edges = _edges(cfg, dparams, ccfg, fleet)
    result = FleetRuntime(router, edges, fleet, ccfg, vocab=cfg.vocab).run()
    return [list(d.response_tokens) for d in edges], router, result


@pytest.fixture(scope="module")
def golden_streams(dense_pair):
    cfg, tparams, dparams = dense_pair
    return _golden_run(cfg, tparams, dparams)


@pytest.mark.parametrize("policy", ["wisp", "fcfs"])
def test_chaos_kill_one_verifier_streams_unchanged(dense_pair, golden_streams,
                                                   policy):
    """Kill one of three verifiers mid-run (the acceptance scenario):
    every admitted session completes (migrated ones included) and every
    stream — the failure-touched ones too — is byte-identical to the
    single-verifier golden run."""
    cfg, tparams, dparams = dense_pair
    streams, router, result = _fleet_run(
        cfg, tparams, dparams, policy=policy, schedule="kill=0@0.15",
        verifiers=3,
    )
    assert router.stats["verifier_downs"] == 1
    assert router.stats["migrations"] + router.stats["reopens"] >= 1
    assert all(len(s) > 0 for s in streams)          # everyone finished
    assert streams == golden_streams                 # byte-identical
    assert len(result.metrics.sessions) == CHAOS_CCFG["devices"]
    assert router.dispatcher.degraded is False       # survivor still serves


def test_chaos_fleet_without_failures_matches_golden(dense_pair,
                                                     golden_streams):
    cfg, tparams, dparams = dense_pair
    streams, router, _ = _fleet_run(cfg, tparams, dparams, policy="wisp")
    assert router.stats["verifier_downs"] == 0
    assert streams == golden_streams


def test_chaos_straggler_hedged_away(dense_pair, golden_streams):
    """A wedged-but-alive verifier (400x straggle) blows the hedge ETA:
    its sessions migrate and their in-flight rounds re-dispatch; the
    straggler's late verdicts are dropped at the owner gate.  Streams
    stay byte-identical."""
    cfg, tparams, dparams = dense_pair
    streams, router, _ = _fleet_run(
        cfg, tparams, dparams, policy="wisp",
        schedule="straggle=0@0.05+0.95*400", hedge_factor=2.0,
    )
    assert router.dispatcher.stats["hedged"] >= 1
    assert router.stats["redispatches"] >= 1
    assert router.stats["verifier_downs"] == 0   # alive, just slow
    assert streams == golden_streams


def test_chaos_migrate_session_with_spilled_pages(dense_pair):
    """Chaos x tiering (DESIGN.md §12): migrate a session whose KV pages
    sit in the SOURCE verifier's host spill tier.  The destination's
    ``restore_session`` replays the committed stream as a fresh prefill
    (never touching the source's tier) without deadlocking against its
    own tier hooks, the source teardown releases the spilled refs, and
    the stream continues byte-identical to a run that never spilled."""
    import numpy as np

    cfg, tparams, _ = dense_pair

    def _tiered_router(n=2):
        verifiers = {}
        for i in range(n):
            eng = VerificationEngine(
                cfg, tparams, max_slots=4, max_len=64, page_size=4,
                kv_tier_pages=32, spill_idle_epochs=2,
            )
            verifiers[f"v{i}"] = WISPServer(eng, COEFFS,
                                            network=NetworkModel())
        return FleetRouter(verifiers)

    def run(spill: bool):
        router = _tiered_router()
        sid, now = 0, 0.0
        src = router.open_session(sid, [5, 6, 7, 8], now=now)
        stream = [ev.token for _, ev in router.pop_events()
                  if ev.kind == "FIRST_TOKEN"]
        g = np.random.default_rng(0)

        def one_round(owner, k):
            nonlocal now
            toks = g.integers(0, cfg.vocab, size=k).astype(np.int32)
            qlog = (g.normal(size=(k, cfg.vocab)) * 1.5).astype(np.float32)
            router.submit(sid, toks, qlog, now=now, t_draft=0.01,
                          t_network=0.005)
            while router.queue_depth(owner):
                for v in router.step(owner, now):
                    stream.extend(int(t) for t in toks[: v.accept_len])
                    stream.append(int(v.token))
                now += 0.005
            router.pop_events()

        one_round(src, 3)
        src_eng = router.verifiers[src].engine
        if spill:
            slot = router.verifiers[src].sessions[sid].slot
            assert src_eng.spill_session(slot) > 0
            assert src_eng.kv.spilled_pages(slot) > 0
        committed = [5, 6, 7, 8] + stream
        dst, replayed = router.migrate_session(sid, committed, rounds=1,
                                               now=now)
        assert dst != src and replayed == len(committed) - 1
        if spill:
            # the source teardown left no host entry owned by a live
            # sequence — entries were dropped or orphaned to prefix-only
            assert all(e.owner is None
                       for e in src_eng.kv.tier.entries.values())
        one_round(dst, 2)
        one_round(dst, 3)
        return stream

    assert run(spill=True) == run(spill=False)


def test_chaos_verifier_rejoins(dense_pair, golden_streams):
    """A verifier that dies and recovers re-enters the rotation (rejoin
    hook) without perturbing any stream.  Deliberately uses the legacy
    ``ClusterConfig.fail_at`` tuples (not the DSL) to pin the
    deprecation shim `resolve_fault_schedule` compiles onto the unified
    schedule."""
    cfg, tparams, dparams = dense_pair
    streams, router, _ = _fleet_run(cfg, tparams, dparams, policy="wisp",
                                    fail_at=((0, 0.12, 0.5),))
    assert router.stats["verifier_downs"] == 1
    assert router.stats["rejoins"] == 1
    assert streams == golden_streams
