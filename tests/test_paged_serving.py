"""Paged verification engine: dense-vs-paged losslessness equivalence,
prefix-page sharing across sessions, rollback page reclamation, the
scheduler's live memory budget, and OutOfPages admission queueing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import SchedulerConfig
from repro.models import build
from repro.serving.engine import VerificationEngine, VerifyItem, supports_paged
from repro.serving.server import WISPServer

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, bundle, params


def _greedy_reference(bundle, params, prompt, n_tokens, max_len=128):
    """Pure target greedy decode — the stream any lossless engine must emit."""
    cache = bundle.init_cache(1, max_len, dtype=jnp.float32)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = bundle.prefill(params, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(n_tokens - 1):
        lg, cache = bundle.decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache, jnp.int32(pos)
        )
        out.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return out


def test_engine_selects_paged_for_full_attention():
    assert supports_paged(get_config("qwen2-7b").reduced())
    assert supports_paged(get_config("deepseek-moe-16b").reduced())
    assert supports_paged(get_config("whisper-tiny").reduced())
    assert supports_paged(get_config("llama-3.2-vision-90b").reduced())
    assert not supports_paged(get_config("xlstm-350m").reduced())
    assert not supports_paged(get_config("gemma2-9b").reduced())  # windowed


def test_dense_and_paged_engines_emit_identical_streams(dense_model):
    """Crafted drafts drive full-accept, partial-reject and full-reject
    rounds through BOTH engines; committed streams and accept lengths must
    match token for token (and equal the target's own greedy decode)."""
    cfg, bundle, params = dense_model
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    want = _greedy_reference(bundle, params, prompt, 12)

    dense = VerificationEngine(cfg, params, max_slots=2, max_len=128,
                               method="greedy", paged=False)
    paged = VerificationEngine(cfg, params, max_slots=2, max_len=128,
                               method="greedy", paged=True, page_size=4)
    sd, fd = dense.new_session(prompt)
    sp, fp = paged.new_session(prompt)
    assert fd == fp == want[0]

    committed = [want[0]]
    V = cfg.vocab

    def garbage(next_tok):
        return [(next_tok + 7) % V, (next_tok + 13) % V, (next_tok + 29) % V]

    plans = ["accept", "reject", "partial", "accept"]
    saw_reject = False
    for plan in plans:
        n = len(committed)
        if plan == "accept":
            d = want[n : n + 3]
            expect_l = 3
        elif plan == "reject":
            d = garbage(want[n])
            expect_l = 0
        else:
            d = [want[n]] + garbage(want[n + 1])[:2]
            expect_l = 1
        d = np.asarray(d, np.int32)
        q = np.zeros((len(d), V), np.float32)
        (od,) = dense.verify([VerifyItem(slot=sd, draft_tokens=d, q_logits=q)])
        (op,) = paged.verify([VerifyItem(slot=sp, draft_tokens=d, q_logits=q)])
        assert (od.accept_len, od.token) == (op.accept_len, op.token)
        assert od.accept_len == expect_l
        committed.extend(list(d[: od.accept_len]) + [od.token])
        saw_reject |= od.accept_len < len(d)
    assert saw_reject                       # rollback path exercised
    assert committed == want[: len(committed)]
    assert dense.fed[sd] == paged.fed[sp]


def test_rollback_releases_unreachable_tail_pages(dense_model):
    cfg, _, params = dense_model
    eng = VerificationEngine(cfg, params, max_slots=1, max_len=128,
                             method="greedy", paged=True, page_size=4)
    slot, _ = eng.new_session([1, 2, 3, 4, 5, 6, 7])        # 7 toks, 2 pages
    pages_before = eng.kv.seq_pages(slot)
    # deep garbage draft: verification reserves pages for fed+8 tokens,
    # then rejects everything — the tail pages must come back
    d = np.full(7, cfg.vocab - 1, np.int32)
    q = np.zeros((7, cfg.vocab), np.float32)
    (o,) = eng.verify([VerifyItem(slot=slot, draft_tokens=d, q_logits=q)])
    assert o.accept_len == 0
    # fed advanced by 1 (re-fed last token): 8 tokens -> exactly 2 pages
    assert eng.kv.seq_len(slot) == 8
    assert eng.kv.seq_pages(slot) == 2
    assert eng.kv.seq_pages(slot) <= pages_before + 1


def test_sessions_share_prompt_prefix_pages(dense_model):
    cfg, _, params = dense_model
    eng = VerificationEngine(cfg, params, max_slots=3, max_len=64,
                             method="greedy", paged=True, page_size=4)
    prompt = [5, 4, 3, 2, 1, 0, 1, 2, 3, 4]                 # 2 full pages
    s1, f1 = eng.new_session(prompt)
    before = eng.kv.allocator.in_use
    s2, f2 = eng.new_session(prompt)
    st = eng.prefix_cache_stats()
    assert st["hits"] >= 1
    assert f1 == f2
    p1, p2 = eng.kv.tables[s1].pages, eng.kv.tables[s2].pages
    assert p1[:2] == p2[:2]                                 # physical sharing
    assert eng.kv.allocator.refcount[p1[0]] == 2
    # the shared prefix cost the pool only the private tail
    assert eng.kv.allocator.in_use - before < len(p1)
    assert eng.stats["prefix_cached_tokens"] == 8

    # verification results for the sharing session match a fresh solo engine
    d = np.asarray([9, 9, 9], np.int32)
    q = np.zeros((3, cfg.vocab), np.float32)
    o1, o2 = eng.verify([
        VerifyItem(slot=s1, draft_tokens=d, q_logits=q),
        VerifyItem(slot=s2, draft_tokens=d, q_logits=q),
    ])
    assert (o1.accept_len, o1.token) == (o2.accept_len, o2.token)


def test_scheduler_budget_tracks_live_free_pages(dense_model):
    cfg, _, params = dense_model
    eng = VerificationEngine(cfg, params, max_slots=4, max_len=64,
                             method="greedy", paged=True, page_size=4)
    server = WISPServer(eng, COEFFS)
    cap0 = eng.memory_budget_tokens()
    assert server.open_session(0, [1, 2, 3, 4, 5], slo_class=4).active
    server.submit(0, np.asarray([7, 8], np.int32),
                  np.zeros((2, cfg.vocab), np.float32),
                  now=0.0, t_draft=0.0, t_network=0.0)
    server.step(0.0)
    server.step(1.0)   # budget refreshes at the START of each epoch
    # the epoch's budget is the engine's live capacity, not the static
    # default — and the caller's SchedulerConfig is never mutated
    assert server.memory_budget_tokens == eng.memory_budget_tokens()
    assert server.memory_budget_tokens <= cap0
    assert server.sched_cfg.memory_budget_tokens == \
        SchedulerConfig().memory_budget_tokens


def test_open_session_queues_on_out_of_pages(dense_model):
    cfg, _, params = dense_model
    # pool: 3 usable pages of 8 tokens -> two 9-token prompts cannot coexist
    eng = VerificationEngine(cfg, params, max_slots=4, max_len=24,
                             method="greedy", paged=True, page_size=8,
                             n_pages=4)
    server = WISPServer(eng, COEFFS)
    prompt = list(range(9))
    assert server.open_session(0, prompt, slo_class=4).active
    server.pop_events()                    # drain session 0's direct open
    h1 = server.open_session(1, [9] + prompt[1:], slo_class=4)
    assert h1.state == "queued" and h1.first_token is None
    assert server.queue_depth == 0 and len(server.admission_queue) == 1

    server.step(0.0)                       # still full: stays queued
    assert 1 not in server.sessions and h1.state == "queued"

    server.close_session(0)                # frees pages -> admits session 1
    assert 1 in server.sessions
    assert h1.active and isinstance(h1.first_token, int)
    # the FIRST_TOKEN event matches the handle; the deprecated
    # pop_admissions() shim mirrors it byte for byte
    firsts = [(e.session_id, e.token) for e in server.pop_events()
              if e.kind == "FIRST_TOKEN"]
    assert firsts == [(1, h1.first_token)]
    # the deprecated shim mirrors only QUEUED admissions — byte-identical
    # to the queued sessions' FIRST_TOKEN events
    with pytest.warns(DeprecationWarning):
        assert server.pop_admissions() == firsts


def test_close_session_cancels_queued_session(dense_model):
    cfg, _, params = dense_model
    eng = VerificationEngine(cfg, params, max_slots=4, max_len=24,
                             method="greedy", paged=True, page_size=8,
                             n_pages=4)
    server = WISPServer(eng, COEFFS)
    prompt = list(range(9))
    assert server.open_session(0, prompt, slo_class=4).active
    h1 = server.open_session(1, [9] + prompt[1:], slo_class=4)
    assert h1.state == "queued"
    server.close_session(1)                # cancel while still queued
    assert not server.admission_queue and h1.state == "closed"
    server.close_session(0)                # must NOT admit the cancelled one
    assert not server.sessions
    assert not [e for e in server.pop_events()
                if e.kind == "FIRST_TOKEN" and e.session_id == 1]
    with pytest.raises(KeyError):
        server.close_session(42)           # unknown session still loud


def test_over_admitted_batch_degrades_to_partial_progress(dense_model):
    """The live token budget can over-admit (committed tokens of sessions
    outside the batch are not page headroom).  When verify hits OutOfPages
    the epoch must still serve whatever fits solo instead of requeueing
    the whole batch forever."""
    cfg, _, params = dense_model
    # pool: 7 usable pages of 4 tokens; three 7-token sessions (2 pages
    # each) leave ONE free page.  Session 2 stays idle: its committed
    # tokens inflate the budget, so the scheduler admits BOTH submitting
    # sessions (2*12 = 24 <= free 4 + committed 21) though only one more
    # page exists.
    bundle = build(cfg)
    eng = VerificationEngine(cfg, params, max_slots=3, max_len=24,
                             method="greedy", paged=True, page_size=4,
                             n_pages=8)
    server = WISPServer(eng, COEFFS)
    firsts = {}
    for sid in (0, 1, 2):
        firsts[sid] = server.open_session(
            sid, list(range(10 * sid, 10 * sid + 7)), slo_class=4
        ).first_token
        assert firsts[sid] is not None
    for sid in (0, 1):
        # drafts = the target's own greedy continuation, so the whole block
        # is accepted and the extra page stays HELD (no rollback trim that
        # would free it mid-epoch); each request wants capacity 7+5=12 ->
        # one more page per session
        want = _greedy_reference(
            bundle, params, list(range(10 * sid, 10 * sid + 7)), 5)
        assert want[0] == firsts[sid]
        server.submit(sid, np.asarray(want[1:5], np.int32),
                      np.zeros((4, cfg.vocab), np.float32),
                      now=0.0, t_draft=0.0, t_network=0.0)
    verdicts = server.step(0.0)
    assert len(verdicts) == 1              # one fit, one did not
    assert verdicts[0].accept_len == 4     # full accept: the page stays held
    assert server.queue_depth == 1         # the other is requeued, not lost
    # closing the served session frees pages; the survivor then completes
    server.close_session(verdicts[0].session_id)
    verdicts2 = server.step(1.0)
    assert len(verdicts2) == 1
    assert {verdicts[0].session_id, verdicts2[0].session_id} == {0, 1}


@pytest.mark.slow
@pytest.mark.parametrize("name", ["deepseek-moe-16b", "llama-3.2-vision-90b",
                                  "whisper-tiny"])
def test_paged_matches_dense_across_families(name):
    """moe / vlm / audio: the paged engine's verify outcomes must equal the
    dense engine's on the same crafted session (cross-attention K/V rides
    in the dense side cache; self-attn KV is paged)."""
    cfg = get_config(name).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    extras = None
    if cfg.family == "vlm":
        emb = jax.random.normal(
            jax.random.PRNGKey(1),
            (1, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        extras = {"image_embeds": emb}
    elif cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(1),
            (1, cfg.encoder_frames, cfg.d_model), jnp.float32)
        extras = {"frames": frames}

    dense = VerificationEngine(cfg, params, max_slots=2, max_len=64,
                               method="greedy", paged=False)
    paged = VerificationEngine(cfg, params, max_slots=2, max_len=64,
                               method="greedy", paged=True, page_size=8)
    assert paged.paged and not dense.paged
    prompt = [3, 1, 4, 1, 5, 9]
    sd, fd = dense.new_session(prompt, extras=extras)
    sp, fp = paged.new_session(prompt, extras=extras)
    assert fd == fp

    rng = np.random.default_rng(0)
    last = fd
    for _ in range(2):
        d = np.asarray([last, rng.integers(cfg.vocab), rng.integers(cfg.vocab)],
                       np.int32)
        q = np.zeros((3, cfg.vocab), np.float32)
        (od,) = dense.verify([VerifyItem(slot=sd, draft_tokens=d, q_logits=q)])
        (op,) = paged.verify([VerifyItem(slot=sp, draft_tokens=d, q_logits=q)])
        assert (od.accept_len, od.token) == (op.accept_len, op.token)
        last = od.token
