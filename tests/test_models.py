"""Per-arch smoke tests (reduced configs, one forward/train step on CPU) +
decode-vs-forward consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build
from repro.models.zoo import batch_specs, input_specs
from repro.configs.shapes import SHAPES


def _batch_for(cfg, B, S, rng, with_targets=True):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    }
    if with_targets:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_step(arch, rng):
    """Reduced config: one forward/train step, loss finite, shapes right."""
    cfg = get_config(arch).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, rng)
    loss, aux = bundle.forward_train(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    # logits path (no targets)
    logits, _ = bundle.forward_train(
        params, {k: v for k, v in batch.items() if k != "targets"}
    )
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_serve_path(arch, rng):
    """prefill + 2 decode steps: shapes + finiteness + cache plumbing."""
    cfg = get_config(arch).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    B, S, M = 2, 16, 64
    batch = _batch_for(cfg, B, S, rng, with_targets=False)
    cache = bundle.init_cache(B, M)
    logits, cache = bundle.prefill(params, batch, cache)
    assert logits.shape == (B, S, cfg.vocab)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    lg1, cache = bundle.decode(params, tok, cache, jnp.int32(S))
    lg2, cache = bundle.decode(params, tok, cache, jnp.int32(S + 1))
    assert lg1.shape == (B, 1, cfg.vocab) and lg2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma2-9b", "starcoder2-15b",
                                  "deepseek-moe-16b", "whisper-tiny"])
def test_decode_matches_forward_teacher_forced(arch, rng):
    """Serving-path correctness: prefill(prompt) + decode(suffix tokens) must
    reproduce the full-sequence forward logits at the suffix positions.
    This is the property WISP verification relies on (verify logits == what
    a full forward would produce)."""
    import dataclasses

    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # prefill uses capacity routing (EXPERIMENTS §Perf cell B) while
        # verify is exact-dropless; they agree whenever nothing drops, so
        # test at a capacity factor that guarantees no drops
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0)
        )
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(2), dtype=jnp.float32)
    B, P, T = 1, 8, 4
    toks = rng.integers(0, cfg.vocab, (B, P + T))
    batch_full = _batch_for(cfg, B, P + T, rng, with_targets=False)
    batch_full["tokens"] = jnp.asarray(toks, jnp.int32)
    if cfg.moe is not None:
        cache_ref = bundle.init_cache(B, P + T + 8, dtype=jnp.float32)
        full_logits, _ = bundle.prefill(params, batch_full, cache_ref)
    else:
        full_logits, _ = bundle.forward_train(params, batch_full)

    batch_prompt = {k: (v[:, :P] if k == "tokens" else v)
                    for k, v in batch_full.items()}
    cache = bundle.init_cache(B, P + T + 8, dtype=jnp.float32) \
        if cfg.family != "ssm" else bundle.init_cache(B, P + T + 8)
    pl, cache = bundle.prefill(params, batch_prompt, cache)
    np.testing.assert_allclose(
        np.asarray(pl[:, -1], np.float32),
        np.asarray(full_logits[:, P - 1], np.float32),
        atol=2e-3, rtol=2e-3,
    )
    dl, cache = bundle.decode(
        params, jnp.asarray(toks[:, P:], jnp.int32), cache, jnp.int32(P)
    )
    np.testing.assert_allclose(
        np.asarray(dl, np.float32),
        np.asarray(full_logits[:, P:], np.float32),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_cover_all_cells(arch):
    """input_specs returns allocation-free specs for every runnable cell."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, f"{arch} x {shape.name} has no inputs"
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_moe_dropless_is_composition_independent(rng):
    """Verification invariance: a request's MoE output must not depend on
    what else is in the microbatch (dropless routing)."""
    cfg = get_config("deepseek-moe-16b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(3), dtype=jnp.float32)
    M = 64
    t1 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    t2 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    c1 = bundle.init_cache(1, M, dtype=jnp.float32)
    solo, _ = bundle.prefill(params, {"tokens": t1}, c1)
    c2 = bundle.init_cache(2, M, dtype=jnp.float32)
    both, _ = bundle.prefill(
        params, {"tokens": jnp.concatenate([t1, t2], 0)}, c2
    )
    np.testing.assert_allclose(
        np.asarray(solo[0], np.float32), np.asarray(both[0], np.float32),
        atol=1e-4, rtol=1e-4,
    )


def test_gemma2_softcap_bounds_logits(rng):
    cfg = get_config("gemma2-9b").reduced()
    assert cfg.final_softcap > 0
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(4))
    batch = _batch_for(cfg, 1, 16, rng, with_targets=False)
    logits, _ = bundle.forward_train(params, batch)
    assert np.abs(np.asarray(logits, np.float32)).max() <= cfg.final_softcap + 1e-3
