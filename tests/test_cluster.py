"""Event-driven cluster runtime: determinism, speculative-continue
rollback/commit equivalence with the lock-step driver, close-while-pending
regression, churn/admission integration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterRuntime, EventKind, EventQueue, build_fleet
from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_pair():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = bundle.init(jax.random.PRNGKey(0))
    dparams = bundle.init(jax.random.PRNGKey(1))
    return cfg, tparams, dparams


def _cluster_run(cfg, tparams, dparams, ccfg, *, policy="wisp",
                 method="residual", greedy=False, max_slots=None):
    engine = VerificationEngine(
        cfg, tparams, max_slots=max_slots or ccfg.devices,
        max_len=ccfg.max_len, method=method,
    )
    server = WISPServer(engine, COEFFS, policy=policy,
                        network=NetworkModel())
    fleet = build_fleet(ccfg, cfg.vocab)
    edges = [
        EdgeDevice(cfg, dparams, k_max=ccfg.k_max, max_len=ccfg.max_len,
                   seed=100 + sp.idx, draft_speed=sp.draft_speed,
                   greedy=greedy)
        for sp in fleet
    ]
    runtime = ClusterRuntime(server, edges, fleet, ccfg, vocab=cfg.vocab)
    return runtime.run()


def _lockstep_run(cfg, tparams, dparams, ccfg, *, method="residual",
                  greedy=False):
    engine = VerificationEngine(cfg, tparams, max_slots=ccfg.devices,
                                max_len=ccfg.max_len, method=method)
    server = WISPServer(engine, COEFFS, network=NetworkModel())
    fleet = build_fleet(ccfg, cfg.vocab)
    edges = [
        EdgeDevice(cfg, dparams, k_max=ccfg.k_max, max_len=ccfg.max_len,
                   seed=100 + sp.idx, draft_speed=sp.draft_speed,
                   greedy=greedy)
        for sp in fleet
    ]
    now = 0.0
    for sp, dev in zip(fleet, edges):
        handle = server.open_session(sp.idx, sp.prompt,
                                     slo_class=sp.slo_class,
                                     draft_speed=sp.draft_speed,
                                     queue_on_full=False)
        dev.start_session(sp.idx, sp.prompt, handle.first_token)
    for _ in range(ccfg.rounds):
        results = {}
        for i, dev in enumerate(edges):
            res = dev.draft_round()
            server.submit(i, res.tokens, res.q_logits, now=now,
                          t_draft=res.draft_time, t_network=0.01)
            results[i] = res
        while server.queue_depth:
            verdicts = server.step(now)
            if not verdicts:
                now += 0.005
                continue
            for v in verdicts:
                edges[v.session_id].apply_verdict(
                    v.accept_len, v.token, results[v.session_id].tokens
                )
            now += 0.01
    return edges


def test_event_queue_same_instant_ordering():
    """Same-timestamp events pop in EventKind priority order (verdicts and
    arrivals before dispatch), then insertion order."""
    q = EventQueue()
    q.push(1.0, EventKind.DISPATCH, "d")
    q.push(1.0, EventKind.REQUEST, "r1")
    q.push(1.0, EventKind.VERDICT, "v")
    q.push(1.0, EventKind.REQUEST, "r2")
    q.push(0.5, EventKind.DISPATCH, "early")
    order = [q.pop().payload for _ in range(5)]
    assert order == ["early", "v", "r1", "r2", "d"]


def test_cluster_deterministic_under_fixed_seed(dense_pair):
    """Two runs with identical seeds produce the identical event outcome:
    same iteration logs, same committed streams, same horizon."""
    cfg, tparams, dparams = dense_pair
    ccfg = ClusterConfig(devices=2, rounds=3, k_max=3, max_len=128, seed=0)
    a = _cluster_run(cfg, tparams, dparams, ccfg)
    b = _cluster_run(cfg, tparams, dparams, ccfg)
    assert a.horizon == b.horizon
    assert [dataclasses.astuple(it) for it in a.metrics.iterations] == \
           [dataclasses.astuple(it) for it in b.metrics.iterations]
    for da, db in zip(a.devices, b.devices):
        assert da.session.committed == db.session.committed
    assert dataclasses.astuple(a.metrics.spec) == \
           dataclasses.astuple(b.metrics.spec)


def test_cluster_stream_matches_lockstep_rollback_path(dense_pair):
    """Speculative continuation with a weak draft (residual accept, most
    guesses wrong → rollback path): the clusterized stream must commit
    byte-identical tokens to the lock-step driver for the same seed."""
    cfg, tparams, dparams = dense_pair
    ccfg = ClusterConfig(devices=2, rounds=3, k_max=3, max_len=128, seed=0)
    result = _cluster_run(cfg, tparams, dparams, ccfg)
    sync_edges = _lockstep_run(cfg, tparams, dparams, ccfg)
    assert result.metrics.spec.rollbacks > 0    # the path was exercised
    for dev_c, dev_s in zip(result.devices, sync_edges):
        assert dev_c.session.committed == dev_s.session.committed


def test_cluster_stream_matches_lockstep_commit_path(dense_pair):
    """Self-speculation (draft == target, greedy): every block fully
    accepts and every speculation commits; streams must still match the
    lock-step driver byte for byte."""
    cfg, tparams, _ = dense_pair
    ccfg = ClusterConfig(devices=2, rounds=3, k_max=3, max_len=128, seed=0)
    result = _cluster_run(cfg, tparams, tparams, ccfg, method="greedy",
                          greedy=True)
    sync_edges = _lockstep_run(cfg, tparams, tparams, ccfg,
                               method="greedy", greedy=True)
    assert result.metrics.spec.commits > 0      # the path was exercised
    assert result.metrics.acceptance_rate() == 1.0
    for dev_c, dev_s in zip(result.devices, sync_edges):
        assert dev_c.session.committed == dev_s.session.committed


def test_close_session_purges_pending(dense_pair):
    """Regression: close_session must drop the closed session's in-flight
    requests from the pending pool — a later step() used to KeyError on
    sessions[r.session_id]."""
    cfg, tparams, dparams = dense_pair
    engine = VerificationEngine(cfg, tparams, max_slots=2, max_len=128)
    server = WISPServer(engine, COEFFS)
    dev = EdgeDevice(cfg, dparams, k_max=3, max_len=128)
    first = server.open_session(0, [1, 2, 3], slo_class=2).first_token
    dev.start_session(0, [1, 2, 3], first)
    res = dev.draft_round()
    server.submit(0, res.tokens, res.q_logits, now=0.0, t_draft=0.0,
                  t_network=0.0)
    assert server.queue_depth == 1
    server.close_session(0)
    assert server.queue_depth == 0              # purged with the session
    verdicts = server.step(0.0)                 # must not KeyError
    assert verdicts == []


def test_churn_mode_with_admission_queue(dense_pair):
    """Session churn against an engine with fewer slots than devices: the
    second device waits in the admission queue and is admitted when the
    first session closes; the run completes sessions from both devices."""
    cfg, tparams, dparams = dense_pair
    ccfg = ClusterConfig(devices=2, rounds=None, horizon=5.0, k_max=2,
                         max_len=128, seed=0, response_len_mean=3.0,
                         think_time_mean=0.05)
    result = _cluster_run(cfg, tparams, dparams, ccfg, max_slots=1)
    m = result.metrics
    assert len(m.sessions) >= 2
    assert {s.device for s in m.sessions} == {0, 1}
    # streams were committed and sessions closed cleanly
    assert all(s.committed > 0 for s in m.sessions)
