"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.logit_features.ops import logit_features_op, logit_features_ref
from repro.kernels.paged_attention.ops import (
    gather_pages,
    paged_attention_op,
    paged_attention_ref,
)
from repro.kernels.verify_attention.ops import (
    verify_attention_op,
    verify_attention_ref,
)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


# ---------------------------------------------------------------------------
# verify attention (small-Q x long-KV online softmax)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,K,S,D", [
    (1, 4, 4, 1, 128, 64),       # plain decode, MHA
    (2, 4, 2, 8, 256, 64),       # GQA verify block
    (3, 8, 1, 5, 384, 128),      # MQA, ragged lengths
    (2, 4, 2, 16, 1024, 128),    # long prefix
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_sweep(B, Hq, Hkv, K, S, D, dtype):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(B, K, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    lengths = jnp.asarray(rng.integers(K + 1, S + 1, size=B), jnp.int32)
    out = verify_attention_op(q, k, v, lengths, blk_kv=128)
    ref = verify_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_verify_attention_softcap_and_window():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, K, S, D = 2, 4, 2, 4, 256, 64
    q = jnp.asarray(rng.normal(size=(B, K, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([200, 256], jnp.int32)
    for kw in ({"softcap": 30.0}, {"window": 64}, {"softcap": 50.0, "window": 128}):
        out = verify_attention_op(q, k, v, lengths, blk_kv=128, **kw)
        ref = verify_attention_ref(q, k, v, lengths, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_verify_attention_masking_is_exact():
    """Tokens beyond `lengths` must not leak into the output."""
    rng = np.random.default_rng(1)
    B, Hq, Hkv, K, S, D = 1, 2, 2, 2, 256, 32
    q = jnp.asarray(rng.normal(size=(B, K, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([100], jnp.int32)
    out1 = verify_attention_op(q, k, v, lengths)
    # poison the masked region
    k2 = k.at[:, 100:].set(1e4)
    v2 = v.at[:, 100:].set(-1e4)
    out2 = verify_attention_op(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,D,page,n_pages,n_max", [
    (2, 4, 2, 64, 128, 8, 4),
    (4, 8, 8, 64, 256, 16, 3),
    (1, 8, 1, 128, 128, 4, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, Hq, Hkv, D, page, n_pages, n_max, dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), dtype)
    kp = jnp.asarray(rng.normal(size=(n_pages, page, Hkv, D)), dtype)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, Hkv, D)), dtype)
    bt = jnp.asarray(
        rng.permutation(n_pages)[: B * n_max].reshape(B, n_max), jnp.int32
    )
    lengths = jnp.asarray(rng.integers(1, n_max * page + 1, size=B), jnp.int32)
    out = paged_attention_op(q, kp, vp, bt, lengths)
    ref = paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_paged_matches_dense_attention():
    """Paged result == dense attention over the gathered pages."""
    rng = np.random.default_rng(3)
    B, H, Hkv, D, page, n_pages, n_max = 2, 4, 2, 64, 128, 6, 3
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, Hkv, D)), jnp.float32)
    bt = jnp.asarray([[0, 2, 4], [1, 3, 5]], jnp.int32)
    lengths = jnp.asarray([300, 384], jnp.int32)
    out = paged_attention_op(q, kp, vp, bt, lengths)
    kd = gather_pages(kp, bt)
    vd = gather_pages(vp, bt)
    ref = verify_attention_ref(
        q[:, None], kd, vd, lengths
    )[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# logit features
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,V", [(1, 128), (4, 1000), (2, 4096), (8, 50304)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logit_features_sweep(B, V, dtype):
    rng = np.random.default_rng(11)
    logits = jnp.asarray(rng.normal(size=(B, V)) * 3, dtype)
    out = logit_features_op(logits)
    ref = logit_features_ref(logits)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=3e-2 if dtype == jnp.bfloat16 else 1e-5
    )


def test_logit_features_values():
    """Hand-checkable case: uniform logits."""
    V = 64
    logits = jnp.zeros((1, V), jnp.float32)
    f = np.asarray(logit_features_ref(logits))[0]
    assert abs(f[0] - 1.0 / V) < 1e-6          # confidence
    assert abs(f[1] - 1.0) < 1e-6              # normalized entropy = 1
    assert abs(f[2] - 0.0) < 1e-6              # margin
    assert abs(f[3] - 0.0) < 1e-6              # logit std
    assert abs(f[4] - 8.0 / V) < 1e-6          # top-8 mass
