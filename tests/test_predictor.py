"""Rejection predictor: MLP + stump ensemble on synthetic separable data,
operating-point metrics (paper Table 4), persistence."""
import numpy as np
import pytest

from repro.core.features import NUM_FEATURES
from repro.core.predictor import (
    MLPConfig,
    RejectionPredictor,
    StumpEnsemble,
    auc_score,
    operating_point,
    train_mlp,
    train_stumps,
)


def _synth(rng, n=3000, sep=2.0, pos_frac=0.73):
    """Synthetic feature clouds mimicking the paper's: accepted tokens have
    higher confidence/margin, lower entropy."""
    n_pos = int(n * pos_frac)
    n_neg = n - n_pos
    mu_pos = np.array([0.8, 0.2, 0.5, 3.0, 0.95])
    mu_neg = mu_pos - sep * np.array([0.25, -0.25, 0.3, 0.5, 0.2])
    X = np.concatenate(
        [
            rng.normal(mu_pos, 0.3, size=(n_pos, NUM_FEATURES)),
            rng.normal(mu_neg, 0.3, size=(n_neg, NUM_FEATURES)),
        ]
    )
    y = np.concatenate([np.ones(n_pos), np.zeros(n_neg)])
    idx = rng.permutation(n)
    return X[idx], y[idx]


def test_mlp_learns_and_beats_chance():
    rng = np.random.default_rng(0)
    X, y = _synth(rng)
    Xtr, ytr, Xte, yte = X[:2400], y[:2400], X[2400:], y[2400:]
    pred = train_mlp(Xtr, ytr, MLPConfig(epochs=12))
    m = operating_point(np.asarray(pred.predict_accept(Xte)), yte)
    assert m["acc"] > 0.85
    assert m["bal_acc"] > 0.85
    auc = auc_score(np.asarray(pred.proba(Xte)), yte)
    assert auc > 0.9


def test_class_weight_trades_coverage_for_specificity():
    """Raising the rejected-class weight must reduce FPR (Theorem 1 lever)."""
    rng = np.random.default_rng(1)
    X, y = _synth(rng, sep=1.0)
    light = train_mlp(X, y, MLPConfig(epochs=10, neg_weight=1.0, seed=1))
    heavy = train_mlp(X, y, MLPConfig(epochs=10, neg_weight=6.0, seed=1))
    m_light = operating_point(np.asarray(light.predict_accept(X)), y)
    m_heavy = operating_point(np.asarray(heavy.predict_accept(X)), y)
    assert m_heavy["fpr"] <= m_light["fpr"] + 1e-9
    assert m_heavy["rec1"] <= m_light["rec1"] + 1e-9   # the trade-off


def test_stump_ensemble_trains():
    rng = np.random.default_rng(2)
    X, y = _synth(rng)
    model = train_stumps(X, y, n_rounds=40)
    m = operating_point(model.predict_accept(X), y)
    assert m["acc"] > 0.8
    assert auc_score(model.proba(X), y) > 0.85


def test_operating_point_counts():
    y = np.array([1, 1, 0, 0, 1])
    p = np.array([True, False, True, False, True])
    m = operating_point(p, y)
    assert m["confusion"] == {"tp": 2, "fn": 1, "fp": 1, "tn": 1}
    assert abs(m["rec1"] - 2 / 3) < 1e-9
    assert abs(m["spec"] - 1 / 2) < 1e-9
    assert abs(m["fpr"] - 1 / 2) < 1e-9


def test_auc_degenerate_and_perfect():
    assert auc_score(np.array([0.9, 0.8, 0.2, 0.1]), np.array([1, 1, 0, 0])) == 1.0
    assert auc_score(np.array([0.1, 0.2, 0.8, 0.9]), np.array([1, 1, 0, 0])) == 0.0
    assert auc_score(np.array([0.5, 0.5]), np.array([1, 1])) == 0.5


def test_predictor_save_load(tmp_path):
    rng = np.random.default_rng(3)
    X, y = _synth(rng, n=500)
    pred = train_mlp(X, y, MLPConfig(epochs=3))
    path = tmp_path / "p.json"
    pred.save(path)
    pred2 = RejectionPredictor.load(path)
    np.testing.assert_allclose(
        np.asarray(pred.proba(X[:16])), np.asarray(pred2.proba(X[:16])), atol=1e-6
    )
