"""Serving with RECURRENT targets (xLSTM / Zamba2): the engine's stepwise
verify + state-snapshot rollback path (DESIGN.md §5 — a recurrent state
cannot be truncated like a KV prefix, so the engine steps token-by-token
and selects the state at the accepted length)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serving.engine import VerificationEngine, VerifyItem


@pytest.fixture(scope="module", params=["xlstm-350m", "zamba2-1.2b"])
def recurrent_target(request):
    cfg = get_config(request.param).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, bundle, params


def _autoregressive_greedy(bundle, params, prompt, n_tokens):
    cfg = bundle.cfg
    cache = bundle.init_cache(1, 256)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = bundle.prefill(params, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(n_tokens - 1):
        lg, cache = bundle.decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache, jnp.int32(pos)
        )
        out.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return out


@pytest.mark.slow
def test_recurrent_verify_lossless_greedy(recurrent_target):
    """Stepwise verification against a recurrent target must emit exactly
    the target's greedy stream, including across rejections (state
    rollback must not corrupt the recurrence)."""
    cfg, bundle, params = recurrent_target
    prompt = [5, 6, 7]
    want = _autoregressive_greedy(bundle, params, prompt, 8)

    engine = VerificationEngine(cfg, params, max_slots=2, max_len=256,
                                method="greedy", cache_dtype=jnp.float32)
    slot, first = engine.new_session(prompt)
    assert first == want[0]
    got = [first]
    rng = np.random.default_rng(0)
    while len(got) < len(want):
        # adversarial draft: half-right (forces mid-block rejections)
        k = 3
        start = len(got)
        draft = []
        for i in range(k):
            if start + i < len(want) and rng.random() < 0.5:
                draft.append(want[start + i])       # correct token
            else:
                draft.append(int(rng.integers(0, cfg.vocab)))
        draft = np.asarray(draft, np.int32)
        (out,) = engine.verify(
            [VerifyItem(slot=slot, draft_tokens=draft,
                        q_logits=np.zeros((k, cfg.vocab), np.float32))]
        )
        got.extend(int(t) for t in draft[: out.accept_len])
        got.append(out.token)
    assert got[: len(want)] == want


def test_recurrent_batched_verify_matches_solo(recurrent_target):
    """Stepwise verify in a batch == verified alone (state selection is
    per-row)."""
    cfg, bundle, params = recurrent_target
    rng = np.random.default_rng(1)
    prompts = [[2, 3, 4], [9, 8, 7]]
    drafts = [rng.integers(0, cfg.vocab, size=3).astype(np.int32)
              for _ in prompts]

    def fresh():
        return VerificationEngine(cfg, params, max_slots=4, max_len=128,
                                  method="greedy", cache_dtype=jnp.float32)

    solo = []
    for p, d in zip(prompts, drafts):
        eng = fresh()
        slot, _ = eng.new_session(p)
        (o,) = eng.verify([VerifyItem(slot=slot, draft_tokens=d,
                                      q_logits=np.zeros((3, cfg.vocab),
                                                        np.float32))])
        solo.append((o.accept_len, o.token))

    eng = fresh()
    items = []
    for p, d in zip(prompts, drafts):
        slot, _ = eng.new_session(p)
        items.append(VerifyItem(slot=slot, draft_tokens=d,
                                q_logits=np.zeros((3, cfg.vocab),
                                                  np.float32)))
    batched = [(o.accept_len, o.token) for o in eng.verify(items)]
    assert solo == batched
