"""Failure detection + straggler hedging."""
import pytest

from repro.runtime.failure import FailurePlan, HeartbeatMonitor
from repro.runtime.straggler import HedgedDispatcher, NoReplicasError


def test_heartbeat_declares_death_and_rejoin():
    deaths = []
    mon = HeartbeatMonitor(timeout=1.0, on_death=lambda p, t: deaths.append(p))
    mon.register("a", 0.0)
    mon.register("b", 0.0)
    mon.beat("a", 0.9)
    dead = mon.sweep(1.5)
    assert dead == ["b"] and deaths == ["b"]
    assert mon.alive_peers() == ["a"]
    mon.beat("a", 2.0)
    mon.beat("b", 2.0)            # elastic rejoin
    assert mon.n_alive == 2
    assert mon.sweep(2.1) == []


def test_failure_plan_windows():
    plan = FailurePlan([("r0", 5.0, 10.0), ("r1", 3.0, None)])
    assert plan.is_up("r0", 4.9) and not plan.is_up("r0", 5.0)
    assert plan.is_up("r0", 10.0)
    assert not plan.is_up("r1", 100.0)
    assert plan.is_up("r2", 0.0)


def test_hedged_dispatch_basic_flow():
    hd = HedgedDispatcher(["r0", "r1"], guard=0.01, hedge_factor=2.0)
    key = (7, 0)
    r = hd.dispatch(key, eta=0.1, now=0.0)
    assert r in ("r0", "r1")
    # before the hedge deadline nothing happens
    assert hd.sweep(0.1) == []
    # past 2 * (eta + guard) the batch is hedged to the other replica
    hedged = hd.sweep(0.5)
    assert len(hedged) == 1
    (k, backup) = hedged[0]
    assert k == key and backup != r
    # idempotent commit: first wins, duplicate dropped
    assert hd.commit(key) is True
    assert hd.commit(key) is False
    assert hd.stats["dup_commits_dropped"] == 1


def test_hedge_fires_once_per_key():
    hd = HedgedDispatcher(["r0", "r1"], hedge_factor=1.0, guard=0.0)
    hd.dispatch((1, 1), eta=0.01, now=0.0)
    assert len(hd.sweep(1.0)) == 1
    assert hd.sweep(2.0) == []        # already hedged


def test_replica_failure_redispatches_inflight():
    hd = HedgedDispatcher(["r0", "r1", "r2"])
    keys = [(i, 0) for i in range(6)]
    assignments = {k: hd.dispatch(k, eta=0.1, now=0.0) for k in keys}
    victim = assignments[keys[0]]
    hd.remove_replica(victim)
    assert victim not in hd.replicas
    for k, f in hd.inflight.items():
        assert f.replica != victim
    # victims' work counted as hedged
    n_victim = sum(1 for k, r in assignments.items() if r == victim)
    assert hd.stats["hedged"] == n_victim


def test_add_replica_elastic_scaleup():
    hd = HedgedDispatcher(["r0"])
    hd.add_replica("r1")
    seen = {hd.dispatch((i, 0), 0.1, 0.0) for i in range(4)}
    assert seen == {"r0", "r1"}


def test_pick_replica_all_excluded_returns_none():
    # ISSUE-6 satellite: the old code fell through to replicas[0] — the
    # excluded (wedged) primary — doubling the stuck work instead of
    # skipping the hedge
    hd = HedgedDispatcher(["r0"])
    assert hd.pick_replica(exclude="r0") is None
    assert hd.pick_replica() == "r0"        # no exclusion still round-robins


def test_single_replica_sweep_skips_hedge():
    hd = HedgedDispatcher(["r0"], hedge_factor=1.0, guard=0.0)
    hd.dispatch((1, 0), eta=0.01, now=0.0)
    assert hd.sweep(5.0) == []              # nowhere to hedge: skip, not self
    assert hd.stats["hedges_skipped"] == 1
    # the entry stays in flight and is re-checked: a rejoin can rescue it
    hd.add_replica("r1")
    assert hd.sweep(5.1) == [((1, 0), "r1")]


def test_remove_last_replica_enters_degraded_mode():
    # ISSUE-6 satellite: removing the last replica used to leave it in
    # rotation, silently "re-dispatching" work back to the dead replica
    hd = HedgedDispatcher(["r0"])
    hd.dispatch((3, 2), eta=0.1, now=0.0)
    plan = hd.remove_replica("r0")
    assert hd.replicas == []
    assert plan == [((3, 2), None)]         # explicit orphan signal
    assert hd.degraded
    assert (3, 2) in hd.orphaned and not hd.inflight
    with pytest.raises(NoReplicasError):
        hd.dispatch((4, 0), eta=0.1, now=1.0)


def test_add_replica_reclaims_orphans():
    hd = HedgedDispatcher(["r0"])
    hd.dispatch((3, 2), eta=0.1, now=0.0)
    hd.remove_replica("r0")
    plan = hd.add_replica("r1")
    assert plan == [((3, 2), "r1")]
    assert not hd.degraded and not hd.orphaned
    assert hd.inflight[(3, 2)].replica == "r1"
    assert hd.inflight[(3, 2)].hedged       # never re-hedged by the sweep
    # an orphan whose verdict somehow still lands commits (and clears) fine
    hd.dispatch((5, 0), eta=0.1, now=0.0)
    hd.remove_replica("r1")
    assert hd.commit((5, 0)) is True
    assert (5, 0) not in hd.orphaned


def test_heartbeat_on_rejoin_hook():
    # ISSUE-6 satellite: beat() on a dead peer flipped alive silently —
    # the dispatcher rotation never learned about the rejoin
    deaths, rejoins = [], []
    mon = HeartbeatMonitor(
        timeout=1.0,
        on_death=lambda p, t: deaths.append((p, t)),
        on_rejoin=lambda p, t: rejoins.append((p, t)),
    )
    mon.register("a", 0.0)
    assert mon.sweep(2.0) == ["a"]
    mon.beat("a", 3.0)
    assert rejoins == [("a", 3.0)]
    assert mon.rejoins == [("a", 3.0)]
    # a beat on an alive peer is not a rejoin
    mon.beat("a", 3.5)
    assert len(rejoins) == 1


def test_track_then_commit_dedups_hedge_race():
    # the fleet router routes by ownership (track), not round-robin
    # (dispatch); the race where primary and hedge both answer resolves
    # first-wins on the shared (session_id, round_index) key
    hd = HedgedDispatcher(["r0", "r1"], hedge_factor=1.0, guard=0.0)
    hd.track((9, 4), "r0", eta=0.01, now=0.0)
    [(key, backup)] = hd.sweep(1.0)
    assert key == (9, 4) and backup == "r1"
    assert hd.commit((9, 4)) is True        # whichever replica answers first
    assert hd.commit((9, 4)) is False       # the straggler's late answer
    assert hd.stats["dup_commits_dropped"] == 1
