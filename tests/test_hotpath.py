"""Device-resident verification hot path (DESIGN.md §9).

Three guarantees of the fused-dispatch refactor:

  * **golden streams** — committed token streams are byte-identical to the
    pre-refactor engine for every backend × policy × prefill-mode cell
    (fixtures in ``tests/golden/streams.json``, captured at the seed
    commit by ``tests/_golden_scenario.py``; residual verification with
    rng-tagged rows, so accept draws AND correction sampling are pinned);
  * **dispatch/byte budgets** — one fused program launch per verify call
    on every backend, O(1) in the draft length on the recurrent backend,
    and zero q staging in greedy mode (the dispatch-counter fixture CI's
    budget gate also uses);
  * **compact-q semantics** — the O(K·C) wire format keeps accept
    decisions (and greedy entirely) EXACT, and its residual correction
    distribution stays within the documented ``2·tail/Z`` total-variation
    bound of the dense rule.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _golden_scenario as golden
from repro.configs import get_config
from repro.core.speculative import (
    CompactQ,
    compact_from_logits,
    speculative_verify,
    speculative_verify_compact,
    stack_compact,
)
from repro.models import build
from repro.serving.engine import VerificationEngine, VerifyItem
from repro.serving.transport import NetworkModel


# ---------------------------------------------------------------------------
# golden-stream regression (pre- vs post-refactor byte equality)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden_streams():
    with open(golden.GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize(
    "backend,policy,prefill",
    list(golden.all_cells()),
    ids=lambda v: str(v),
)
def test_golden_stream_unchanged(golden_streams, backend, policy, prefill):
    key = f"{backend}/{policy}/{prefill}"
    got = golden.run_scenario(backend, policy, prefill)
    assert got == golden_streams[key], (
        f"committed stream drifted from the seed fixture for {key}"
    )


@pytest.mark.parametrize("backend", list(golden.BACKENDS))
def test_golden_mixed_k_stream_unchanged(golden_streams, backend):
    """Ragged-K cells (adaptive speculation, DESIGN.md §11): sessions
    batch at different draft lengths every round — the padded mixed-K
    dispatch must keep replaying the captured streams byte-for-byte."""
    key = f"mixed-k/{backend}"
    got = golden.run_mixed_k_scenario(backend)
    assert got == golden_streams[key], (
        f"committed stream drifted from the seed fixture for {key}"
    )


def test_golden_fleet_stream_unchanged(golden_streams):
    """3-verifier fleet cell with a forced healthy-owner migration: the
    prefix-locality routing, restore_session committed-stream replay and
    post-migration round keying must replay byte-identically."""
    got = golden.run_fleet_scenario()
    assert got == golden_streams["fleet/3-verifier"], (
        "committed stream drifted from the seed fixture for fleet/3-verifier"
    )


@pytest.mark.parametrize("fmt,quantize", [("raw", False), ("int8", True)])
def test_golden_tiered_stream_unchanged(golden_streams, fmt, quantize):
    """Tiered cells (DESIGN.md §12): every session's pages are force-spilled
    to the host tier after each round and paged back in mid-stream by the
    next verify.  Both spill formats ({raw, int8-quantize-on}) must replay
    byte-identically to the stored cell AND to the untiered
    paged/wisp/monolithic baseline — the tier is invisible to the accept
    rule and the correction draws."""
    got = golden.run_tiered_scenario(quantize)
    assert got == golden_streams[f"tiered/{fmt}"], (
        f"committed stream drifted from the seed fixture for tiered/{fmt}"
    )
    assert got == golden_streams["paged/wisp/monolithic"], (
        "spill/reload perturbed the stream vs the untiered paged baseline"
    )


@pytest.mark.parametrize("policy", list(golden.POLICIES))
@pytest.mark.parametrize("prefill", list(golden.PREFILL_MODES))
def test_paged_golden_cells_replay_with_tier_enabled(golden_streams, policy,
                                                     prefill):
    """Acceptance: the EXISTING paged golden cells replay byte-identical
    with a host tier merely attached (no forced spill) — enabling tiering
    on a workload that fits in the device pool is a strict no-op."""
    key = f"paged/{policy}/{prefill}"
    got = golden.run_scenario(
        "paged", policy, prefill,
        engine_overrides={"kv_tier_pages": 64, "spill_quantize": True,
                          "spill_idle_epochs": 2},
    )
    assert got == golden_streams[key], (
        f"attaching an (idle) host tier changed the stream for {key}"
    )


# ---------------------------------------------------------------------------
# dispatch / staging budgets (the CI budget gate's counter fixture)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_models():
    out = {}
    for backend, name in (("attention", "qwen2-7b"),
                          ("recurrent", "xlstm-350m")):
        cfg = get_config(name).reduced()
        bundle = build(cfg)
        params = (bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
                  if cfg.family in ("ssm", "hybrid")
                  else bundle.init(jax.random.PRNGKey(0)))
        out[backend] = (cfg, params)
    return out


@pytest.fixture
def dispatch_counter():
    """Snapshot-and-delta reader over an engine's compiled-program launch
    counters (``VerificationEngine.dispatch_counts``)."""

    class Counter:
        def __init__(self):
            self._snap = {}

        def start(self, engine):
            self._snap = dict(engine.dispatch_counts)
            self.engine = engine

        def delta(self, name: str) -> int:
            return self.engine.dispatch_counts[name] - \
                self._snap.get(name, 0)

    return Counter()


def _engine(tiny_models, backend, **kw):
    cfg, params = tiny_models["recurrent" if backend == "recurrent"
                              else "attention"]
    ekw = {"max_slots": 4, "max_len": 128, "seed": 3}
    if backend == "recurrent":
        ekw["cache_dtype"] = jnp.float32
    elif backend == "paged":
        ekw.update(paged=True, page_size=4)
    else:
        ekw["paged"] = False
    ekw.update(kw)
    return cfg, VerificationEngine(cfg, params, **ekw)


def _mk_items(cfg, slots, K, rnd, *, q="dense"):
    items = []
    for i, s in enumerate(slots):
        g = np.random.default_rng(17 * rnd + i)
        toks = g.integers(0, cfg.vocab, size=K).astype(np.int32)
        qlog = (g.normal(size=(K, cfg.vocab)) * 1.5).astype(np.float32)
        it = VerifyItem(slot=s, draft_tokens=toks, rng_tag=(i, rnd))
        if q == "dense":
            it.q_logits = qlog
        elif q == "compact":
            it.q_compact = compact_from_logits(qlog, toks, C=8)
        items.append(it)
    return items


@pytest.mark.parametrize("backend", ["dense", "paged", "recurrent"])
def test_one_fused_dispatch_per_verify(tiny_models, dispatch_counter,
                                       backend):
    """Every verify() batch is exactly ONE compiled-program launch."""
    cfg, eng = _engine(tiny_models, backend)
    slots = [eng.new_session([1, 2, 3 + i])[0] for i in range(2)]
    eng.verify(_mk_items(cfg, slots, 3, 0))          # compile
    dispatch_counter.start(eng)
    for r in range(1, 4):
        eng.verify(_mk_items(cfg, slots, 3, r))
    assert dispatch_counter.delta("verify") == 3


def test_recurrent_dispatches_independent_of_k(tiny_models,
                                               dispatch_counter):
    """The scan-based recurrent verify is O(1) dispatches in the draft
    length (the stepwise loop was K+2)."""
    cfg, eng = _engine(tiny_models, "recurrent")
    slots = [eng.new_session([1, 2, 3])[0]]
    per_k = {}
    for K in (2, 8):
        eng.verify(_mk_items(cfg, slots, K, 0))      # compile this bucket
        dispatch_counter.start(eng)
        eng.verify(_mk_items(cfg, slots, K, 1))
        per_k[K] = dispatch_counter.delta("verify")
    assert per_k == {2: 1, 8: 1}


def test_greedy_stages_no_q(tiny_models):
    """Satellite: greedy verification must not build ANY q staging buffer
    (the seed engine ran ``np.full((nb,K,V), -30.0)`` unconditionally)."""
    cfg, eng = _engine(tiny_models, "dense", method="greedy")
    slots = [eng.new_session([1, 2, 3])[0]]
    eng.verify(_mk_items(cfg, slots, 4, 0))
    assert eng.stats["h2d_q_bytes"] == 0
    assert all("qlog" not in bufs for bufs in eng._pools.values())
    # and greedy ignores q even when the caller supplies it
    items = _mk_items(cfg, slots, 4, 1)
    eng.verify(items)
    assert eng.stats["h2d_q_bytes"] == 0


@pytest.mark.parametrize("backend", ["dense", "paged", "recurrent"])
def test_padded_batch_matches_solo(tiny_models, backend):
    """Pad rows come from the pooled buffers' reset state (OOB slot
    sentinel); an odd-sized batch (nb > n) must commit exactly what each
    item would alone."""
    cfg, _ = _engine(tiny_models, backend)
    prompts = [[2, 3, 4], [9, 8, 7], [5, 5, 6]]
    drafts = [np.random.default_rng(i).integers(0, cfg.vocab, size=3)
              .astype(np.int32) for i in range(3)]

    def outcomes(batched: bool):
        _, eng = _engine(tiny_models, backend, method="greedy")
        if batched:
            items = []
            for p, d in zip(prompts, drafts):
                slot, _ = eng.new_session(p)
                items.append(VerifyItem(slot=slot, draft_tokens=d,
                                        rng_tag=(slot, 0)))
            return [(o.accept_len, o.token) for o in eng.verify(items)]
        out = []
        for p, d in zip(prompts, drafts):
            slot, _ = eng.new_session(p)
            (o,) = eng.verify([VerifyItem(slot=slot, draft_tokens=d,
                                          rng_tag=(slot, 0))])
            out.append((o.accept_len, o.token))
        return out

    assert outcomes(batched=True) == outcomes(batched=False)


@pytest.mark.parametrize("backend", ["dense", "paged", "recurrent"])
@pytest.mark.parametrize("method", ["greedy", "residual"])
def test_mixed_k_batch_matches_solo(tiny_models, backend, method):
    """Ragged draft lengths in ONE fused batch (adaptive speculation,
    DESIGN.md §11): per-session controllers make every dispatch epoch a
    potential mixed-K batch.  Rows are padded to the bucketed max draft
    length with per-row ``dlen`` masks — each item must commit exactly
    what it would alone at its own K (where the pad bucket differs)."""
    cfg, _ = _engine(tiny_models, backend)
    ks = [1, 3, 5, 2]
    prompts = [[2, 3, 4], [9, 8, 7], [5, 5, 6], [4, 2, 9]]
    drafts, qlogs = [], []
    for i, k in enumerate(ks):
        g = np.random.default_rng(100 + i)
        drafts.append(g.integers(0, cfg.vocab, size=k).astype(np.int32))
        qlogs.append((g.normal(size=(k, cfg.vocab)) * 1.5)
                     .astype(np.float32))

    def _item(i, slot):
        it = VerifyItem(slot=slot, draft_tokens=drafts[i],
                        rng_tag=(slot, 0))
        if method == "residual":
            it.q_logits = qlogs[i]
        return it

    def outcomes(batched: bool):
        _, eng = _engine(tiny_models, backend, method=method)
        if batched:
            items = []
            for i, p in enumerate(prompts):
                slot, _ = eng.new_session(p)
                items.append(_item(i, slot))
            res = [(o.accept_len, o.token) for o in eng.verify(items)]
            assert eng.stats["mixed_k_batches"] == 1
            return res
        out = []
        for i, p in enumerate(prompts):
            slot, _ = eng.new_session(p)
            (o,) = eng.verify([_item(i, slot)])
            out.append((o.accept_len, o.token))
        return out

    assert outcomes(batched=True) == outcomes(batched=False)


# ---------------------------------------------------------------------------
# compact-q semantics
# ---------------------------------------------------------------------------


def _compact_batch(q_logits, draft):
    """Per-row CompactQ stack for (B, K, V) logits."""
    B, K, V = q_logits.shape
    qcs = [compact_from_logits(q_logits[b], draft[b], C=8) for b in range(B)]
    return stack_compact(qcs, B, K, 8)


@pytest.mark.parametrize("method", ["residual", "greedy"])
@pytest.mark.parametrize("tagged", [True, False])
def test_compact_accept_decisions_exact(method, tagged):
    """Accept lengths (and the greedy correction token) must be EXACTLY
    equal between the dense and compact representations — the accept test
    only reads log q at the drafted token, which CompactQ carries
    verbatim."""
    rng = np.random.default_rng(0)
    B, K, V = 4, 6, 64
    draft = rng.integers(0, V, size=(B, K)).astype(np.int32)
    dlen = rng.integers(1, K + 1, size=B).astype(np.int32)
    q = (rng.normal(size=(B, K, V)) * 2.0).astype(np.float32)
    p = (rng.normal(size=(B, K + 1, V)) * 2.0).astype(np.float32)
    tags = (np.stack([np.arange(B), np.arange(B) + 7], axis=1)
            .astype(np.int32) if tagged else None)
    lt, ti, tl, ta = _compact_batch(q, draft)
    kw = dict(method=method,
              rng_tags=None if tags is None else jnp.asarray(tags))
    a = speculative_verify(jax.random.PRNGKey(5), jnp.asarray(draft),
                           jnp.asarray(dlen), jnp.asarray(q),
                           jnp.asarray(p), **kw)
    b = speculative_verify_compact(
        jax.random.PRNGKey(5), jnp.asarray(draft), jnp.asarray(dlen),
        jnp.asarray(lt), jnp.asarray(ti), jnp.asarray(tl), jnp.asarray(ta),
        jnp.asarray(p), **kw)
    assert np.array_equal(a["accept_len"], b["accept_len"])
    assert np.array_equal(a["accept_mask"], b["accept_mask"])
    if method == "greedy":
        assert np.array_equal(a["token"], b["token"])


def test_compact_residual_within_documented_bound():
    """The compact residual correction distribution is within TV <=
    2·tail/Z of the exact one (DESIGN.md §9): top entries of q̂ are exact
    and at most ``tail`` mass is misplaced on each side, so the
    unnormalized residuals differ by <= 2·tail in L1, and Z normalizes.
    Checked analytically (mirroring the reconstruction) and empirically
    against many sampled corrections."""
    rng = np.random.default_rng(1)
    V, C = 32, 8
    # a peaked draft distribution: most mass inside the top-C
    q_logits = (rng.normal(size=(1, V)) * 3.0).astype(np.float32)
    p_logits = (rng.normal(size=(1, V + 0)) * 1.0).astype(np.float32)
    q = np.exp(q_logits[0] - np.log(np.exp(q_logits[0]).sum()))
    p = np.exp(p_logits[0] - np.log(np.exp(p_logits[0]).sum()))

    qc = compact_from_logits(q_logits, np.asarray([0], np.int32), C=C)
    tail = float(qc.tail[0])

    # analytic reconstruction (mirrors residual_qhat_compact)
    qhat = np.full(V, tail / (V - C))
    qhat[qc.top_idx[0]] = np.exp(qc.top_logq[0])
    exact = np.maximum(p - q, 0.0)
    approx = np.maximum(p - qhat, 0.0)
    Z = exact.sum()
    assert Z > 0
    tv = 0.5 * np.abs(exact / Z - approx / approx.sum()).sum()
    bound = 2 * tail / Z
    assert tv <= bound + 1e-6, f"TV {tv:.4f} exceeds bound {bound:.4f}"

    # empirical: force a near-certain rejection at position 0 (draft token
    # with minimal p, log q pinned to 0 => accept prob = p(y) ~ 0) and
    # sample many corrections through the compact kernel via rng_tags
    trials = 4000
    draft = np.full((trials, 1), int(np.argmin(p)), np.int32)
    dlen = np.ones(trials, np.int32)
    tags = np.stack([np.arange(trials), np.zeros(trials)], axis=1) \
        .astype(np.int32)
    lt = np.broadcast_to(
        np.log(q)[draft[0, 0]].astype(np.float32), (trials, 1)).copy()
    # accept test must reject: give it logq >> logp at the draft token
    lt[:] = 0.0          # log q = 0 => accept prob ~ p(y) -> near-certain reject
    ti = np.broadcast_to(qc.top_idx, (trials, 1, C)).copy()
    tl2 = np.broadcast_to(qc.top_logq, (trials, 1, C)).copy()
    ta = np.broadcast_to(qc.tail[None, :], (trials, 1)).copy()
    out = speculative_verify_compact(
        jax.random.PRNGKey(2), jnp.asarray(draft), jnp.asarray(dlen),
        jnp.asarray(lt), jnp.asarray(ti), jnp.asarray(tl2), jnp.asarray(ta),
        jnp.asarray(np.broadcast_to(
            p_logits[None], (trials, 2, V)).copy().astype(np.float32)),
        method="residual", rng_tags=jnp.asarray(tags),
    )
    rejected = np.asarray(out["accept_len"]) == 0
    toks = np.asarray(out["token"])[rejected]
    assert rejected.mean() > 0.9
    emp = np.bincount(toks, minlength=V) / len(toks)
    want = approx / approx.sum()
    tv_emp = 0.5 * np.abs(emp - want).sum()
    assert tv_emp < 0.06, f"empirical TV {tv_emp:.3f} vs compact residual"


def test_mixed_c_batch_pads_do_not_clobber_token_zero():
    """Regression: a batch bucket wider than some block's own C pads the
    unused table columns — the pad id must be OUT of vocab (dropped by the
    scatter), or token 0's real top entry gets non-deterministically
    overwritten during q̂ reconstruction."""
    V = 64
    # a q distribution whose top-1 IS token 0, carrying most of the mass
    q_logits = np.zeros((1, V), np.float32)
    q_logits[0, 0] = 6.0
    qc = compact_from_logits(q_logits, np.asarray([1], np.int32), C=4)
    assert 0 in qc.top_idx[0]
    # stack into a WIDER bucket (C=8): columns 4..8 are pads
    lt, ti, tl, ta = stack_compact([qc], 1, 1, 8)
    from repro.core.speculative import residual_qhat_compact
    qhat = np.asarray(residual_qhat_compact(
        jnp.asarray(ti), jnp.asarray(tl), jnp.asarray(ta),
        jnp.asarray([0], jnp.int32), V,
    ))[0]
    q0 = float(np.exp(qc.top_logq[0][qc.top_idx[0] == 0][0]))
    assert qhat[0] == pytest.approx(q0, rel=1e-6), (
        "pad columns clobbered token 0's reconstructed mass"
    )


def test_run_serving_rejects_none_q_with_residual():
    """q_mode='none' ships no q statistics at all, which only a greedy
    verifier can consume — a residual verifier would silently test
    against the staging buffers' uniform fill."""
    from repro.launch.serve import run_serving

    with pytest.raises(ValueError, match="q_mode"):
        run_serving(devices=1, rounds=1, verbose=False, q_mode="none")


def test_compact_refuses_non_unit_temperature():
    """CompactQ statistics are built at temperature 1.0; verifying them at
    another temperature would compare p^(1/T) against unscaled q, so the
    compact path must refuse instead of silently biasing the accept test."""
    B, K, V, C = 1, 2, 16, 4
    z = jnp.zeros
    with pytest.raises(ValueError, match="temperature"):
        speculative_verify_compact(
            jax.random.PRNGKey(0), z((B, K), jnp.int32),
            jnp.ones((B,), jnp.int32), z((B, K)), z((B, K, C), jnp.int32),
            z((B, K, C)), z((B, K)), z((B, K + 1, V)),
            method="residual", temperature=0.5,
        )


def test_engine_compact_matches_dense_accepts(tiny_models):
    """Engine-level: the same drafts verified with dense vs compact q
    commit identical accept lengths (accept test exact); greedy streams
    are identical outright."""
    cfg, _ = tiny_models["attention"]
    for method in ("residual", "greedy"):
        outs = {}
        for q in ("dense", "compact"):
            _, eng = _engine(tiny_models, "paged", method=method)
            slots = [eng.new_session([1, 2, 3 + i])[0] for i in range(2)]
            got = []
            for r in range(3):
                for o in eng.verify(_mk_items(cfg, slots, 4, r, q=q)):
                    got.append((o.slot, o.accept_len)
                               + ((o.token,) if method == "greedy" else ()))
            outs[q] = got
        assert outs["dense"] == outs["compact"]


def test_compact_wire_bytes_and_transport():
    """Uplink accounting prices the actual representation: ids-only <
    compact table < modelled dense top-k at the default widths."""
    net = NetworkModel()
    qc = CompactQ(
        logq_tok=np.zeros(4, np.float32),
        top_idx=np.zeros((4, 16), np.int32),
        top_logq=np.zeros((4, 16), np.float32),
        tail=np.zeros(4, np.float32),
    )
    greedy = net.uplink_bytes(4, None)
    compact = net.uplink_bytes(4, qc)
    dense = net.uplink_bytes(4)
    assert greedy < compact < dense
    assert compact == 64 + 4 * 4 + qc.wire_bytes()
    # legacy call sites (no q argument) are unchanged
    assert dense == 64 + 4 * (4 + net.q_topk * 6)
