"""Multi-tenant serving subsystem (DESIGN.md §13): two-stage token-bucket
admission, per-tenant budgets, the "wfq" weighted-fair policy, and the
end-to-end guarantees —

  * the bucket never admits above sustained rate + burst (+ the bounded
    deprioritization debt), under ANY decision sequence;
  * a single decision's stage is monotone in its cost;
  * WFQ splits a saturated verifier by tenant weight, and aging bounds
    how long any item can starve;
  * throttled opens/blocks release deterministically once the bucket
    refills; sheds surface as typed REJECTED events;
  * with unlimited default buckets the subsystem is inert: the golden
    ``tenant/*`` cells replay byte-identical to the untagged wisp
    baseline;
  * killing a verifier mid-run with tenants attached preserves both the
    per-tenant accounting and every committed stream byte.

Property tests run under ``hypothesis`` when installed and collect as
skipped via `_hypothesis_stub` otherwise.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.cluster import (
    ClusterConfig,
    ClusterRuntime,
    TenantWorkload,
    build_fleet,
    build_tenant_registry,
)
from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    SchedulerConfig,
    VerifyRequest,
    make_policy,
)
from repro.fleet import FleetRuntime, build_verifier_fleet
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel
from repro.tenancy import (
    Stage,
    TenantRegistry,
    TenantSpec,
    TokenBucket,
)

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


# ---------------------------------------------------------------------------
# token bucket (pure)
# ---------------------------------------------------------------------------


def test_token_bucket_two_stage_ladder():
    b = TokenBucket(rate=10.0, burst=8.0)          # debt defaults to burst
    assert b.decide(4.0, now=0.0) == Stage.ADMIT   # level 8 -> 4
    assert b.decide(6.0, now=0.0) == Stage.DEPRIORITIZE  # 4 -> -2 (debt band)
    lvl = b.level
    assert b.decide(10.0, now=0.0) == Stage.QUEUE  # would bust the debt
    assert b.level == lvl                          # QUEUE never charges
    # refill at 10 tok/s: by t=2 the bucket is back at burst
    assert b.decide(6.0, now=2.0) == Stage.ADMIT


def test_unlimited_bucket_always_admits_without_charge():
    b = TokenBucket(rate=None)
    for cost in (1.0, 1e6):
        assert b.decide(cost, now=0.0) == Stage.ADMIT
    assert b.level == b.burst


@settings(max_examples=60, deadline=None)
@given(
    rate=st.floats(0.5, 50.0),
    burst=st.floats(1.0, 64.0),
    ops=st.lists(
        st.tuples(st.floats(0.0, 2.0), st.floats(0.1, 32.0)),
        min_size=1, max_size=40,
    ),
)
def test_bucket_never_admits_above_rate_plus_burst(rate, burst, ops):
    """Sum of charged (ADMIT + DEPRIORITIZE) tokens over any window is
    bounded by burst + debt + rate * elapsed: the contract that makes a
    flood tenant's share enforceable at all."""
    b = TokenBucket(rate=rate, burst=burst)
    now, charged = 0.0, 0.0
    for dt, cost in ops:
        now += dt
        stage = b.decide(cost, now=now)
        if stage in (Stage.ADMIT, Stage.DEPRIORITIZE):
            charged += cost
        assert b.level >= -b.deprioritize_debt - 1e-9
    assert charged <= 2 * burst + rate * now + 1e-6   # debt == burst here


@settings(max_examples=60, deadline=None)
@given(
    warmup=st.lists(st.floats(0.1, 16.0), min_size=0, max_size=10),
    c1=st.floats(0.1, 64.0),
    extra=st.floats(0.0, 64.0),
)
def test_bucket_stage_monotone_in_cost(warmup, c1, extra):
    """From any reachable bucket state, a costlier request never gets a
    BETTER stage (the arrival-rate monotonicity of the two-stage design:
    pushing harder can only move a tenant down the ladder)."""
    b1 = TokenBucket(rate=5.0, burst=16.0)
    b2 = TokenBucket(rate=5.0, burst=16.0)
    for cost in warmup:                  # identical history -> same state
        b1.decide(cost, now=0.0)
        b2.decide(cost, now=0.0)
    assert b1.decide(c1, now=0.0) <= b2.decide(c1 + extra, now=0.0)


# ---------------------------------------------------------------------------
# registry + budgets (pure)
# ---------------------------------------------------------------------------


def test_registry_unknown_tenant_lists_names():
    reg = TenantRegistry([TenantSpec("alpha"), TenantSpec("beta")])
    with pytest.raises(ValueError, match=r"alpha.*beta.*default"):
        reg.get("nope")
    assert reg.names() == ["alpha", "beta", "default"]
    assert "alpha" in reg and "nope" not in reg


def test_tenant_spec_parse_and_validation():
    s = TenantSpec.parse("flood:weight=1.5:rate=600:burst=128:conc=4:queued=2")
    assert (s.tenant, s.weight, s.rate_tokens_per_s) == ("flood", 1.5, 600.0)
    assert (s.burst_tokens, s.max_concurrency, s.max_queued) == (128.0, 4, 2)
    with pytest.raises(ValueError, match="known.*fields"):
        TenantSpec.parse("flood:turbo=9")
    with pytest.raises(ValueError, match="needs a name"):
        TenantSpec.parse(":weight=2")
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(tenant="bad", weight=0.0)


def test_admit_session_reject_queue_and_budget_order():
    reg = TenantRegistry([TenantSpec(
        "t", rate_tokens_per_s=1.0, burst_tokens=4.0,
        max_concurrency=1, max_queued=2,
    )])
    st_ = reg.get("t")
    # backlog at max_queued sheds BEFORE any budget or bucket check
    assert reg.admit_session("t", 2.0, 0.0, queued=2) == Stage.REJECT
    assert st_.bucket.level == st_.bucket.burst      # REJECT never charges
    # concurrency budget queues before the bucket is touched
    st_.live_sessions = 1
    assert reg.admit_session("t", 2.0, 0.0) == Stage.QUEUE
    assert st_.bucket.level == st_.bucket.burst
    st_.live_sessions = 0
    assert reg.admit_session("t", 2.0, 0.0) == Stage.ADMIT


def test_admit_block_clamps_to_queue_and_tracks_inflight():
    reg = TenantRegistry([TenantSpec(
        "t", rate_tokens_per_s=1.0, burst_tokens=8.0,
        max_tokens_in_flight=10, max_queued=0,
    )])
    st_ = reg.get("t")
    # tokens-in-flight budget holds the block (never REJECT for streams)
    st_.tokens_in_flight = 9
    assert reg.admit_block("t", 4.0, 0.0) == Stage.QUEUE
    st_.tokens_in_flight = 0
    assert reg.admit_block("t", 4.0, 0.0) == Stage.ADMIT
    # drain the bucket past the debt band: still QUEUE, never REJECT
    for _ in range(8):
        stage = reg.admit_block("t", 4.0, 0.0)
        assert stage <= Stage.QUEUE


def test_unknown_work_kind_lists_registered():
    with pytest.raises(ValueError, match=r"unknown work kind.*registered"):
        VerifyRequest(req_id=0, session_id=0, slo_class=0, arrival=0.0,
                      deadline=1.0, kind="nope")


# ---------------------------------------------------------------------------
# WFQ policy (pure)
# ---------------------------------------------------------------------------


def _witem(i, tenant, weight, *, draft=8, cached=64, enq=0.0, deprio=False):
    return VerifyRequest(
        req_id=i, session_id=i, slo_class=0, arrival=enq, deadline=1e9,
        draft_len=draft, cached_len=cached, alpha=0.8, enqueued_at=enq,
        tenant=tenant, tenant_weight=weight, deprioritized=deprio,
    )


def test_wfq_splits_saturated_service_by_weight():
    """Both tenants permanently backlogged, batch cap 2: served items
    track the 3:1 weight ratio, not the 1:1 arrival ratio."""
    pol = make_policy("wfq", SchedulerConfig(max_batch_requests=2), COEFFS)
    served = {"heavy": 0, "light": 0}
    rid = 0
    pending = []
    t = 0.0
    # epochs are densely spaced so aging credit stays negligible next to
    # the vfinish gap — this isolates the weight term (aging is pinned by
    # test_wfq_aging_bounds_starvation below)
    for epoch in range(40):
        while sum(r.tenant == "heavy" for r in pending) < 3:
            pending.append(_witem(rid, "heavy", 3.0, cached=448, enq=t))
            rid += 1
        while sum(r.tenant == "light" for r in pending) < 3:
            pending.append(_witem(rid, "light", 1.0, cached=448, enq=t))
            rid += 1
        d = pol.schedule(pending, t)
        for r in d.batch:
            served[r.tenant] += 1
            pending.remove(r)
        t += 0.0005
    assert served["heavy"] > 0 and served["light"] > 0
    assert served["heavy"] >= 2 * served["light"], served


def test_wfq_aging_bounds_starvation():
    """A tiny-weight victim item against a continuously replenished
    heavy flood, batch cap 1: linear aging must get it served within a
    bounded number of epochs anyway."""
    pol = make_policy("wfq", SchedulerConfig(max_batch_requests=1), COEFFS)
    victim = _witem(0, "victim", 0.05, enq=0.0)
    pending = [victim]
    rid, t, served_at = 1, 0.0, None
    for epoch in range(200):
        while len(pending) < 4:
            pending.append(_witem(rid, "flood", 8.0, enq=t)); rid += 1
        d = pol.schedule(pending, t)
        assert len(d.batch) == 1
        r = d.batch[0]
        pending.remove(r)
        if r.req_id == 0:
            served_at = epoch
            break
        t += 0.05
    assert served_at is not None, "aging failed to bound the victim's wait"


def test_wfq_deprioritized_items_yield():
    """Two same-weight tenants, one flagged deprioritized (rate-limiter
    debt band): the clean tenant is served first."""
    pol = make_policy("wfq", SchedulerConfig(max_batch_requests=1), COEFFS)
    pending = [
        _witem(0, "debtor", 1.0, deprio=True),
        _witem(1, "clean", 1.0),
    ]
    d = pol.schedule(pending, 0.0)
    assert [r.req_id for r in d.batch] == [1]


# ---------------------------------------------------------------------------
# server integration (reduced dense model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_pair():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = bundle.init(jax.random.PRNGKey(0))
    dparams = bundle.init(jax.random.PRNGKey(1))
    return cfg, tparams, dparams


def _server(cfg, tparams, tenants, *, policy="wfq", max_slots=4):
    eng = VerificationEngine(cfg, tparams, max_slots=max_slots, max_len=128,
                             method="residual", seed=7)
    return WISPServer(eng, COEFFS, policy=policy, network=NetworkModel(),
                      tenants=tenants)


def test_throttled_open_queues_then_admits(dense_pair):
    cfg, tparams, _ = dense_pair
    srv = _server(cfg, tparams,
                  [TenantSpec("slow", rate_tokens_per_s=1.0, burst_tokens=8.0)])
    prompt = [1, 2, 3, 4, 5, 6]
    srv.open_session(0, prompt, slo_class=2, now=0.0, tenant="slow")  # ADMIT
    srv.open_session(1, prompt, slo_class=2, now=0.0, tenant="slow")  # DEPRIO
    srv.open_session(2, prompt, slo_class=2, now=0.0, tenant="slow")  # QUEUE
    kinds = [(ev.kind, ev.session_id) for ev in srv.pop_events()]
    assert ("THROTTLED", 2) in kinds
    assert srv.session_state(2) == "queued"
    assert 2 in srv.throttled_session_ids()
    assert srv.throttle_backlog == 1
    assert srv.tenants.get("slow").live_sessions == 2   # held opens not live
    # bucket refills at 1 tok/s: by t=20 the held open releases
    srv.step(20.0)
    admitted = [ev for ev in srv.pop_events()
                if ev.kind == "ADMITTED" and ev.session_id == 2]
    assert admitted and srv.session_state(2) == "active"
    assert srv.throttle_backlog == 0
    assert srv.tenants.get("slow").live_sessions == 3


def test_rejected_open_sheds_with_typed_event(dense_pair):
    cfg, tparams, _ = dense_pair
    srv = _server(cfg, tparams,
                  [TenantSpec("strict", rate_tokens_per_s=0.5,
                              burst_tokens=2.0, max_queued=0)])
    srv.open_session(7, [1, 2, 3, 4, 5, 6], slo_class=2, now=0.0,
                     tenant="strict")
    evs = srv.pop_events()
    assert [ev.kind for ev in evs] == ["REJECTED"]
    assert evs[0].tenant == "strict"
    assert srv.session_state(7) == "rejected"
    assert srv.tenants.get("strict").rejected == 1
    srv.close_session(7)                    # rejected sids close cleanly
    assert srv.session_state(7) == "closed"


def test_throttled_block_holds_then_verifies(dense_pair):
    cfg, tparams, _ = dense_pair
    srv = _server(cfg, tparams,
                  [TenantSpec("slow", rate_tokens_per_s=1.0, burst_tokens=8.0)])
    srv.open_session(0, [1, 2, 3, 4, 5, 6], slo_class=2, now=0.0,
                     tenant="slow")        # ADMIT: level 8 -> 2
    srv.pop_events()
    toks = list(range(2, 13))              # 11 tokens: 2-11 = -9 < -8 debt
    qlog = (np.random.default_rng(0)
            .normal(size=(len(toks), cfg.vocab)) * 1.5).astype(np.float32)
    srv.submit(0, np.array(toks, dtype=np.int32), qlog, now=0.0,
               t_draft=0.01, t_network=0.005)
    st_ = srv.tenants.get("slow")
    assert srv.throttle_backlog == 1 and srv.queue_depth == 0
    assert st_.tokens_in_flight == 0       # held blocks are not in flight
    held = [ev for ev in srv.pop_events() if ev.kind == "THROTTLED"]
    assert held and held[0].scope == "submit"
    verdicts = srv.step(20.0)              # refilled: releases + verifies
    assert [v.session_id for v in verdicts] == [0]
    assert st_.tokens_in_flight == 0       # charged on release, refunded
    assert st_.submitted_tokens == len(toks)
    assert st_.committed_tokens >= 1


def test_server_unknown_tenant_and_slo_class_errors(dense_pair):
    cfg, tparams, _ = dense_pair
    srv = _server(cfg, tparams, [TenantSpec("alpha")])
    with pytest.raises(ValueError, match=r"unknown tenant.*alpha"):
        srv.open_session(0, [1, 2, 3], now=0.0, tenant="nope")
    with pytest.raises(ValueError, match=r"unknown SLO class.*known"):
        srv.open_session(0, [1, 2, 3], slo_class=99, now=0.0)


def test_golden_tenant_cell_matches_untagged_baseline():
    """The no-contention guarantee, end to end: the tenant-tagged wfq
    scenario replays byte-identical to BOTH its stored golden cell and
    the untagged dense/wisp/monolithic baseline cell."""
    import json
    import os

    from _golden_scenario import GOLDEN_PATH, run_tenant_scenario

    if not os.path.exists(GOLDEN_PATH):
        pytest.skip("golden streams not generated")
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    streams = run_tenant_scenario()
    assert streams == golden["tenant/wfq"]
    assert streams == golden["dense/wisp/monolithic"]


# ---------------------------------------------------------------------------
# fleet chaos x tenancy
# ---------------------------------------------------------------------------

TENANT_CHAOS = dict(
    rounds=3, k_max=4, max_len=256, seed=0,
    prefill_mode="chunked", prefill_chunk_tokens=16,
    tenant_workloads=(
        TenantWorkload("victim", devices=2, weight=2.0),
        TenantWorkload("flood", devices=2, weight=1.0),
    ),
)


def _edges(cfg, dparams, ccfg, fleet):
    return [
        EdgeDevice(cfg, dparams, k_max=ccfg.k_max, max_len=ccfg.max_len,
                   seed=100 + sp.idx, draft_speed=sp.draft_speed)
        for sp in fleet
    ]


def test_chaos_verifier_kill_preserves_tenant_accounting(dense_pair):
    """Kill one of three verifiers mid-run with tenants attached
    (unlimited buckets, "wfq" policy): every committed stream — the
    victim tenant's included — stays byte-identical to the
    single-verifier run, and the SHARED tenant registry's accounting
    survives the migrations (net-zero live sessions, per-tenant commits
    intact)."""
    cfg, tparams, dparams = dense_pair

    # single-verifier reference
    ccfg = ClusterConfig(**TENANT_CHAOS)
    fleet = build_fleet(ccfg, cfg.vocab)
    assert [sp.tenant for sp in fleet] == ["victim"] * 2 + ["flood"] * 2
    reg1 = build_tenant_registry(ccfg)
    eng = VerificationEngine(cfg, tparams, max_slots=len(fleet),
                             max_len=ccfg.max_len)
    server = WISPServer(eng, COEFFS, policy="wfq", network=NetworkModel(),
                        prefill="chunked",
                        prefill_chunk_tokens=ccfg.prefill_chunk_tokens,
                        tenants=reg1)
    edges = _edges(cfg, dparams, ccfg, fleet)
    ClusterRuntime(server, edges, fleet, ccfg, vocab=cfg.vocab).run()
    golden = [list(d.response_tokens) for d in edges]

    # 3-verifier fleet, verifier 0 killed mid-run
    ccfg = ClusterConfig(**TENANT_CHAOS, verifiers=3,
                         fail_at=((0, 0.15, None),))
    fleet = build_fleet(ccfg, cfg.vocab)
    registry = build_tenant_registry(ccfg)
    router = build_verifier_fleet(
        cfg, tparams, ccfg.verifiers, COEFFS, max_slots=len(fleet),
        max_len=ccfg.max_len, policy="wfq", network=NetworkModel(),
        prefill="chunked", prefill_chunk_tokens=ccfg.prefill_chunk_tokens,
        heartbeat_timeout=ccfg.heartbeat_timeout,
        tenants=registry,
    )
    edges = _edges(cfg, dparams, ccfg, fleet)
    result = FleetRuntime(router, edges, fleet, ccfg, vocab=cfg.vocab).run()
    streams = [list(d.response_tokens) for d in edges]

    assert router.stats["verifier_downs"] == 1
    assert streams == golden                       # tenancy never perturbs
    snap = registry.snapshot()
    for name in ("victim", "flood"):
        assert snap[name]["live_sessions"] == 0    # net-zero across kill
        assert snap[name]["tokens_in_flight"] == 0
        assert snap[name]["committed_tokens"] > 0
        assert snap[name]["rejected"] == 0
    per_tenant = result.metrics.per_tenant(result.horizon)
    assert per_tenant["victim"]["sessions"] == 2
    assert per_tenant["flood"]["sessions"] == 2
    assert all(r.tenant in ("victim", "flood")
               for r in result.metrics.sessions)
