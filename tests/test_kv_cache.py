"""PageAllocator / PagedKV invariants: refcounts, prefix index accounting,
chained-hash sharing, exhaustion, and no-double-allocation — property-based
where hypothesis is available, example-based otherwise."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.serving.kv_cache import OutOfPages, PageAllocator, PagedKV


# ---------------------------------------------------------------------------
# PageAllocator
# ---------------------------------------------------------------------------


def test_out_of_pages_exactly_at_exhaustion():
    a = PageAllocator(4, page_size=8)
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [0, 1, 2, 3]          # every page handed out once
    with pytest.raises(OutOfPages):
        a.alloc()
    a.release(got[0])
    assert a.alloc() == got[0]                   # freeing reopens exactly one
    with pytest.raises(OutOfPages):
        a.alloc()


def test_no_double_allocation_under_churn():
    rng = np.random.default_rng(0)
    a = PageAllocator(8, page_size=8)
    live = set()
    for _ in range(500):
        if live and rng.random() < 0.45:
            pid = live.pop()
            a.release(pid)
        else:
            try:
                pid = a.alloc()
            except OutOfPages:
                continue
            assert pid not in live, "page handed out twice"
            live.add(pid)
        assert a.in_use == len(live)
    for pid in live:
        assert a.refcount[pid] == 1


def test_refcount_share_release_cycle():
    a = PageAllocator(2, page_size=8)
    pid = a.alloc()
    a.retain(pid)
    a.retain(pid)
    assert a.refcount[pid] == 3
    a.release(pid)
    a.release(pid)
    assert a.refcount[pid] == 1 and pid not in [p for p in a.free]
    a.release(pid)
    assert a.refcount[pid] == 0 and pid in a.free


def test_prefix_index_hit_miss_accounting():
    a = PageAllocator(8, page_size=4)
    toks = list(range(11))                       # 2 full pages + 3 tail
    pages = [a.alloc(), a.alloc(), a.alloc()]
    a.publish_prefix(toks, pages)
    # only full pages are indexed
    assert len(a.prefix_index) == 2

    hit_pages, n = a.lookup_prefix(toks)
    assert hit_pages == pages[:2] and n == 8
    assert (a.hits, a.misses) == (1, 0)
    assert a.refcount[pages[0]] == 2             # lookup retains

    miss_pages, n = a.lookup_prefix([99, 98, 97, 96])
    assert miss_pages == [] and n == 0
    assert (a.hits, a.misses) == (1, 1)


def test_chained_hash_shares_identical_prefixes_only():
    """Prefixes equal through page k share exactly k pages: the chained
    hash makes page k+1's identity depend on everything before it."""
    a = PageAllocator(16, page_size=4)
    base = [1, 2, 3, 4, 5, 6, 7, 8]
    pages = [a.alloc(), a.alloc()]
    a.publish_prefix(base, pages)

    same_first = [1, 2, 3, 4, 9, 9, 9, 9]
    got, n = a.lookup_prefix(same_first)
    assert got == pages[:1] and n == 4

    # same page-2 CONTENT but different page 1: chained hash must miss
    diff_first = [9, 9, 9, 9, 5, 6, 7, 8]
    got, n = a.lookup_prefix(diff_first)
    assert got == [] and n == 0


def test_cached_pages_evicted_lazily_on_exhaustion():
    a = PageAllocator(2, page_size=4)
    toks = [1, 2, 3, 4]
    pid = a.alloc()
    a.publish_prefix(toks, [pid])
    a.release(pid)                               # resident, refcount 0
    assert pid not in a.free and a.available == 2
    # exhaustion evicts the unreferenced cached page instead of failing
    got = [a.alloc(), a.alloc()]
    assert sorted(got) == [0, 1]
    assert not a.prefix_index                    # index entry dropped


def test_eviction_is_lru_by_last_touch_not_dict_order():
    """Regression: ``_evict_unreferenced`` used to walk ``page_hash`` in
    dict-insertion order, so eviction (and tier-spill victim selection)
    depended on publication history rather than recency.  It must evict
    strictly by last-touch epoch (page id as the tie-break), one page per
    ``need`` — a hot prefix entry survives pressure longer than a cold one."""
    a = PageAllocator(4, page_size=4)
    pids = [a.alloc() for _ in range(4)]
    for i, pid in enumerate(pids[:3]):
        a.publish_prefix([10 + i] * 4, [pid])
        a.release(pid)                           # resident, refcount 0
    # touch in NON-insertion order: recency is now 0 < 2 < 1
    a.tick()
    a.touch(pids[0])
    a.tick()
    a.touch(pids[2])
    a.tick()
    a.touch(pids[1])
    # each exhausted alloc evicts exactly the least-recently-touched page
    got = [a.alloc() for _ in range(3)]
    assert got == [pids[0], pids[2], pids[1]], (
        "eviction followed insertion order, not last-touch LRU"
    )
    assert not a.prefix_index and not a.page_hash


def test_eviction_never_takes_referenced_pages():
    """A prefix-reachable page with refcount >= 1 (shared or live) is
    pinned: exhaustion evicts only unreferenced cached pages, and raises
    once none remain."""
    a = PageAllocator(2, page_size=4)
    hot = a.alloc()
    a.publish_prefix([1, 2, 3, 4], [hot])        # published AND referenced
    cold = a.alloc()
    a.publish_prefix([5, 6, 7, 8], [cold])
    a.release(cold)                              # only eviction candidate
    assert a.alloc() == cold
    with pytest.raises(OutOfPages):
        a.alloc()                                # hot page stays pinned
    assert a.page_hash.get(hot) is not None


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.integers(0, 2), min_size=1, max_size=200),
       n_pages=st.integers(1, 6))
def test_allocator_invariants_random_ops(ops, n_pages):
    """Under any alloc/retain/release interleaving: refcounts stay >= 0,
    free pages have refcount 0, and live + free + resident == n_pages."""
    rng = np.random.default_rng(0)
    a = PageAllocator(n_pages, page_size=4)
    live = []
    for op in ops:
        if op == 0:
            try:
                live.append(a.alloc())
            except OutOfPages:
                pass
        elif op == 1 and live:
            a.retain(live[rng.integers(len(live))])
        elif op == 2 and live:
            pid = live.pop(rng.integers(len(live)))
            a.release(pid)
        assert (a.refcount >= 0).all()
        for pid in a.free:
            assert a.refcount[pid] == 0
        assert len(set(a.free)) == len(a.free)   # free list has no dupes
        assert set(live) <= set(range(n_pages)) - set(a.free)


# ---------------------------------------------------------------------------
# PagedKV
# ---------------------------------------------------------------------------


def _mk_kv(n_pages=8, page_size=4):
    return PagedKV(2, n_pages, 2, 4, page_size=page_size, dtype=jnp.float32)


def test_scratch_page_reserved():
    kv = _mk_kv()
    assert kv.scratch_page == 0
    assert 0 not in kv.allocator.free
    kv.open_seq(1, [1, 2, 3])
    kv.ensure_capacity(1, 3)
    assert 0 not in kv.tables[1].pages           # never handed to sequences


def test_open_close_releases_pages():
    kv = _mk_kv()
    kv.open_seq(7, [1, 2, 3, 4, 5])
    kv.ensure_capacity(7, 5)
    assert kv.seq_pages(7) == 2
    used = kv.allocator.in_use
    kv.close_seq(7)
    assert kv.allocator.in_use == used - 2
    assert 7 not in kv.tables


def test_trim_seq_releases_rejected_tail():
    kv = _mk_kv()
    kv.open_seq(1, [1, 2, 3])
    kv.ensure_capacity(1, 11)                    # speculate deep: 3 pages
    assert kv.seq_pages(1) == 3
    kv.set_len(1, 5)                             # only 5 tokens survived
    kv.trim_seq(1)
    assert kv.seq_pages(1) == 2                  # page 3 was unreachable
    kv.set_len(1, 8)
    kv.trim_seq(1)
    assert kv.seq_pages(1) == 2                  # boundary: page 2 full, kept


def test_prefix_sharing_shares_pages_and_refcounts():
    kv = _mk_kv()
    prompt = list(range(10))                     # 2 full pages + 2 tail
    kv.open_seq(1, prompt)
    kv.ensure_capacity(1, 10)
    kv.publish_seq_prefix(1, prompt)

    n_cached = kv.open_seq(2, prompt)
    assert n_cached == 8
    p1, p2 = kv.tables[1].pages, kv.tables[2].pages
    assert p1[:2] == p2[:2]                      # physical sharing
    for pid in p1[:2]:
        assert kv.allocator.refcount[pid] == 2
    kv.ensure_capacity(2, 10)
    assert p2[2] != p1[2]                        # tails stay private

    kv.close_seq(1)
    for pid in p2[:2]:
        assert kv.allocator.refcount[pid] == 1


def test_full_page_aligned_prompt_keeps_one_page_to_recompute():
    """A fully-cached, page-aligned prompt must give back its last cached
    page: prefill logits for the final position have to be recomputed and
    may only be written to pages the new sequence owns."""
    kv = _mk_kv()
    prompt = list(range(8))                      # exactly 2 pages
    kv.open_seq(1, prompt)
    kv.ensure_capacity(1, 8)
    kv.publish_seq_prefix(1, prompt)
    n_cached = kv.open_seq(2, prompt)
    assert n_cached == 4                         # last page recomputed
    assert len(kv.tables[2].pages) == 1


def test_free_tokens_accounting():
    kv = _mk_kv(n_pages=8, page_size=4)          # 7 usable after scratch
    assert kv.free_tokens == 7 * 4
    kv.open_seq(1, [1, 2, 3])
    kv.ensure_capacity(1, 6)
    assert kv.free_tokens == 5 * 4
    assert kv.resident_tokens() == 2 * 4
    kv.close_seq(1)
    assert kv.free_tokens == 7 * 4


def test_write_and_gather_dense_roundtrip():
    kv = _mk_kv(n_pages=8, page_size=4)
    kv.open_seq(1, [0])
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 6, 2, 4)).astype(np.float32)
    v = rng.normal(size=(2, 6, 2, 4)).astype(np.float32)
    kv.write_tokens(1, 0, jnp.asarray(k), jnp.asarray(v))
    kv.set_len(1, 6)
    kd, vd = kv.gather_dense(1, 6)
    np.testing.assert_allclose(np.asarray(kd), k, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vd), v, atol=1e-6)
