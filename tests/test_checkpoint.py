"""Sharded checkpointing: roundtrip, atomic commit, GC, async save,
elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree():
    return {
        "params": {
            "w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "blocks": [jnp.ones((2, 2), jnp.float32), jnp.zeros((5,), jnp.int32)],
        },
        "step": jnp.asarray(7, jnp.int32),
    }


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = {jax.tree_util.keystr(p): v for p, v in jax.tree_util.tree_leaves_with_path(b)}
    for p, va in la:
        vb = lb[jax.tree_util.keystr(p)]
        np.testing.assert_array_equal(
            np.asarray(va, np.float32), np.asarray(vb, np.float32)
        )
        assert np.asarray(va).dtype == np.asarray(vb).dtype


def test_roundtrip_with_bfloat16(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree)
    out, meta = restore_checkpoint(str(tmp_path))
    _assert_tree_equal(tree, out)


def test_latest_step_and_meta(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(), meta={"arch": "x"})
    save_checkpoint(str(tmp_path), 5, _tree(), meta={"arch": "y"})
    assert latest_step(str(tmp_path)) == 5
    _, meta = restore_checkpoint(str(tmp_path))
    assert meta["arch"] == "y"


def test_uncommitted_staging_ignored(tmp_path):
    """A crash mid-save (staging dir without manifest rename) must be
    invisible to restore."""
    save_checkpoint(str(tmp_path), 2, _tree())
    # simulate a crashed save: orphan staging directory
    os.makedirs(tmp_path / "step_00000009.tmp-abc")
    assert latest_step(str(tmp_path)) == 2
    out, _ = restore_checkpoint(str(tmp_path))
    _assert_tree_equal(_tree(), out)


def test_corrupt_latest_falls_back_explicitly(tmp_path):
    """A step dir without manifest.json is not 'committed'."""
    save_checkpoint(str(tmp_path), 2, _tree())
    os.makedirs(tmp_path / "step_00000004")
    assert latest_step(str(tmp_path)) == 2


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), meta={"step": s}, blocking=(s % 2 == 0))
    mgr.wait()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore with explicit shardings re-lays the arrays on the current
    mesh (single device here; the mechanism is mesh-size independent)."""
    from repro.launch.mesh import make_test_mesh

    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = make_test_mesh(1, 1)
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        tree,
    )
    out, _ = restore_checkpoint(str(tmp_path), shardings=sh)
    _assert_tree_equal(tree, out)
    for leaf in jax.tree.leaves(out):
        assert isinstance(leaf, jax.Array)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"))
