import os

# keep the CPU quiet and deterministic for tests (NOT 512 fake devices —
# only the dry-run sets xla_force_host_platform_device_count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
