"""Simulator behaviour: system ordering, load monotonicity, attribution,
capacity search."""
import numpy as np
import pytest

from repro.sim import (
    capacity_search,
    centralized,
    simulate,
    sled,
    wisp,
)
from repro.sim.acceptance import AcceptanceModel, PredictorOperatingPoint
from repro.sim.systems import fcfs_cached, variant


def test_violations_increase_with_load():
    rates = [simulate(sled(n, sim_time=40.0)).violation_rate()
             for n in (8, 64, 256)]
    assert rates[0] <= rates[1] <= rates[2]


def test_wisp_beats_sled_and_centralized_under_load():
    N = 128
    w = simulate(wisp(N, sim_time=40.0))
    s = simulate(sled(N, sim_time=40.0))
    c = simulate(centralized(N, sim_time=40.0))
    assert w.violation_rate() < s.violation_rate()
    assert w.violation_rate() < c.violation_rate()
    assert w.goodput() > s.goodput()
    assert w.goodput() > c.goodput()


def test_slo_scheduler_cuts_violations_vs_fcfs_at_load():
    N = 160
    w = simulate(wisp(N, sim_time=40.0))
    f = simulate(fcfs_cached(N, sim_time=40.0))
    assert w.violation_rate() < f.violation_rate()


def test_predictor_reduces_waste():
    base = simulate(variant(wisp(32, sim_time=30.0), predictor=None))
    pred = simulate(wisp(32, sim_time=30.0))
    assert pred.waste_fraction() < base.waste_fraction()
    assert pred.acceptance_rate() > base.acceptance_rate()


def test_tighter_slo_fails_first():
    r = simulate(wisp(224, sim_time=40.0))
    v = [r.violation_rate(s) for s in (2.0, 4.0, 6.0, 8.0)]
    assert v[0] <= v[-1]


def test_attribution_classifies_violations():
    r = simulate(sled(192, sim_time=30.0))
    att = r.attribution()
    kinds = {a["kind"] for a in att if a["violated"]}
    assert kinds <= {"compute", "queue"}
    assert any(a["violated"] for a in att)
    for a in att:
        assert (a["kind"] is None) == (not a["violated"])


def test_capacity_search_monotone_fake():
    calls = []

    def make_cfg(n):
        calls.append(n)
        return n

    import repro.sim.capacity as cap

    def fake_violation(make, n):
        return 0.0 if n <= 37 else 1.0

    orig = cap.violation_rate
    cap.violation_rate = fake_violation
    try:
        assert cap.capacity_search(make_cfg, eps=0.1, n_hi_cap=256) == 37
    finally:
        cap.violation_rate = orig


def test_acceptance_model_matches_table5_block_fraction():
    """Per-token alpha=0.80 with K=8 fixed window must give ~0.42 block
    acceptance (paper Table 5, predictor OFF): E[L]/K = a(1-a^8)/(8(1-a))."""
    rng = np.random.default_rng(0)
    m = AcceptanceModel(0.80, rng)
    tot_acc = tot_draft = 0
    for _ in range(4000):
        o = m.draft_block(8, None, fixed_k=8)
        tot_acc += o.accept_len
        tot_draft += o.n_drafted
    frac = tot_acc / tot_draft
    assert 0.38 < frac < 0.46


def test_predictor_operating_point_improves_sent_acceptance():
    """With the MLP operating point the acceptance of SENT tokens must rise
    vs fixed-window (paper Table 5 ON vs OFF)."""
    rng = np.random.default_rng(1)
    mk = lambda: AcceptanceModel(0.85, np.random.default_rng(1))
    m_off, m_on = mk(), mk()
    off_acc = off_sent = on_acc = on_sent = 0
    pred = PredictorOperatingPoint.mlp()
    for _ in range(4000):
        o = m_off.draft_block(8, None, fixed_k=8)
        off_acc, off_sent = off_acc + o.accept_len, off_sent + o.n_sent
        o = m_on.draft_block(8, pred)
        on_acc, on_sent = on_acc + o.accept_len, on_sent + o.n_sent
    assert on_acc / on_sent > off_acc / off_sent + 0.1


def test_oracle_predictor_eliminates_waste():
    rng = np.random.default_rng(2)
    m = AcceptanceModel(0.8, rng)
    for _ in range(500):
        o = m.draft_block(8, PredictorOperatingPoint.oracle())
        assert o.wasted <= 1    # only the flagged-but-undrafted boundary token
        assert o.accept_len == o.n_sent


def test_sim_deterministic_given_seed():
    a = simulate(wisp(24, sim_time=20.0, seed=7))
    b = simulate(wisp(24, sim_time=20.0, seed=7))
    assert a.goodput() == b.goodput()
    assert a.violation_rate() == b.violation_rate()
