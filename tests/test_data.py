"""Synthetic corpus + sharded loader: determinism, resume, structure."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import (
    SyntheticLMConfig,
    SyntheticStream,
    synthetic_batch_iter,
)


def test_stream_stateless_random_access():
    cfg = SyntheticLMConfig(vocab=512, seq_len=32, seed=3)
    s1 = SyntheticStream(cfg)
    s2 = SyntheticStream(cfg)
    ids = np.array([0, 5, 17, 5])
    a, b = s1.sequences(ids), s2.sequences(ids)
    np.testing.assert_array_equal(a, b)
    # same id -> same sequence regardless of position in the batch
    np.testing.assert_array_equal(a[1], a[3])
    # different seed -> different data
    c = SyntheticStream(SyntheticLMConfig(vocab=512, seq_len=32, seed=4))
    assert not np.array_equal(a, c.sequences(ids))


def test_stream_tokens_in_vocab_and_learnable():
    cfg = SyntheticLMConfig(vocab=256, seq_len=64, seed=0)
    seqs = SyntheticStream(cfg).sequences(np.arange(64))
    assert seqs.min() >= 0 and seqs.max() < 256
    # bigram structure: next-token conditional entropy < marginal entropy
    flat = seqs[:, :-1].ravel()
    nxt = seqs[:, 1:].ravel()
    marg = np.bincount(nxt, minlength=256) / len(nxt)
    h_marg = -np.sum(marg[marg > 0] * np.log(marg[marg > 0]))
    # conditional on previous token (coarse estimate over frequent tokens)
    h_conds = []
    for t in np.argsort(-np.bincount(flat, minlength=256))[:10]:
        sel = nxt[flat == t]
        if len(sel) < 50:
            continue
        p = np.bincount(sel, minlength=256) / len(sel)
        h_conds.append(-np.sum(p[p > 0] * np.log(p[p > 0])))
    assert np.mean(h_conds) < h_marg - 0.1


def test_batch_iter_resumable():
    cfg = SyntheticLMConfig(vocab=128, seq_len=16, seed=1)
    it = synthetic_batch_iter(cfg, batch=4)
    batches = [next(it) for _ in range(4)]
    it2 = synthetic_batch_iter(cfg, batch=4, start_step=2)
    b2 = next(it2)
    np.testing.assert_array_equal(batches[2]["tokens"], b2["tokens"])
    np.testing.assert_array_equal(batches[2]["targets"], b2["targets"])


def test_targets_are_shifted_tokens():
    cfg = SyntheticLMConfig(vocab=128, seq_len=16, seed=2)
    b = next(synthetic_batch_iter(cfg, batch=2))
    stream = SyntheticStream(cfg)
    seqs = stream.sequences(np.array([0, 1]))
    np.testing.assert_array_equal(b["tokens"], seqs[:, :-1])
    np.testing.assert_array_equal(b["targets"], seqs[:, 1:])


def test_sharded_loader_state_roundtrip():
    from repro.launch.mesh import make_test_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_test_mesh(1, 1)
    sh = NamedSharding(mesh, P())
    cfg = SyntheticLMConfig(vocab=64, seq_len=8, seed=0)
    loader = ShardedLoader(cfg, 4, sh)
    b0 = next(loader)
    b1 = next(loader)
    state = loader.state_dict()
    assert state == {"step": 2}
    loader2 = ShardedLoader(cfg, 4, sh)
    loader2.load_state_dict(state)
    b2 = next(loader2)
    assert isinstance(b2["tokens"], jax.Array)
    # deterministic continuation
    b2b = next(ShardedLoader(cfg, 4, sh, start_step=2))
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  np.asarray(b2b["tokens"]))
