"""Guard against the property suite silently degrading to skips.

`_hypothesis_stub` exists so the suite still COLLECTS where the optional
``hypothesis`` dependency is absent (each property test turns into one
skip).  That fallback must never fire on an environment that HAS
hypothesis — e.g. CI tier-1, which installs ``.[test]`` — or the
property tests would quietly stop executing while staying green.

This test is skipped (not failed) where hypothesis genuinely is not
installed: there the stub firing is the designed behavior.
"""
from __future__ import annotations

import importlib
import importlib.util
import sys

import pytest

#: every test module that guards its hypothesis import with the stub
PROPERTY_MODULES = (
    "test_chaos",
    "test_estimator",
    "test_kv_cache",
    "test_policies",
    "test_scheduler",
    "test_sharding",
    "test_spec_controller",
    "test_speculative",
    "test_tiered_kv",
    "test_wdt",
)


@pytest.mark.skipif(
    importlib.util.find_spec("hypothesis") is None,
    reason="hypothesis not installed: stub-skip fallback is the designed "
           "behavior here (CI tier-1 installs .[test] and runs this)",
)
def test_property_modules_run_real_hypothesis():
    for name in PROPERTY_MODULES:
        importlib.import_module(name)
    assert "_hypothesis_stub" not in sys.modules, (
        "hypothesis is importable, yet some property module fell back to "
        "tests/_hypothesis_stub — its property tests are silently skipping"
    )
