"""Training integration: loss decreases, checkpoint resume continuity,
optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train


@pytest.mark.slow
def test_training_reduces_loss(tmp_path):
    # like the adafactor test below: a tiny run spends its whole life in
    # the schedule's warmup window, so the smoke lr is set high enough
    # that the effective rate actually moves the weights within 40 steps
    out = train(
        "qwen2-7b", steps=40, batch=8, seq=64, reduced=True,
        log_every=5, seed=0, lr=3e-3,
    )
    losses = out["losses"]
    assert len(losses) >= 3
    assert losses[-1] < losses[0] - 0.05, f"no learning: {losses}"


@pytest.mark.slow
def test_checkpoint_resume_matches_uninterrupted(tmp_path):
    """Bitwise state continuity: (20 steps) == (10 steps, restart, 10 more)."""
    kw = dict(arch="qwen2-7b", batch=4, seq=32, reduced=True, log_every=0,
              seed=3)
    full = train(steps=20, **kw)

    ck = str(tmp_path / "ck")
    train(steps=10, ckpt_dir=ck, ckpt_every=10, **kw)
    resumed = train(steps=20, ckpt_dir=ck, ckpt_every=100, **kw)

    fl = jax.tree.leaves(full["params"])
    rl = jax.tree.leaves(resumed["params"])
    for a, b in zip(fl, rl):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_adafactor_runs_and_learns():
    # adafactor's relative updates need a higher LR to move within the
    # schedule's warmup window on a tiny run
    out = train("qwen2-7b", steps=40, batch=8, seq=64, reduced=True,
                opt="adafactor", lr=3e-3, log_every=5, seed=1)
    assert out["losses"][-1] < out["losses"][0]


@pytest.mark.slow
def test_remat_changes_nothing_numerically():
    a = train("qwen2-7b", steps=5, batch=4, seq=32, reduced=True,
              log_every=1, seed=2, remat=False)
    b = train("qwen2-7b", steps=5, batch=4, seq=32, reduced=True,
              log_every=1, seed=2, remat=True)
    np.testing.assert_allclose(a["losses"], b["losses"], rtol=1e-5)


@pytest.mark.slow
def test_micro_batch_accumulation_matches_full():
    """Gradient accumulation (micro_batches) must reproduce the full-batch
    update (f32 accumulation; tiny fp reorder tolerance)."""
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import build
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen2-7b").reduced()
    mesh = make_test_mesh(1, 1)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
    }
    outs = {}
    for mb in (1, 4):
        step, info = make_train_step(
            cfg, mesh, opt_cfg=OptConfig(lr=1e-3), micro_batches=mb
        )
        with mesh:
            p0 = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
            o0 = info["init_opt"](p0)
            p1, _, m = step(p0, o0, batch)
        outs[mb] = (float(m["loss"]), p1)
    assert abs(outs[1][0] - outs[4][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-4
        )


@pytest.mark.slow
def test_moe_training_smoke():
    out = train("deepseek-moe-16b", steps=8, batch=4, seq=32, reduced=True,
                log_every=2, seed=4)
    assert np.isfinite(out["losses"]).all()
