"""Property tests for the adaptive-speculation loop (DESIGN.md §11).

Four invariants pin the per-session dynamic-K machinery:

  * a block never drafts past its chosen cap: ``n_drafted <= K`` for any
    K request and any predictor verdict sequence (and exactly K with no
    predictor);
  * the adaptive controller's K stays in ``[1, k_max]`` and moves at
    most one step per observation (the hysteresis contract), under
    ARBITRARY feedback — including NaN/inf/negative signals;
  * the server-side committed prefix never shrinks under any K
    schedule (streams only ever extend, whatever the controller does);
  * within-block early stop is monotone in the predictor threshold: a
    stricter predictor never drafts MORE tokens.

Property tests run under ``hypothesis`` when installed (CI tier-1
installs it — see `test_hypothesis_available.py`) and collect as
skipped via `_hypothesis_stub` otherwise.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.core.controller import BlockDrafter
from repro.core.speculation import (
    SpeculationController,
    available_spec_policies,
    make_spec_controller,
)


# ---------------------------------------------------------------------------
# registry surface (example-based)
# ---------------------------------------------------------------------------


def test_registry_names_and_aliases():
    assert available_spec_policies() == ["adaptive", "scripted", "static"]
    for name in ("static", "fixed", "adaptive", "dynamic", "scripted"):
        c = make_spec_controller(name, k_max=5)
        assert isinstance(c, SpeculationController)
        assert 1 <= c.next_k() <= 5
    with pytest.raises(ValueError, match="available"):
        make_spec_controller("nope")


def test_scripted_schedule_replays_then_holds():
    c = make_spec_controller("scripted", k_max=6, schedule=[3, 1, 9, 2])
    assert [c.next_k() for _ in range(6)] == [3, 1, 6, 2, 2, 2]
    c.start_session()
    assert c.next_k() == 3


def test_static_matches_legacy_k_max():
    c = make_spec_controller("static", k_max=4)
    for _ in range(3):
        assert c.next_k() == 4
        c.observe(accept_len=0, k_used=4, rtt=9.9, queue_depth=50)


def test_adaptive_state_roundtrip_survives_migration():
    a = make_spec_controller("adaptive", k_max=8)
    for _ in range(6):
        a.observe(accept_len=1, k_used=8, rtt=0.002, queue_depth=12)
    b = make_spec_controller("adaptive", k_max=8)
    b.load_state(a.state())
    assert b.next_k() == a.next_k()
    assert b.state() == a.state()


# ---------------------------------------------------------------------------
# property 1: a block never drafts past its chosen K
# ---------------------------------------------------------------------------


class _FakeCtl:
    """Duck-typed stand-in for `DraftingController`: deterministic
    synthetic logits, no model, no jit — `BlockDrafter` only reads the
    attributes below plus ``sample_next``."""

    def __init__(self, k_max: int, predictor=None, vocab: int = 16):
        self.k_max = k_max
        self.predictor = predictor
        self.include_flagged = False
        self.q_mode = "dense"
        self.q_top_c = 8
        self.draft_speed = 50.0
        self.vocab = vocab

    def sample_next(self, rng, last_token, cache, pos):
        g = np.random.default_rng(1000 + 7 * int(last_token) + int(pos))
        lg = jnp.asarray(g.normal(size=(1, self.vocab)), jnp.float32)
        return int(g.integers(0, self.vocab)), lg, cache


class _BoolSeqPredictor:
    """Scripted per-position accept verdicts (True past the end)."""

    def __init__(self, accepts):
        self.accepts = list(accepts)
        self._i = 0

    def predict_accept(self, feats):
        ok = self.accepts[self._i] if self._i < len(self.accepts) else True
        self._i += 1
        return np.asarray([bool(ok)])


def _run_drafter(ctl, k):
    d = BlockDrafter(ctl, jax.random.PRNGKey(0), 3, None, 0, k=k)
    while d.step():
        pass
    return d.result()


@given(k=st.integers(min_value=-3, max_value=24),
       k_max=st.integers(min_value=1, max_value=12),
       accepts=st.lists(st.booleans(), max_size=24))
@settings(max_examples=60, deadline=None)
def test_draft_len_never_exceeds_chosen_k(k, k_max, accepts):
    pred = _BoolSeqPredictor(accepts) if accepts else None
    res = _run_drafter(_FakeCtl(k_max, predictor=pred), k)
    cap = max(1, min(k, k_max))
    assert res.k_used == cap
    assert 0 < res.n_drafted <= cap
    assert res.n_sent <= res.n_drafted
    assert len(res.tokens) == res.n_sent
    if res.stopped_by == "max":
        assert res.n_drafted == cap
    if pred is None:
        # no predictor: the cap is exhausted exactly
        assert res.n_drafted == res.n_sent == cap


# ---------------------------------------------------------------------------
# property 2: adaptive K bounded + slew-limited under arbitrary feedback
# ---------------------------------------------------------------------------

_signal = st.one_of(st.none(),
                    st.floats(allow_nan=True, allow_infinity=True))
_observation = st.tuples(
    st.integers(min_value=-4, max_value=64),     # accept_len
    st.integers(min_value=-4, max_value=64),     # k_used
    _signal,                                     # p_accept
    _signal,                                     # rtt
    _signal,                                     # queue_depth
)


@given(k_max=st.integers(min_value=1, max_value=16),
       seq=st.lists(_observation, max_size=40))
@settings(max_examples=120, deadline=None)
def test_adaptive_k_bounded_and_slew_limited(k_max, seq):
    c = make_spec_controller("adaptive", k_max=k_max, draft_speed=50.0)
    c.start_session()
    prev = c.next_k()
    assert 1 <= prev <= k_max
    for accept_len, k_used, p_accept, rtt, queue_depth in seq:
        c.observe(accept_len=accept_len, k_used=k_used, p_accept=p_accept,
                  rtt=rtt, queue_depth=queue_depth)
        k = c.next_k()
        assert 1 <= k <= k_max, (k, k_max)
        assert abs(k - prev) <= 1, "hysteresis: one step per observation"
        prev = k


# ---------------------------------------------------------------------------
# property 3: the committed prefix never shrinks under ANY K schedule
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shared_server():
    from repro.core.estimator import EstimatorCoeffs
    from repro.serving.engine import VerificationEngine
    from repro.serving.server import WISPServer

    from repro.configs import get_config
    from repro.models import build

    cfg = get_config("qwen2-7b").reduced()
    params = build(cfg).init(jax.random.PRNGKey(0))
    engine = VerificationEngine(cfg, params, max_slots=2, max_len=256,
                                method="residual", seed=7)
    server = WISPServer(
        engine, EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3),
        policy="fcfs",
    )
    return cfg, engine, server


_sid_counter = itertools.count(100)


@given(schedule=st.lists(st.integers(min_value=1, max_value=6),
                         min_size=1, max_size=4))
@settings(max_examples=8, deadline=None)
def test_committed_prefix_never_shrinks(shared_server, schedule):
    """Drive real verification rounds under an arbitrary K schedule and
    read the ENGINE's committed token buffer back after every round: it
    must only ever extend (the adaptive loop may change where blocks are
    cut, never un-commit)."""
    cfg, engine, server = shared_server
    sid = next(_sid_counter)
    server.open_session(sid, [1 + sid % 5, 2, 3, 4], slo_class=2, now=0.0)
    slot = server.sessions[sid].slot
    prev = list(engine.tokens[slot])
    now = 0.0
    for rnd, k in enumerate(schedule):
        g = np.random.default_rng(31 * sid + rnd)
        toks = g.integers(0, cfg.vocab, size=k).astype(np.int32)
        qlog = (g.normal(size=(k, cfg.vocab)) * 1.5).astype(np.float32)
        server.submit(sid, toks, qlog, now=now, t_draft=0.01,
                      t_network=0.005)
        while server.queue_depth:
            server.step(now)
            now += 0.005
        server.pop_events()
        cur = list(engine.tokens[slot])
        assert len(cur) > len(prev), "every round must commit >= 1 token"
        assert cur[: len(prev)] == prev, "committed prefix shrank"
        prev = cur
    server.close_session(sid)


# ---------------------------------------------------------------------------
# property 4: early stop is monotone in the predictor threshold
# ---------------------------------------------------------------------------


class _ThresholdPredictor:
    """Accept while the scripted per-position proba clears ``threshold``
    — raising the threshold can only turn accepts into rejections."""

    def __init__(self, probas, threshold):
        self.probas = list(probas)
        self.threshold = float(threshold)
        self._i = 0

    def predict_accept(self, feats):
        p = self.probas[self._i] if self._i < len(self.probas) else 1.0
        self._i += 1
        return np.asarray([p >= self.threshold])


@given(probas=st.lists(st.floats(min_value=0.0, max_value=1.0),
                       min_size=1, max_size=12),
       t_lo=st.floats(min_value=0.0, max_value=1.0),
       t_hi=st.floats(min_value=0.0, max_value=1.0),
       k=st.integers(min_value=1, max_value=12))
@settings(max_examples=60, deadline=None)
def test_early_stop_monotone_in_threshold(probas, t_lo, t_hi, k):
    if t_lo > t_hi:
        t_lo, t_hi = t_hi, t_lo
    lo = _run_drafter(
        _FakeCtl(12, predictor=_ThresholdPredictor(probas, t_lo)), k)
    hi = _run_drafter(
        _FakeCtl(12, predictor=_ThresholdPredictor(probas, t_hi)), k)
    assert hi.n_drafted <= lo.n_drafted
    assert hi.n_sent <= lo.n_sent
    # and the stricter run's block is a prefix of the looser run's
    assert list(hi.tokens) == list(lo.tokens)[: hi.n_sent]
