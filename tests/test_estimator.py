"""Verification-time estimator: feature math, OLS recovery, persistence."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.core.estimator import (
    BatchShape,
    EstimatorCoeffs,
    analytic_tpu_coeffs,
    batch_features,
    evaluate,
    fit_ols,
    load_coeffs,
    save_coeffs,
)


@settings(max_examples=50, deadline=None)
@given(
    reqs=st.lists(
        st.tuples(st.integers(0, 2000), st.integers(0, 50_000)), min_size=0,
        max_size=16,
    )
)
def test_batch_features_additive(reqs):
    shapes = [BatchShape(new_tokens=n, cached_tokens=c) for n, c in reqs]
    f = batch_features(shapes)
    assert f[0] == sum(n for n, _ in reqs)
    assert f[1] == sum((n + c) * n for n, c in reqs)
    assert f[2] == sum(c for _, c in reqs)
    # additivity: features of a union = sum of features
    half = len(shapes) // 2
    np.testing.assert_allclose(
        f, batch_features(shapes[:half]) + batch_features(shapes[half:])
    )


def _synth_dataset(rng, coeffs, n=200, noise=0.0):
    feats, lats = [], []
    for _ in range(n):
        b = [
            BatchShape(
                new_tokens=int(rng.integers(1, 2000)),
                cached_tokens=int(rng.integers(0, 4000)),
            )
            for _ in range(rng.integers(1, 8))
        ]
        f = batch_features(b)
        y = coeffs.predict_features(f) * (1 + noise * rng.normal())
        feats.append(f)
        lats.append(y)
    return np.stack(feats), np.asarray(lats)


def test_ols_recovers_ground_truth():
    rng = np.random.default_rng(0)
    truth = EstimatorCoeffs(a=3.3e-5, b_compute=3.5e-8, b_read=4.6e-6, c=0.0149)
    X, y = _synth_dataset(rng, truth, n=300)
    fit = fit_ols(X, y)
    assert fit.r2 > 0.999
    np.testing.assert_allclose(fit.coeffs.a, truth.a, rtol=1e-3)
    np.testing.assert_allclose(fit.coeffs.b_compute, truth.b_compute, rtol=1e-3)
    np.testing.assert_allclose(fit.coeffs.b_read, truth.b_read, rtol=1e-3)
    np.testing.assert_allclose(fit.coeffs.c, truth.c, rtol=1e-3)


def test_ols_with_noise_and_bootstrap_ci():
    rng = np.random.default_rng(1)
    truth = EstimatorCoeffs(a=3.3e-5, b_compute=3.5e-8, b_read=4.6e-6, c=0.0149)
    X, y = _synth_dataset(rng, truth, n=400, noise=0.05)
    fit = fit_ols(X, y, bootstrap=200)
    assert fit.r2 > 0.95
    lo, hi = fit.ci95["a"]
    assert lo <= truth.a <= hi
    # held-out evaluation consistent
    X2, y2 = _synth_dataset(np.random.default_rng(2), truth, n=100, noise=0.05)
    m = evaluate(fit.coeffs, X2, y2)
    assert m["r2"] > 0.9


def test_save_load_roundtrip(tmp_path):
    c = EstimatorCoeffs(a=1e-5, b_compute=2e-8, b_read=3e-6, c=0.01)
    p = tmp_path / "coeffs.json"
    save_coeffs(c, p)
    c2 = load_coeffs(p)
    assert c == c2


def test_analytic_tpu_coeffs_sane():
    from repro.configs import get_config

    c = analytic_tpu_coeffs(get_config("qwen2-7b"))
    assert 0 < c.b_compute < c.b_read < c.a      # per-unit cost ordering
    assert 0 < c.a < 1e-3                        # < 1 ms/token on a v5e
    # cold prefill costs more than a cached follow-up
    cold = c.predict([BatchShape(new_tokens=512, cached_tokens=0)])
    warm = c.predict([BatchShape(new_tokens=8, cached_tokens=504)])
    assert cold > warm
