"""cost_scan semantics: unrolled == lax.scan, trip-count cap, None ys."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import loops


def _body(c, x):
    return c + x, c * 2.0


def test_unroll_matches_scan():
    xs = jnp.arange(12.0)
    c1, y1 = loops.scan(_body, 0.0, xs)
    with loops.cost_unroll(True):
        c2, y2 = loops.scan(_body, 0.0, xs)
    assert float(c1) == float(c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_unroll_none_ys():
    def body(c, x):
        return c + x, None

    with loops.cost_unroll(True):
        c, ys = loops.scan(body, 0.0, jnp.arange(4.0))
    assert ys is None
    assert float(c) == 6.0


def test_unroll_tree_carry_and_ys():
    def body(c, x):
        c = {"a": c["a"] + x["u"], "b": c["b"] * 1.0}
        return c, {"out": c["a"], "skip": None}

    xs = {"u": jnp.arange(5.0)}
    init = {"a": jnp.zeros(()), "b": jnp.ones(())}
    ref_c, ref_y = jax.lax.scan(
        lambda c, x: body(c, x), init, xs
    )
    with loops.cost_unroll(True):
        c, y = loops.scan(body, init, xs)
    np.testing.assert_allclose(float(c["a"]), float(ref_c["a"]))
    np.testing.assert_allclose(np.asarray(y["out"]), np.asarray(ref_y["out"]))
    assert y["skip"] is None


def test_trip_count_cap_keeps_rolled():
    """Loops longer than UNROLL_LIMIT must stay lax.scan even in cost mode
    (per-token recurrences would explode the HLO)."""
    xs = jnp.arange(float(loops.UNROLL_LIMIT + 1))

    def traced_count():
        n = [0]

        def body(c, x):
            n[0] += 1
            return c + x, None

        with loops.cost_unroll(True):
            jax.make_jaxpr(lambda: loops.scan(body, 0.0, xs))()
        return n[0]

    # rolled: the body traces once (lax.scan), not len(xs) times
    assert traced_count() == 1


def test_flag_restored_on_exception():
    try:
        with loops.cost_unroll(True):
            assert loops.cost_unroll_enabled()
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert not loops.cost_unroll_enabled()
