"""Edge-link fault domain: deterministic chaos, retry/dedup, degradation.

Pins the DESIGN.md §14 contract from both ends:

  * **mechanism** — the `FaultSchedule` DSL parses/merges as documented;
    `FaultyTransport` fates are a pure function of message identity
    (never of event-loop order); `NetworkModel` jitter is seeded and its
    ``sigma=0`` path is exactly the unjittered model; the speculation
    controller's link-health degradation law is hysteretic.
  * **end-to-end law** — under drop + duplication + reordering + a hard
    link-down window, retry/backoff + idempotent re-submission + verdict
    replay/dedup commit per-session streams BYTE-IDENTICAL to the
    fault-free run (faults may only cost time, never change bytes), and
    the property holds over randomly drawn schedules, not just the
    canned ones.

Property tests run under ``hypothesis`` when installed (CI tier-1
installs it — see `test_hypothesis_available.py`) and collect as skipped
via `_hypothesis_stub` otherwise.
"""
from __future__ import annotations

import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.chaos import (
    FAULT_PRESETS,
    FaultSchedule,
    FaultyTransport,
    LinkFaults,
    parse_fault_schedule,
    resolve_fault_schedule,
)
from repro.core.speculation import make_spec_controller
from repro.serving.transport import NetworkModel


# -- schedule DSL ------------------------------------------------------------

def test_parse_dsl_scopes_and_windows():
    s = parse_fault_schedule(
        "drop=0.1,up.dup=0.2,down.spike=0.3,spike_s=0.08,"
        "linkdown@0.25+0.5,up.linkdown@1.0+0.1,seed=7"
    )
    assert s.seed == 7
    assert s.up.drop == s.down.drop == 0.1          # unscoped -> both
    assert (s.up.dup, s.down.dup) == (0.2, 0.0)     # up.-scoped
    assert (s.up.spike, s.down.spike) == (0.0, 0.3)
    assert s.up.spike_s == s.down.spike_s == 0.08
    assert s.down.windows == ((0.25, 0.75),)
    assert s.up.windows == ((0.25, 0.75), (1.0, 1.1))
    assert s.up.is_down(0.3) and not s.up.is_down(0.75)  # half-open


def test_parse_dsl_verifier_faults():
    s = parse_fault_schedule("kill=0@0.15,kill=2@0.1+0.4,"
                             "straggle=1@0.05+0.95*400")
    assert s.verifier_fail == ((0, 0.15, None), (2, 0.1, 0.5))
    assert s.verifier_straggle == ((1, 0.05, 1.0, 400.0),)
    assert s.has_verifier_faults() and not s.has_link_faults()


def test_parse_presets_and_passthrough():
    flap = parse_fault_schedule("flap")
    assert flap == parse_fault_schedule(FAULT_PRESETS["flap"])
    assert flap.seed == 7 and flap.up.windows == ((0.25, 0.75),)
    assert parse_fault_schedule(None) == FaultSchedule()
    assert parse_fault_schedule(flap) is flap       # ready schedules pass


@pytest.mark.parametrize("bad", ["nope=1", "linkdown@0.5", "drop", "kill=x@1",
                                 "straggle=0@0.1*4"])
def test_parse_rejects_bad_tokens(bad):
    with pytest.raises(ValueError):
        parse_fault_schedule(bad)


def test_resolve_merges_legacy_shims_and_defaults_seed():
    @dataclasses.dataclass
    class Cfg:
        fault_schedule: object = "lossy"
        fail_at: tuple = ((1, 0.2, None),)
        straggle: tuple = ((0, 0.1, 0.9, 50.0),)
        seed: int = 42

    s = resolve_fault_schedule(Cfg())
    assert s.seed == 7                              # DSL seed wins
    assert s.verifier_fail == ((1, 0.2, None),)     # legacy rows folded in
    assert s.verifier_straggle == ((0, 0.1, 0.9, 50.0),)
    s2 = resolve_fault_schedule(Cfg(fault_schedule="drop=0.1"))
    assert s2.seed == 42                            # inherits the run seed


# -- transport: fates are pure functions of message identity -----------------

def _transport(**link):
    sched = FaultSchedule(seed=3, up=LinkFaults(**link),
                          down=LinkFaults(**link))
    return FaultyTransport(NetworkModel(), sched)


def test_transport_requires_resolved_seed():
    with pytest.raises(ValueError):
        FaultyTransport(NetworkModel(), FaultSchedule())


def test_zero_fault_schedule_is_single_on_time_delivery():
    tr = _transport()
    assert tr.deliveries("up", (0, 0, 0), 1.0, 0.01) == [1.01]
    assert tr.stats["up_dropped"] == 0


def test_window_drops_every_message_inside_it():
    sched = FaultSchedule(seed=3, up=LinkFaults(windows=((0.2, 0.4),)))
    tr = FaultyTransport(NetworkModel(), sched)
    assert tr.deliveries("up", (0, 0, 0), 0.3, 0.01) == []
    assert tr.deliveries("up", (0, 0, 1), 0.4, 0.01) \
        == pytest.approx([0.41])            # half-open: t1 is back up
    assert tr.stats["up_window_drops"] == 1


@settings(max_examples=50, deadline=None)
@given(
    drop=st.floats(0.0, 1.0), dup=st.floats(0.0, 1.0),
    reorder=st.floats(0.0, 1.0), spike=st.floats(0.0, 1.0),
    key=st.tuples(st.integers(0, 99), st.integers(0, 99),
                  st.integers(0, 9)),
    direction=st.sampled_from(["up", "down"]),
    t_send=st.floats(0.0, 10.0), latency=st.floats(1e-4, 0.5),
)
def test_transport_fates_deterministic_and_causal(drop, dup, reorder, spike,
                                                  key, direction, t_send,
                                                  latency):
    """Same identity -> same fate, independent of call order; surviving
    copies never arrive before ``t_send + latency``; at most one
    duplicate."""
    mk = lambda: _transport(drop=drop, dup=dup, reorder=reorder, spike=spike)
    a = mk().deliveries(direction, key, t_send, latency)
    tr = mk()
    tr.deliveries(direction, (77, 77, 7), 0.0, latency)   # unrelated traffic
    b = tr.deliveries(direction, key, t_send, latency)
    assert a == b
    assert len(a) <= 2
    assert all(t >= t_send + latency for t in a)
    if len(a) == 2:
        assert a[1] > a[0]


def test_up_down_fates_independent():
    tr = _transport(drop=0.5, dup=0.3, reorder=0.3)
    ups = [bool(tr.deliveries("up", (i, 0, 0), 0.0, 0.01)) for i in range(40)]
    dns = [bool(tr.deliveries("down", (i, 0, 0), 0.0, 0.01))
           for i in range(40)]
    assert ups != dns          # distinct dircodes -> distinct rng streams


# -- NetworkModel seeded jitter ----------------------------------------------

def test_jitter_sigma_zero_is_exact_identity():
    base = NetworkModel()
    j0 = dataclasses.replace(base, jitter_sigma=0.0, jitter_seed=5)
    assert j0.uplink_time(4, key=(0, 1, 2, 3)) == base.uplink_time(4)
    assert j0.downlink_time(key=(1, 1, 2, 3)) == base.downlink_time()


def test_jitter_deterministic_per_key_and_varies_across_keys():
    net = dataclasses.replace(NetworkModel(), jitter_sigma=0.3,
                              jitter_seed=5)
    a = net.downlink_time(key=(1, 3, 2, 0))
    assert a == net.downlink_time(key=(1, 3, 2, 0))
    assert a != net.downlink_time(key=(1, 3, 2, 1))
    assert a > 0
    # no key -> base latency (control-plane messages stay unjittered)
    assert net.downlink_time() == NetworkModel().downlink_time()


# -- graceful-degradation law (speculation controller) -----------------------

def test_degradation_is_opt_in():
    c = make_spec_controller("static", k_max=6)
    for _ in range(8):
        c.observe_link(False, down=True)
    assert c.choose_k() == 6 and not c.degraded_last


def test_degradation_hysteresis():
    c = make_spec_controller("static", k_max=6, degrade=True)
    assert c.choose_k() == 6
    c.observe_link(False)                    # one flap: health dips
    assert c.link_health < 1.0
    while c.link_health >= c.degrade_below:
        c.observe_link(False)
    k_flap = c.choose_k()
    assert 1 <= k_flap < 6 and c.degraded_last
    c.observe_link(False, down=True)         # runtime latches hard-down
    assert c.choose_k() == 1 and c.degraded_last
    c.observe_link(True)                     # one ok is NOT recovery...
    assert c.link_down and c.choose_k() == 1
    while c.link_down:                       # ...streak + health both needed
        c.observe_link(True)
    assert c.link_health >= c.recover_above
    assert c.choose_k() == 6 and not c.degraded_last


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.booleans()), max_size=60))
def test_degraded_k_always_valid(outcomes):
    c = make_spec_controller("static", k_max=5, degrade=True)
    for ok, down in outcomes:
        c.observe_link(ok, down=down and not ok)
        assert 1 <= c.choose_k() <= 5
        assert 0.0 <= c.link_health <= 1.0


# -- end to end: faults cost time, never bytes -------------------------------

#: rounds=3 so the virtual clock actually reaches the flap preset's
#: 0.25 s outage window and the ~10% loss law has messages to bite on
_E2E_KW = dict(devices=2, rounds=3, k_max=3, verbose=False, seed=0)


@pytest.fixture(scope="module")
def clean_streams():
    from repro.launch.serve import run_serving
    r = run_serving(**_E2E_KW)
    return [list(d.response_tokens) for d in r["edges"]]


def _chaos_run(schedule, **kw):
    from repro.launch.serve import run_serving
    r = run_serving(fault_schedule=schedule, **{**_E2E_KW, **kw})
    return [list(d.response_tokens) for d in r["edges"]], r["metrics"].chaos


def test_flap_streams_byte_identical_to_clean(clean_streams):
    """The acceptance schedule (drop + dup + reorder + 500 ms outage):
    every committed stream matches the fault-free golden byte for byte,
    and the recovery machinery demonstrably ran."""
    streams, c = _chaos_run("flap", link_timeout=0.08)
    assert streams == clean_streams
    assert c.retries > 0 and c.timeouts > 0
    assert c.uplink_drops + c.downlink_drops > 0


def test_downlink_loss_recovers_via_verdict_replay(clean_streams):
    """Lost/duplicated VERDICTs: the retried request hits the server's
    idempotency gate, which replays the cached verdict instead of
    re-verifying; duplicate deliveries die at the device's round gate."""
    streams, c = _chaos_run("down.drop=0.5,dup=0.2,seed=5",
                            link_timeout=0.05)
    assert streams == clean_streams
    assert c.downlink_drops > 0
    assert c.verdicts_replayed > 0          # lost-ack recovery path ran
    assert c.dup_verdicts_dropped > 0       # dedup gate ran
    assert c.link_down_events >= c.link_up_events


def test_chaos_counters_clean_when_unfaulted(clean_streams):
    streams, c = _chaos_run(None)
    assert streams == clean_streams
    assert all(v == 0 for v in c.as_dict().values())


@settings(max_examples=4, deadline=None)
@given(
    drop=st.floats(0.0, 0.3), dup=st.floats(0.0, 0.2),
    reorder=st.floats(0.0, 0.2), seed=st.integers(0, 99),
    window=st.booleans(),
)
def test_random_schedules_preserve_streams(clean_streams, drop, dup, reorder,
                                           seed, window):
    """The byte-identity law is not a property of the canned presets:
    ANY seeded loss/dup/reorder law (optionally with an outage window)
    terminates and commits the golden streams."""
    spec = f"drop={drop},dup={dup},reorder={reorder},seed={seed}"
    if window:
        spec += ",linkdown@0.1+0.3"
    streams, _ = _chaos_run(spec, link_timeout=0.08)
    assert streams == clean_streams
