"""Chunked prompt prefill (DESIGN.md §8): chunked-vs-monolithic
equivalence (first token, prefix-index state; dense and paged backends),
resumable OutOfPages, mixed verify+prefill engine steps, the server's
chunked dispatch flow, and stream invariance across cluster prefill modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.models import build
from repro.serving.engine import (
    PrefillChunkItem,
    VerificationEngine,
    VerifyItem,
)
from repro.serving.kv_cache import OutOfPages
from repro.serving.server import WISPServer

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return cfg, bundle, params


def _engine(cfg, params, *, paged, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 128)
    if paged:
        kw.setdefault("page_size", 4)
    return VerificationEngine(cfg, params, method="greedy", paged=paged, **kw)


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_chunked_prefill_commits_identical_first_token(dense_model, paged):
    """Chunked prefill must commit the byte-identical first token — and,
    paged, the identical prefix-index state — as monolithic prefill, and
    later verification must be indistinguishable between the two."""
    cfg, _, params = dense_model
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]

    mono = _engine(cfg, params, paged=paged)
    chunked = _engine(cfg, params, paged=paged)
    slot_m, first_m = mono.new_session(prompt)
    st = chunked.begin_prefill(prompt)
    while not st.finished:
        chunked.prefill_chunk(st, 4)            # page-aligned chunks
    assert st.first_token == first_m
    assert st.chunks == 3
    assert int(chunked.fed[st.slot]) == int(mono.fed[slot_m]) == len(prompt)
    if paged:
        # identical prefix-index state: same chained page hashes published
        assert (chunked.kv.allocator.prefix_index.keys()
                == mono.kv.allocator.prefix_index.keys())
        assert chunked.tokens[st.slot] == mono.tokens[slot_m] == list(prompt)

    # a verify round after chunked prefill matches the monolithic engine
    d = np.asarray([7, 8, 9], np.int32)
    q = np.zeros((3, cfg.vocab), np.float32)
    (om,) = mono.verify([VerifyItem(slot=slot_m, draft_tokens=d, q_logits=q)])
    (oc,) = chunked.verify([VerifyItem(slot=st.slot, draft_tokens=d,
                                       q_logits=q)])
    assert (om.accept_len, om.token) == (oc.accept_len, oc.token)


def test_chunked_prefill_uses_prefix_cache(dense_model):
    """A chunked prefill of a prompt whose prefix is cached starts past
    the cached pages and still completes with the sharing semantics of the
    monolithic path (same first token, shared physical pages)."""
    cfg, _, params = dense_model
    eng = _engine(cfg, params, paged=True, max_slots=3, max_len=64)
    prompt = [5, 4, 3, 2, 1, 0, 1, 2, 3, 4]                 # 2 full pages
    s1, f1 = eng.new_session(prompt)
    st = eng.begin_prefill(prompt)
    assert st.done == 8 and st.n_cached == 8                # prefix hit
    while not st.finished:
        eng.prefill_chunk(st, 4)
    assert st.first_token == f1
    p1, p2 = eng.kv.tables[s1].pages, eng.kv.tables[st.slot].pages
    assert p1[:2] == p2[:2]                                 # physical sharing
    assert eng.stats["prefix_cached_tokens"] == 8


def test_prefill_chunk_out_of_pages_is_resumable(dense_model):
    """A chunk the pool cannot cover raises with the state intact; after
    pages free the same state resumes and commits the same first token a
    fresh monolithic engine produces."""
    cfg, _, params = dense_model
    eng = VerificationEngine(cfg, params, max_slots=2, max_len=24,
                             method="greedy", paged=True, page_size=4,
                             n_pages=6)                     # 5 usable pages
    blocker, _ = eng.new_session(list(range(40, 52)))       # 3 pages
    st = eng.begin_prefill(list(range(2, 14)))              # needs 3 pages
    eng.prefill_chunk(st, 4)
    eng.prefill_chunk(st, 4)                                # pool now full
    done_before = st.done
    with pytest.raises(OutOfPages):
        eng.prefill_chunk(st, 4)
    assert st.done == done_before and not st.finished       # state intact
    eng.close_session(blocker)                              # frees pages
    eng.prefill_chunk(st, 4)
    assert st.finished
    ref = _engine(cfg, params, paged=True)
    _, want = ref.new_session(list(range(2, 14)))
    assert st.first_token == want


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_engine_step_executes_mixed_batches(dense_model, paged):
    """One engine step with a verify item AND a prefill chunk: outcomes
    align with items, the verify outcome matches a verify-only dispatch,
    and the chunk advances exactly its budget."""
    cfg, _, params = dense_model
    prompt = [3, 1, 4, 1, 5, 9]
    d = np.asarray([7, 8, 9], np.int32)
    q = np.zeros((3, cfg.vocab), np.float32)

    solo = _engine(cfg, params, paged=paged)
    slot_s, _ = solo.new_session(prompt)
    (want,) = solo.verify([VerifyItem(slot=slot_s, draft_tokens=d,
                                      q_logits=q)])

    eng = _engine(cfg, params, paged=paged, max_slots=3)
    slot_v, _ = eng.new_session(prompt)
    st = eng.begin_prefill([9, 8, 7, 6, 5, 4, 3, 2])
    out = eng.step([
        VerifyItem(slot=slot_v, draft_tokens=d, q_logits=q),
        PrefillChunkItem(st, 4),
    ])
    assert (out[0].accept_len, out[0].token) == (want.accept_len, want.token)
    assert out[1].processed == 4 and out[1].first_token is None
    (fin,) = eng.step([PrefillChunkItem(st, 4)])
    assert fin.first_token is not None and fin.done == fin.total == 8


def test_server_chunked_flow_matches_monolithic_first_token(dense_model):
    """Server in chunked mode: open_session returns a ``prefilling``
    handle, dispatch epochs drive the chunks under Algorithm 1, the
    FIRST_TOKEN event (and the deprecated pop_admissions shim) surfaces
    the same first token the monolithic server returns, and the TTFT
    record lands on the event stream against the class's deadline."""
    cfg, _, params = dense_model
    prompt = list(range(2, 22))
    mono = WISPServer(_engine(cfg, params, paged=True), COEFFS)
    first_mono = mono.open_session(0, prompt, slo_class=2).first_token

    srv = WISPServer(_engine(cfg, params, paged=True), COEFFS,
                     prefill="chunked", prefill_chunk_tokens=8)
    vt = lambda served: srv.scheduler.batch_time(served)
    h = srv.open_session(0, prompt, slo_class=2, now=0.0)
    assert h.state == "prefilling" and h.first_token is None
    assert 0 in srv.prefilling and srv.queue_depth == 1
    t, epochs = 0.0, 0
    while 0 in srv.prefilling:
        srv.step(t, verify_time=vt)
        t += 0.01
        epochs += 1
        assert epochs < 10, "chunked prefill did not converge"
    assert h.state == "active" and h.first_token == first_mono
    evs = srv.pop_events()
    firsts = [(e.session_id, e.token) for e in evs
              if e.kind == "FIRST_TOKEN"]
    assert firsts == [(0, first_mono)]
    with pytest.warns(DeprecationWarning):
        assert srv.pop_admissions() == firsts      # legacy shim agrees
    (rec,) = [e.record for e in evs if e.kind == "TTFT_RECORD"]
    assert srv.prefill_log == [rec]                # legacy side-car agrees
    assert rec.chunks == 3 and rec.prompt_len == 20
    assert not rec.violated and rec.ttft > 0.0

    # the activated session verifies normally
    d = np.asarray([1, 2, 3], np.int32)
    q = np.zeros((3, cfg.vocab), np.float32)
    mono.submit(0, d, q, now=t, t_draft=0.0, t_network=0.0)
    srv.submit(0, d, q, now=t, t_draft=0.0, t_network=0.0)
    (vm,) = mono.step(t)
    (vc,) = srv.step(t, verify_time=vt)
    assert (vm.accept_len, vm.token) == (vc.accept_len, vc.token)


def test_server_close_cancels_prefilling_session(dense_model):
    """close_session mid-prefill must retire the slot, the queued chunk,
    and the prefilling record — and must not publish the partial prompt."""
    cfg, _, params = dense_model
    srv = WISPServer(_engine(cfg, params, paged=True), COEFFS,
                     prefill="chunked", prefill_chunk_tokens=8)
    assert srv.open_session(0, list(range(2, 22)), slo_class=3,
                            now=0.0).state == "prefilling"
    srv.step(0.0)                           # one chunk runs
    srv.close_session(0)
    assert 0 not in srv.prefilling
    assert all(r.session_id != 0 for r in srv.pending)
    assert not srv.engine.kv.tables          # pages released
    assert not srv.engine.kv.allocator.prefix_index  # nothing published
    assert len(srv.engine.free_slots) == srv.engine.max_slots


def test_mutually_blocked_prefills_preempt_instead_of_livelock(dense_model):
    """Two long prompts that each fit alone but not together: their
    partial prefills exhaust the pool and every chunk comes back oom.
    The server must preempt the younger session back to the admission
    queue (pages released) so the older completes — not requeue both
    forever."""
    cfg, _, params = dense_model
    # 4 usable pages of 4 tokens; two 12-token prompts need 3 pages each
    eng = VerificationEngine(cfg, params, max_slots=2, max_len=16,
                             method="greedy", paged=True, page_size=4,
                             n_pages=5)
    srv = WISPServer(eng, COEFFS, prefill="chunked", prefill_chunk_tokens=4)
    vt = lambda served: srv.scheduler.batch_time(served)
    assert srv.open_session(0, list(range(2, 14)), slo_class=3,
                            now=0.0).state == "prefilling"
    h1 = srv.open_session(1, list(range(20, 32)), slo_class=3, now=0.1)
    assert h1.state == "prefilling"
    t, epochs = 0.2, 0
    while 0 not in srv.sessions:
        srv.step(t, verify_time=vt)
        t += 0.01
        epochs += 1
        assert epochs < 20, "older prefill starved: admission livelock"
    # the younger session was preempted back to the admission queue (it
    # may already be re-prefilling on the freed slot, but it is not done)
    assert srv.prefill_preemptions >= 1
    assert 1 not in srv.sessions
    evs = srv.pop_events()
    assert [e.session_id for e in evs if e.kind == "PREEMPTED"] == [1]
    assert [e.session_id for e in evs if e.kind == "FIRST_TOKEN"] == [0]
    srv.close_session(0)
    epochs = 0
    while 1 not in srv.sessions:
        srv.step(t, verify_time=vt)
        t += 0.01
        epochs += 1
        assert epochs < 20, "preempted session never re-admitted"
    want = _engine(cfg, params, paged=True).new_session(
        list(range(20, 32)))[1]
    assert h1.first_token == want
    firsts = {e.session_id: e.token for e in srv.pop_events()
              if e.kind == "FIRST_TOKEN"}
    assert firsts[1] == want


def test_cluster_streams_invariant_to_prefill_mode(dense_model):
    """Fixed-work cluster runs under zero / monolithic / chunked prefill
    commit byte-identical streams (timing never reaches a sampling key);
    monolithic and chunked charge a nonzero TTFT, zero does not."""
    from repro.launch.serve import run_serving

    cfg, _, _ = dense_model
    slow = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=1e-6, c=1e-3)
    runs = {}
    for mode in ("zero", "monolithic", "chunked"):
        runs[mode] = run_serving(
            devices=2, rounds=2, k_max=3, verbose=False, seed=0,
            prompt_len=12, prefill_mode=mode, prefill_chunk_tokens=4,
            coeffs=slow,
        )
    streams = {
        mode: [list(d.session.committed) for d in r["result"].devices]
        for mode, r in runs.items()
    }
    assert streams["zero"] == streams["monolithic"] == streams["chunked"]
    ttft = {mode: [s.ttft for s in r["metrics"].sessions]
            for mode, r in runs.items()}
    assert all(v == 0.0 for v in ttft["zero"])
    assert all(v > 0.0 for v in ttft["monolithic"])
    assert all(v > 0.0 for v in ttft["chunked"])
    # the chunked server really chunked: 12-token prompts / 4-token chunks
    assert runs["chunked"]["server"].engine.stats["prefill_chunks"] \
        >= 2 * 3


def test_prefix_cache_stats_reports_backend(dense_model):
    """The dense backend has no prefix cache: its zeros are structural,
    and the backend field is how callers tell that apart from a measured
    0% hit rate (the paged backend reports real counters)."""
    cfg, _, params = dense_model
    dense = _engine(cfg, params, paged=False)
    paged = _engine(cfg, params, paged=True)
    assert dense.prefix_cache_stats()["backend"] == "dense"
    assert dense.stats["backend"] == "dense"
    st = paged.prefix_cache_stats()
    assert st["backend"] == "paged" and paged.stats["backend"] == "paged"
    paged.new_session([1, 2, 3, 4, 5])
    assert paged.prefix_cache_stats()["misses"] >= 1
    dense.new_session([1, 2, 3, 4, 5])
    assert dense.prefix_cache_stats()["hits"] == 0   # structurally zero
