"""Fallback shims for the optional ``hypothesis`` dependency.

Property-based tests import from here when ``hypothesis`` is missing so the
module still collects: ``@given`` replaces the test with a skipped stand-in,
``@settings`` is a no-op, and ``st`` is an "anything" object whose strategy
constructors (including ``st.composite``) return inert placeholders that can
be called or chained at module scope without blowing up.
"""
from __future__ import annotations

import pytest


class _Strategy:
    """Inert stand-in for any ``strategies`` attribute: calling it or
    accessing attributes on it just yields another stand-in, so strategy
    expressions evaluated at module import (``st.lists(st.integers(0, 4))``,
    ``@st.composite`` factories, ...) all resolve harmlessly."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self

    def __repr__(self):
        return "<hypothesis-stub strategy>"


st = _Strategy()


def given(*_args, **_kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed (property test)")
        def _skipped_property_test():
            pass  # pragma: no cover

        _skipped_property_test.__name__ = fn.__name__
        _skipped_property_test.__doc__ = fn.__doc__
        return _skipped_property_test

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


__all__ = ["given", "settings", "st"]
