"""WDT accounting (Eq. 7-10) + the Theorem-1 monotonicity property."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.core.wdt import IterationLog, WDTStats
from repro.sim.acceptance import AcceptanceModel, PredictorOperatingPoint


def _log(drafted, accepted, **kw):
    d = dict(
        session_id=0, round_index=0, n_drafted=drafted, n_sent=drafted,
        n_accepted=accepted, n_committed=accepted + 1,
        t_draft=drafted / 50.0, t_network=0.01, t_queue=0.02, t_verify=0.03,
    )
    d.update(kw)
    return IterationLog(**d)


def test_wdt_equations():
    it = _log(8, 3)
    assert it.wasted == 5                               # Eq. 7
    assert abs(it.wdt(1 / 50.0) - 5 / 50.0) < 1e-12     # Eq. 8
    assert abs(it.t_total - (8 / 50 + 0.01 + 0.02 + 0.03)) < 1e-12
    assert abs(it.token_speed - 4 / it.t_total) < 1e-9  # Eq. 4


def test_full_accept_no_waste():
    assert _log(8, 8).wasted == 0


@settings(max_examples=30, deadline=None)
@given(
    drafted=st.integers(0, 16),
    accepted=st.integers(0, 16),
)
def test_waste_nonnegative_bounded(drafted, accepted):
    accepted = min(accepted, drafted)
    it = _log(drafted, accepted)
    assert 0 <= it.wasted <= drafted


def test_stats_accumulate():
    s = WDTStats()
    s.add(_log(8, 4), tau_d=0.02)
    s.add(_log(8, 8), tau_d=0.02)
    assert s.iterations == 2
    assert s.drafted == 16 and s.accepted == 12
    assert s.wasted == 4
    assert abs(s.t_wdt - 4 * 0.02) < 1e-12
    assert abs(s.acceptance_rate - 12 / 16) < 1e-12
    assert abs(s.waste_fraction - 4 / 16) < 1e-12
    assert s.goodput(10.0) == s.committed / 10.0


@pytest.mark.parametrize("alpha", [0.6, 0.8, 0.9])
def test_theorem1_lower_fpr_less_waste(alpha):
    """Theorem 1: a predictor with lower false-alarm rate (FPR at the first
    true rejection) yields E[W_theta'] <= E[W_theta].  Checked empirically
    over matched random seeds."""
    def expected_waste(fpr, n=6000):
        m = AcceptanceModel(alpha, np.random.default_rng(123))
        pred = PredictorOperatingPoint(fpr=fpr, fnr=0.2)
        return np.mean(
            [m.draft_block(8, pred).wasted for _ in range(n)]
        )

    w = [expected_waste(f) for f in (0.9, 0.6, 0.3, 0.05)]
    # monotone non-increasing in FPR (small slack for MC noise)
    for a, b in zip(w, w[1:]):
        assert b <= a + 0.03, f"waste not monotone: {w}"


def test_theorem1_waste_requires_false_pass():
    """W > 0 only if the predictor passes the first true rejection
    (the necessary condition in the proof's Step 1)."""
    m = AcceptanceModel(0.7, np.random.default_rng(5))
    pred = PredictorOperatingPoint(fpr=0.0, fnr=0.3)   # never passes a reject
    for _ in range(2000):
        o = m.draft_block(8, pred)
        # flagged token is never sent, so waste is at most the flagged one
        assert o.wasted <= 1
        assert o.accept_len == o.n_sent
