"""Logical-axis sharding resolution: divisibility + uniqueness guards."""
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from jax.sharding import PartitionSpec as P

from repro.common.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    logical_to_spec,
    make_param_shardings,
)
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh():
    # single CPU device: mesh axes of size 1 exercise the rule plumbing
    return make_test_mesh(1, 1)


class FakeMesh:
    """Shape-only stand-in so guards can be tested against big meshes
    without 256 devices."""

    def __init__(self, **shape):
        self.shape = shape


def test_divisibility_guard_skips_nondivisible():
    mesh = FakeMesh(data=16, model=16)
    # 4 kv heads cannot shard over model=16 -> replicated
    spec = logical_to_spec(("layers", "act_batch", "act_cache", "act_kv", None),
                           (28, 128, 32768, 4, 128), mesh, SERVE_RULES)
    assert spec == P(None, "data", "model")
    # 64 query heads CAN shard over 16
    spec = logical_to_spec(("embed", "heads", "head_dim"),
                           (8192, 64, 128), mesh, TRAIN_RULES)
    assert spec == P("data", "model")


def test_uniqueness_guard_one_axis_per_tensor():
    mesh = FakeMesh(data=16, model=16)
    # vocab and mlp both want "model": first one wins
    spec = logical_to_spec(("vocab", "mlp"), (256000, 14336), mesh, TRAIN_RULES)
    assert spec == P("model")


def test_multi_axis_batch_sharding():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = logical_to_spec(("act_batch", "act_seq"), (256, 4096), mesh,
                           TRAIN_RULES)
    assert spec == P(("pod", "data"), "model")
    # batch not divisible by pod*data -> falls back to the divisible prefix
    # (single mesh axes are emitted unwrapped — P("pod") — matching the
    # module's convention; older jax PartitionSpec.__eq__ does not
    # normalize ("pod",) to "pod")
    spec = logical_to_spec(("act_batch", "act_seq"), (2, 4096), mesh,
                           TRAIN_RULES)
    assert spec == P("pod", "model")


def test_rank_mismatch_raises():
    mesh = FakeMesh(data=2)
    with pytest.raises(ValueError):
        logical_to_spec(("embed",), (8, 8), mesh, TRAIN_RULES)


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 128, 256]), min_size=1,
                  max_size=4),
    axes=st.lists(
        st.sampled_from(["embed", "heads", "mlp", "vocab", "act_batch", None]),
        min_size=1, max_size=4,
    ),
)
def test_spec_always_valid(dims, axes):
    n = min(len(dims), len(axes))
    dims, axes = dims[:n], axes[:n]
    mesh = FakeMesh(pod=2, data=4, model=4)
    spec = logical_to_spec(axes, dims, mesh, TRAIN_RULES)
    used = []
    for entry, dim in zip(tuple(spec), dims):
        if entry is None:
            continue
        group = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for ax in group:
            assert ax in mesh.shape
            prod *= mesh.shape[ax]
            used.append(ax)
        assert dim % prod == 0, "divisibility guard violated"
    assert len(used) == len(set(used)), "mesh axis reused within one tensor"


def test_make_param_shardings_tree(mesh):
    axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
    shapes = {
        "w": jax.ShapeDtypeStruct((8, 16), "float32"),
        "b": jax.ShapeDtypeStruct((16,), "float32"),
    }
    sh = make_param_shardings(axes, shapes, mesh, TRAIN_RULES)
    assert set(sh) == {"w", "b"}
    for v in jax.tree.leaves(sh):
        assert isinstance(v, jax.sharding.NamedSharding)
