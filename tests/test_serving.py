"""End-to-end serving integration: engine slot model, client/server loop,
greedy losslessness (speculative output == pure target decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine, VerifyItem
from repro.serving.server import WISPServer

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_pair():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    dparams = bundle.init(jax.random.PRNGKey(1), dtype=jnp.float32)
    return cfg, bundle, tparams, dparams


def _autoregressive_greedy(bundle, params, prompt, n_tokens, max_len=256):
    cfg = bundle.cfg
    cache = bundle.init_cache(1, max_len, dtype=jnp.float32)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = bundle.prefill(params, {"tokens": toks}, cache)
    out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
    pos = len(prompt)
    for _ in range(n_tokens - 1):
        lg, cache = bundle.decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache, jnp.int32(pos)
        )
        out.append(int(jnp.argmax(lg[0, -1])))
        pos += 1
    return out


@pytest.mark.slow
def test_greedy_speculative_is_lossless(dense_pair):
    """The WISP serve loop with greedy accept rule must emit EXACTLY the
    target model's greedy decode, token for token, regardless of the draft
    model — the core speculative-decoding guarantee."""
    cfg, bundle, tparams, dparams = dense_pair
    prompt = [3, 1, 4, 1, 5, 9]
    want = _autoregressive_greedy(bundle, tparams, prompt, 12)

    engine = VerificationEngine(
        cfg, tparams, max_slots=2, max_len=256, method="greedy"
    )
    server = WISPServer(engine, COEFFS)
    dev = EdgeDevice(cfg, dparams, k_max=4, greedy=True, max_len=256)
    first = server.open_session(0, prompt, slo_class=4).first_token
    dev.start_session(0, prompt, first)
    assert first == want[0]
    while len(dev.response_tokens) < len(want):
        res = dev.draft_round()
        server.submit(0, res.tokens, res.q_logits, now=0.0, t_draft=0.0,
                      t_network=0.0)
        (v,) = server.step(0.0)
        dev.apply_verdict(v.accept_len, v.token, res.tokens)
    assert dev.response_tokens[: len(want)] == want


def test_engine_slot_reuse_and_isolation(dense_pair):
    """Closing a session frees its slot; a new session on the reused slot
    must not see stale state."""
    cfg, bundle, tparams, _ = dense_pair
    engine = VerificationEngine(cfg, tparams, max_slots=1, max_len=128,
                                method="greedy")
    s1, t1 = engine.new_session([7, 8, 9])
    engine.close_session(s1)
    s2, t2 = engine.new_session([7, 8, 9])
    assert s1 == s2          # only one slot
    assert t1 == t2          # same prompt -> same first token (greedy)
    with pytest.raises(RuntimeError):
        engine.new_session([1, 2])   # slot exhausted


def test_engine_batched_verify_matches_solo(dense_pair):
    """Verification interference must not change *results*: a request
    verified in a batch gets the same accept/reject as verified alone."""
    cfg, bundle, tparams, dparams = dense_pair
    rng = np.random.default_rng(0)

    def fresh_engine():
        return VerificationEngine(cfg, tparams, max_slots=4, max_len=128,
                                  method="greedy")

    prompts = [[2, 3, 4], [9, 8, 7, 6], [5, 5, 5]]
    drafts = [rng.integers(0, cfg.vocab, size=k).astype(np.int32)
              for k in (3, 2, 4)]

    # solo
    solo = []
    for p, d in zip(prompts, drafts):
        eng = fresh_engine()
        slot, _ = eng.new_session(p)
        (o,) = eng.verify([VerifyItem(slot=slot, draft_tokens=d,
                                      q_logits=np.zeros((len(d), cfg.vocab),
                                                        np.float32))])
        solo.append((o.accept_len, o.token))

    # batched
    eng = fresh_engine()
    items = []
    for p, d in zip(prompts, drafts):
        slot, _ = eng.new_session(p)
        items.append(VerifyItem(slot=slot, draft_tokens=d,
                                q_logits=np.zeros((len(d), cfg.vocab),
                                                  np.float32)))
    outs = eng.verify(items)
    batched = [(o.accept_len, o.token) for o in outs]
    assert solo == batched


def test_server_tracks_committed_and_alpha(dense_pair):
    cfg, bundle, tparams, dparams = dense_pair
    engine = VerificationEngine(cfg, tparams, max_slots=2, max_len=128)
    server = WISPServer(engine, COEFFS)
    dev = EdgeDevice(cfg, dparams, k_max=3, max_len=128)
    first = server.open_session(0, [1, 2, 3], slo_class=2).first_token
    dev.start_session(0, [1, 2, 3], first)
    a0 = server.sessions[0].alpha
    for r in range(3):
        res = dev.draft_round()
        server.submit(0, res.tokens, res.q_logits, now=float(r),
                      t_draft=res.draft_time, t_network=0.01)
        (v,) = server.step(float(r))
        dev.apply_verdict(v.accept_len, v.token, res.tokens)
        # client and server agree on the committed stream length
        assert server.sessions[0].committed_len == len(dev.session.committed)
    assert server.sessions[0].rounds == 3
    server.close_session(0)
    assert 0 not in server.sessions
