"""Tiered KV spill/reload battery (DESIGN.md §12): state-machine
invariants of the HBM -> host-DRAM page tier — property-based where
hypothesis is available, example-based otherwise.

The four invariants the tier must hold under ANY schedule of
open/grow/publish/spill/reload/close operations:

  1. no page is ever simultaneously resident and spilled (a block-table
     ref is a device id >= 0 XOR a ``~handle`` < 0 with a live host
     entry — and each host entry has at most one table owner);
  2. refcounts never go negative (and free pages are refcount 0);
  3. prefix-reachable pages with refcount > 1 are pinned: they are never
     spilled or evicted while an unreferenced page is available;
  4. conservation — every non-free device page is reachable (scratch, a
     block table, or the prefix index) and every host entry is reachable
     (a block table or the prefix index): nothing leaks, nothing is
     double-owned, and device ``in_use + free == n_pages`` throughout.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.serving.kv_cache import (
    OutOfPages,
    PageFault,
    PagedKV,
    TierConfig,
    is_spilled,
)


def _mk_kv(n_pages=8, page_size=4, host_pages=8, quantize=False,
           idle_epochs=1):
    counters: dict = {}
    kv = PagedKV(
        2, n_pages, 2, 4, page_size=page_size, dtype=jnp.float32,
        tier=TierConfig(host_pages=host_pages, quantize=quantize,
                        idle_epochs=idle_epochs),
        counters=counters,
    )
    return kv, counters


def _check_invariants(kv: PagedKV):
    """The four DESIGN.md §12 invariants, checked structurally."""
    alloc = kv.allocator
    n = alloc.n_pages
    # (2) refcounts never negative; free list is duplicate-free refcount-0
    assert (alloc.refcount >= 0).all()
    assert len(set(alloc.free)) == len(alloc.free)
    for pid in alloc.free:
        assert alloc.refcount[pid] == 0
    # (1) every table ref is a live device page XOR a live host handle,
    # and no handle is referenced by two tables (spill is refcount-1 only)
    handles_referenced = []
    for t in kv.tables.values():
        for ref in t.pages:
            if is_spilled(ref):
                assert (~ref) in kv.tier.entries, "dangling spilled ref"
                handles_referenced.append(~ref)
            else:
                assert 0 <= ref < n and ref not in alloc.free, (
                    "resident ref points at a freed page"
                )
    assert len(handles_referenced) == len(set(handles_referenced)), (
        "one host entry referenced by two block tables"
    )
    # host entry ownership matches the tables that reference it
    for h, e in kv.tier.entries.items():
        if e.owner is not None:
            assert e.owner in kv.tables
            assert any(r == ~h for r in kv.tables[e.owner].pages), (
                "owned host entry not referenced by its owner's table"
            )
    # prefix index <-> page_hash stay a consistent bidirectional map,
    # and every index ref is live (resident or spilled)
    for hsh, ref in alloc.prefix_index.items():
        assert alloc.page_hash.get(ref) == hsh
        if is_spilled(ref):
            assert (~ref) in kv.tier.entries
        else:
            assert ref not in alloc.free
    for ref, hsh in alloc.page_hash.items():
        assert alloc.prefix_index.get(hsh) == ref
    # (4) conservation: device pool partitions exactly into free + reachable
    assert alloc.in_use + len(alloc.free) == n
    reachable = {kv.scratch_page}
    for t in kv.tables.values():
        reachable |= {r for r in t.pages if not is_spilled(r)}
    reachable |= {r for r in alloc.page_hash if r >= 0}
    assert reachable == set(range(n)) - set(alloc.free), (
        "leaked or phantom device pages"
    )
    host_reachable = set(handles_referenced) | {
        ~r for r in alloc.page_hash if is_spilled(r)
    }
    assert host_reachable == set(kv.tier.entries), "leaked host entries"
    assert 0 <= kv.tier.in_use <= kv.tier.cfg.host_pages
    # (3) shared prefix pages are pinned on device
    for t in kv.tables.values():
        for ref in t.pages:
            if not is_spilled(ref):
                continue
            # a spilled ref can never ALSO be shared: its device refcount
            # was 1 at spill time and the handle has a single table owner
    for pid in range(n):
        if alloc.refcount[pid] > 1:
            assert pid not in alloc.free


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.integers(0, 6), min_size=1, max_size=120),
       n_pages=st.integers(3, 7), host_pages=st.integers(1, 5),
       quantize=st.booleans())
def test_tier_state_machine_invariants(ops, n_pages, host_pages, quantize):
    """Any alloc/grow/publish/spill/reload/close schedule holds all four
    invariants after every step — including schedules where the device
    pool, the host pool, or both run out mid-operation."""
    rng = np.random.default_rng(0)
    kv, _ = _mk_kv(n_pages=n_pages, host_pages=host_pages,
                   quantize=quantize)
    toks: dict[int, list[int]] = {}
    next_sid = 0
    for op in ops:
        sids = list(kv.tables)
        sid = sids[int(rng.integers(len(sids)))] if sids else None
        try:
            if op == 0:  # open a new sequence (prefix lookup may page in)
                prompt = [int(x) for x in
                          rng.integers(1, 9, size=int(rng.integers(1, 10)))]
                n_cached = kv.open_seq(next_sid, prompt)
                toks[next_sid] = prompt
                kv.set_len(next_sid, n_cached)
                next_sid += 1
            elif op == 1 and sid is not None:  # grow by a few tokens
                grow = int(rng.integers(1, 6))
                toks[sid] = toks[sid] + [int(x) for x in
                                         rng.integers(1, 9, size=grow)]
                want = kv.seq_len(sid) + grow
                kv.ensure_capacity(sid, want)
                kv.set_len(sid, want)
            elif op == 2 and sid is not None:  # publish committed prefix
                kv.publish_seq_prefix(sid, toks[sid][: kv.seq_len(sid)])
            elif op == 3 and sid is not None:  # force-spill
                kv.spill_seq(sid)
            elif op == 4 and sid is not None:  # page back in
                kv.ensure_resident(sid)
            elif op == 5 and sid is not None:  # close (publish half the time)
                commit = toks[sid][: kv.seq_len(sid)] \
                    if rng.random() < 0.5 else None
                kv.close_seq(sid, commit)
                toks.pop(sid)
            elif op == 6:
                kv.tick()
        except OutOfPages:
            pass  # exhaustion must leave consistent, resumable state
        _check_invariants(kv)


# ---------------------------------------------------------------------------
# spill encodings: int8-when-bit-exact, raw fallback
# ---------------------------------------------------------------------------


def _grid_kv(rng):
    """K/V whose values are exact int multiples of ``amax/127`` (amax
    pinned to 127 per (k/v, layer) => scale exactly 1.0): the int8
    round-trip is bit-exact, so the quantized format is actually stored."""
    k = rng.integers(-127, 128, size=(2, 4, 2, 4)).astype(np.float32)
    v = rng.integers(-127, 128, size=(2, 4, 2, 4)).astype(np.float32)
    for layer in range(2):
        k[layer, 0, 0, 0] = 127.0
        v[layer, 0, 0, 0] = 127.0
    return k, v


def test_int8_spill_stored_when_roundtrip_exact():
    kv, counters = _mk_kv(quantize=True)
    k, v = _grid_kv(np.random.default_rng(3))
    kv.open_seq(1, [5])
    kv.write_tokens(1, 0, jnp.asarray(k), jnp.asarray(v))
    kv.set_len(1, 4)
    assert kv.spill_seq(1) == 1
    assert (counters["spills_quantized"], counters["spills_raw"]) == (1, 0)
    (entry,) = kv.tier.entries.values()
    assert entry.fmt == "int8"
    assert entry.nbytes < k.nbytes + v.nbytes      # ~4x smaller + scales
    assert kv.ensure_resident(1) == 1
    assert counters["pages_paged_in"] == 1
    kd, vd = kv.gather_dense(1, 4)
    np.testing.assert_array_equal(np.asarray(kd), k)
    np.testing.assert_array_equal(np.asarray(vd), v)


def test_lossy_int8_falls_back_to_raw():
    """Real float K/V does not round-trip int8 — the encoder must refuse
    the quantized format (storing it would perturb target logits and flip
    accept decisions at the margin) and keep exact raw bytes instead."""
    kv, counters = _mk_kv(quantize=True)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 4, 2, 4)).astype(np.float32)
    v = rng.normal(size=(2, 4, 2, 4)).astype(np.float32)
    kv.open_seq(1, [5])
    kv.write_tokens(1, 0, jnp.asarray(k), jnp.asarray(v))
    kv.set_len(1, 4)
    assert kv.spill_seq(1) == 1
    assert (counters["spills_quantized"], counters["spills_raw"]) == (0, 1)
    kv.ensure_resident(1)
    kd, vd = kv.gather_dense(1, 4)
    np.testing.assert_array_equal(np.asarray(kd), k)
    np.testing.assert_array_equal(np.asarray(vd), v)


def test_spill_reload_cycles_preserve_bytes_exactly():
    """Many spill/reload cycles (both formats) never drift a single byte
    — the byte-identity contract the golden battery rides on."""
    for quantize in (False, True):
        kv, _ = _mk_kv(quantize=quantize)
        rng = np.random.default_rng(7)
        k = rng.normal(size=(2, 8, 2, 4)).astype(np.float32)
        v = rng.normal(size=(2, 8, 2, 4)).astype(np.float32)
        kv.open_seq(1, [5])
        kv.write_tokens(1, 0, jnp.asarray(k), jnp.asarray(v))
        kv.set_len(1, 8)
        for _ in range(4):
            assert kv.spill_seq(1) == 2
            assert kv.ensure_resident(1) == 2
        kd, vd = kv.gather_dense(1, 8)
        np.testing.assert_array_equal(np.asarray(kd), k)
        np.testing.assert_array_equal(np.asarray(vd), v)


# ---------------------------------------------------------------------------
# eviction policy: pinned shared pages, LRU prefix-only host entries
# ---------------------------------------------------------------------------


def test_shared_prefix_pages_never_spill():
    """Refcount > 1 prefix pages (a hot shared system prompt) are pinned:
    force-spilling both sharers leaves the shared page resident."""
    kv, _ = _mk_kv(n_pages=10)
    prompt = list(range(8))                        # 2 full pages
    kv.open_seq(1, prompt)
    kv.ensure_capacity(1, 8)
    kv.set_len(1, 8)
    kv.publish_seq_prefix(1, prompt)
    kv.open_seq(2, prompt)                         # shares page 0
    kv.ensure_capacity(2, 8)
    kv.set_len(2, 8)
    shared = kv.tables[1].pages[0]
    assert kv.allocator.refcount[shared] == 2
    for sid in (1, 2):
        kv.spill_seq(sid)
    assert kv.tables[1].pages[0] == shared         # still resident
    assert kv.tables[2].pages[0] == shared
    assert not is_spilled(shared)
    # the private (refcount-1) pages DID spill
    assert kv.spilled_pages(1) >= 1


def test_host_pool_owned_entries_never_dropped():
    """A host entry holding a live sequence's only copy is unrecoverable
    state: when the host pool is full of owned entries, further spills
    are refused rather than destroying it."""
    kv, counters = _mk_kv(n_pages=10, host_pages=1)
    kv.open_seq(1, [1])
    kv.ensure_capacity(1, 4)
    kv.set_len(1, 4)
    assert kv.spill_seq(1) == 1                    # host slot now owned
    kv.open_seq(2, [2])
    kv.ensure_capacity(2, 4)
    kv.set_len(2, 4)
    assert kv.spill_seq(2) == 0                    # refused, not dropped
    assert counters["host_evictions"] == 0
    assert kv.spilled_pages(1) == 1                # seq 1 untouched


def test_host_pool_prefix_only_entries_evicted_lru():
    """Closing a session orphans its spilled pages to prefix-only
    ownership; those entries ARE droppable (they can be recomputed from
    tokens) and go LRU-first when the host pool needs room."""
    kv, counters = _mk_kv(n_pages=10, host_pages=1)
    kv.open_seq(1, [9])
    kv.ensure_capacity(1, 4)
    kv.set_len(1, 4)
    assert kv.spill_seq(1) == 1
    kv.close_seq(1, [1, 2, 3, 4])                  # spilled page -> prefix-only
    assert all(e.owner is None for e in kv.tier.entries.values())
    kv.open_seq(2, [8])
    kv.ensure_capacity(2, 4)
    kv.set_len(2, 4)
    assert kv.spill_seq(2) == 1                    # room made by dropping it
    assert counters["host_evictions"] == 1
    # the dropped entry's prefix-index entries went with it
    assert all(not is_spilled(r)
               for r in kv.allocator.prefix_index.values())


def test_lookup_pages_spilled_prefix_back_in():
    """A prefix-index entry pointing at a spilled page is still a cache
    HIT: open_seq pages it back onto the device transparently."""
    kv, counters = _mk_kv(n_pages=10)
    prompt = list(range(8))
    kv.open_seq(1, prompt)
    kv.ensure_capacity(1, 8)
    kv.set_len(1, 8)
    assert kv.spill_seq(1) == 2
    kv.close_seq(1, prompt)                        # publishes the ~handles
    assert any(is_spilled(r) for r in kv.allocator.prefix_index.values())
    n_cached = kv.open_seq(2, prompt)
    assert n_cached == 4                           # page-aligned: last given back
    assert counters["pages_paged_in"] >= 1
    assert all(not is_spilled(r) for r in kv.tables[2].pages)


def test_block_table_faults_on_spilled_ref():
    """The device hot path must never consume a spilled reference — the
    block-table staging raises PageFault instead of shipping a negative
    id to the kernel; ensure_resident clears it."""
    kv, _ = _mk_kv()
    kv.open_seq(1, [1])
    kv.ensure_capacity(1, 4)
    kv.set_len(1, 4)
    assert kv.spill_seq(1) == 1
    with pytest.raises(PageFault):
        kv.block_table([1], 2)
    kv.ensure_resident(1)
    bt = kv.block_table([1], 2)
    assert bt.shape == (1, 2) and bt[0, 0] > 0


def test_reclaim_spills_coldest_idle_sequence_first():
    """Device-pool exhaustion reclaims through the tier: the coldest
    sequence past ``idle_epochs`` spills (LRU by last-use epoch), while
    sequences touched this epoch are protected."""
    kv, counters = _mk_kv(n_pages=6, host_pages=8, idle_epochs=1)
    # 5 usable pages after scratch: two 2-page seqs + 1 free
    for sid in (1, 2):
        kv.open_seq(sid, [sid])
        kv.ensure_capacity(sid, 8)
        kv.set_len(sid, 8)
    kv.tick()
    kv.touch_seq(2)                                # seq 2 is hot
    kv.tick()
    # a third sequence needs 2 pages; only 1 is free -> reclaim spills
    # from seq 1 (idle 2 epochs), never from the just-touched seq 2
    kv.open_seq(3, [3])
    kv.ensure_capacity(3, 8)
    assert kv.spilled_pages(1) >= 1
    assert kv.spilled_pages(2) == 0
    assert counters["pages_spilled"] >= 1


def test_spillable_tokens_tracks_cold_pages_and_host_headroom():
    """The scheduler's widened memory budget only counts pages the tier
    could actually absorb: cold refcount-1 pages, capped by host room."""
    kv, _ = _mk_kv(n_pages=10, host_pages=1, idle_epochs=1)
    kv.open_seq(1, [1])
    kv.ensure_capacity(1, 8)                       # 2 private pages
    kv.set_len(1, 8)
    assert kv.spillable_tokens() == 0              # not idle yet
    kv.tick()
    # idle now, but the host pool only has room for ONE of the two pages
    assert kv.spillable_tokens() == 1 * kv.page_size
