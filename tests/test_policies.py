"""Scheduling-policy registry + typed server-event stream (docs/API.md).

Three guarantee families:

  * **policy-generic properties** — every registered policy's
    ScheduleDecision respects the memory budget, the batch-size cap and
    estimator batch-time consistency, on arbitrary mixed pools;
  * **event-stream ordering** — per session: ADMITTED before everything,
    exactly one FIRST_TOKEN, no VERDICT before FIRST_TOKEN, CLOSED last;
  * **channel equivalence** — the legacy shims (open_session handle /
    ``step()`` verdict list / ``pop_admissions()`` / ``prefill_log``)
    and ``pop_events()`` report byte-identical token streams across
    {monolithic, chunked} prefill x all registered policies, in both the
    functional server and (via the lock-step reference driver, a legacy-
    channel consumer) the event-driven cluster runtime.
"""
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    POLICIES,
    PrefillChunkWork,
    SchedulerConfig,
    SLOScheduler,
    VerifyRequest,
    VerifyWork,
    available_policies,
    make_policy,
)
from repro.models import build
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer

COEFFS = EstimatorCoeffs(a=3.3e-5, b_compute=3.5e-8, b_read=4.6e-6, c=0.015)
RUN_COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = bundle.init(jax.random.PRNGKey(0))
    dparams = bundle.init(jax.random.PRNGKey(1))
    return cfg, tparams, dparams


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents_and_aliases():
    assert available_policies() == ["edf", "fcfs", "priority", "wfq", "wisp"]
    assert POLICIES["slo"] is POLICIES["wisp"] is SLOScheduler
    assert POLICIES["fair"] is POLICIES["wfq"]
    p = make_policy("slo", SchedulerConfig(), COEFFS)
    assert p.name == "wisp"                 # alias resolves to canonical
    # instances and classes pass through
    assert make_policy(p, SchedulerConfig(), COEFFS) is p
    assert isinstance(make_policy(SLOScheduler, SchedulerConfig(), COEFFS),
                      SLOScheduler)
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("lifo", SchedulerConfig(), COEFFS)


def test_work_item_hierarchy_and_factory_shim():
    """The legacy VerifyRequest(kind=...) constructor dispatches to the
    class hierarchy; scheduling fields and pricing are unchanged."""
    v = VerifyRequest(req_id=1, session_id=1, slo_class=0, arrival=0.0,
                      deadline=1.0, draft_len=6, cached_len=200, alpha=0.5)
    assert isinstance(v, VerifyWork) and v.kind == "verify"
    assert v.new_tokens == 7 and v.goodput_value == 0.5 * 6 + 1.0
    c = VerifyRequest(req_id=2, session_id=2, slo_class=0, arrival=0.0,
                      deadline=1.0, cached_len=64, prefill_tokens=32,
                      kind="prefill")
    assert isinstance(c, PrefillChunkWork) and c.kind == "prefill"
    assert c.new_tokens == 32 and c.goodput_value == 1.0
    assert c.batch_shape().cached_tokens == 64


# ---------------------------------------------------------------------------
# policy-generic properties
# ---------------------------------------------------------------------------
@st.composite
def mixed_pool(draw):
    """A pool mixing verify work and prefill chunks (arbitrary shapes)."""
    n = draw(st.integers(1, 24))
    reqs = []
    for i in range(n):
        if draw(st.booleans()):
            reqs.append(VerifyWork(
                req_id=i, session_id=i,
                slo_class=draw(st.integers(1, 4)),
                arrival=draw(st.floats(0, 1)),
                deadline=draw(st.floats(0.01, 3.0)),
                draft_len=draw(st.integers(1, 16)),
                cached_len=draw(st.integers(0, 4000)),
                alpha=draw(st.floats(0.1, 0.95)),
            ))
        else:
            reqs.append(PrefillChunkWork(
                req_id=i, session_id=i,
                slo_class=draw(st.integers(1, 4)),
                arrival=draw(st.floats(0, 1)),
                deadline=draw(st.floats(0.01, 3.0)),
                cached_len=draw(st.integers(0, 512)),
                prefill_tokens=draw(st.integers(1, 512)),
            ))
    return reqs


@settings(max_examples=25, deadline=None)
@given(pool=mixed_pool(), t_k=st.floats(0, 2.0),
       budget=st.integers(500, 40_000))
def test_every_policy_respects_budget_and_estimator(pool, t_k, budget):
    """Registry-generic invariants: for EVERY registered policy, the
    decision draws from the pool without duplicates, respects the
    per-epoch memory budget override and the batch-size cap, and reports
    the estimator's batch time for the batch it chose."""
    cfg = SchedulerConfig(memory_budget_tokens=20_000, max_batch_requests=8)
    for name in available_policies():
        s = make_policy(name, cfg, COEFFS)
        d = s.schedule(pool, t_k, memory_budget_tokens=budget)
        ids = [r.req_id for r in d.batch]
        assert len(ids) == len(set(ids))
        assert set(ids) <= {r.req_id for r in pool}
        assert len(d.batch) <= cfg.max_batch_requests
        assert s.memory_tokens(d.batch) <= budget
        assert d.memory_budget_tokens == budget
        assert d.policy == name
        # est_time is the estimator's prediction for exactly this batch
        assert d.est_time == pytest.approx(s.batch_time(d.batch))


def test_edf_orders_by_deadline():
    cfg = SchedulerConfig(max_batch_requests=2)
    s = make_policy("edf", cfg, COEFFS)
    mk = lambda i, dl: VerifyWork(req_id=i, session_id=i, slo_class=2,
                                  arrival=0.0, deadline=dl, draft_len=4,
                                  cached_len=10, alpha=0.5)
    d = s.schedule([mk(1, 3.0), mk(2, 1.0), mk(3, 2.0)], 0.0)
    assert [r.req_id for r in d.batch] == [2, 3]


def test_priority_orders_by_slo_class_then_deadline():
    cfg = SchedulerConfig(max_batch_requests=2)
    s = make_policy("priority", cfg, COEFFS)
    mk = lambda i, cls, dl: VerifyWork(req_id=i, session_id=i, slo_class=cls,
                                       arrival=0.0, deadline=dl, draft_len=4,
                                       cached_len=10, alpha=0.5)
    # class 1 outranks class 2 regardless of deadline; EDF within class
    d = s.schedule([mk(1, 2, 0.1), mk(2, 1, 5.0), mk(3, 1, 2.0)], 0.0)
    assert [r.req_id for r in d.batch] == [3, 2]


# ---------------------------------------------------------------------------
# event-stream ordering
# ---------------------------------------------------------------------------
def _assert_stream_ordered(events):
    """Per-session lifecycle ordering (docs/API.md)."""
    seen: dict[int, list] = {}
    for ev in events:
        seen.setdefault(ev.session_id, []).append(ev.kind)
    for sid, kinds in seen.items():
        admitted_at = kinds.index("ADMITTED") if "ADMITTED" in kinds else None
        firsts = [i for i, k in enumerate(kinds) if k == "FIRST_TOKEN"]
        verdicts = [i for i, k in enumerate(kinds) if k == "VERDICT"]
        if firsts or verdicts:
            assert admitted_at is not None, f"session {sid}: no ADMITTED"
            # only tenancy THROTTLED may precede ADMITTED (a held open
            # throttles first); REJECTED sessions never admit at all
            assert all(k == "THROTTLED" for k in kinds[:admitted_at]), \
                f"session {sid}: ADMITTED not first"
        assert len(firsts) <= 1, f"session {sid}: multiple FIRST_TOKEN"
        if verdicts:
            assert firsts and firsts[0] < verdicts[0], \
                f"session {sid}: VERDICT before FIRST_TOKEN"
        if "CLOSED" in kinds:
            assert kinds.index("CLOSED") == len(kinds) - 1, \
                f"session {sid}: events after CLOSED"


@pytest.mark.parametrize("policy", ["wisp", "fcfs", "edf", "priority", "wfq"])
def test_event_stream_ordered_chunked_flow(dense_model, policy):
    """Chunked prefill + verification + close under every policy emits an
    ordered stream: one ADMITTED first, exactly one FIRST_TOKEN, no
    VERDICT before it, CLOSED last."""
    from repro.serving.client import EdgeDevice

    cfg, tparams, dparams = dense_model
    eng = VerificationEngine(cfg, tparams, max_slots=2, max_len=128,
                             method="greedy", paged=True, page_size=4)
    srv = WISPServer(eng, RUN_COEFFS, policy=policy, prefill="chunked",
                     prefill_chunk_tokens=8)
    dev = EdgeDevice(cfg, dparams, k_max=3, max_len=128, greedy=True)
    h = srv.open_session(0, list(range(2, 22)), slo_class=2, now=0.0)
    t = 0.0
    while h.state == "prefilling":
        srv.step(t, verify_time=lambda served: srv.scheduler.batch_time(served))
        t += 0.01
    dev.start_session(0, list(range(2, 22)), h.first_token)
    for _ in range(2):
        res = dev.draft_round()
        srv.submit(0, res.tokens, res.q_logits, now=t, t_draft=0.0,
                   t_network=0.0)
        (v,) = srv.step(t)
        dev.apply_verdict(v.accept_len, v.token, res.tokens)
        t += 0.01
    srv.close_session(0)
    events = srv.pop_events()
    assert [e.kind for e in events if e.session_id == 0][-1] == "CLOSED"
    _assert_stream_ordered(events)


# ---------------------------------------------------------------------------
# channel equivalence: legacy shims vs pop_events()
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefill", ["monolithic", "chunked"])
@pytest.mark.parametrize("policy", ["wisp", "fcfs", "edf", "priority", "wfq"])
def test_functional_server_channels_agree(dense_model, policy, prefill):
    """One server, two observers: the committed token stream read off the
    legacy channels (handle first_token + step() verdict list) must be
    byte-identical to the stream read off pop_events(), for every policy
    x prefill mode."""
    from repro.serving.client import EdgeDevice

    cfg, tparams, dparams = dense_model
    eng = VerificationEngine(cfg, tparams, max_slots=2, max_len=128)
    srv = WISPServer(eng, RUN_COEFFS, policy=policy, prefill=prefill,
                     prefill_chunk_tokens=4)
    dev = EdgeDevice(cfg, dparams, k_max=3, max_len=128)
    prompt = list(range(3, 13))
    h = srv.open_session(0, prompt, slo_class=2, now=0.0)
    t = 0.0
    while h.state == "prefilling":
        srv.step(t, verify_time=lambda served: srv.scheduler.batch_time(served))
        t += 0.01
    dev.start_session(0, prompt, h.first_token)

    legacy_stream = [h.first_token]
    drafts = []
    for _ in range(3):
        res = dev.draft_round()
        drafts.append([int(x) for x in res.tokens])
        srv.submit(0, res.tokens, res.q_logits, now=t, t_draft=0.0,
                   t_network=0.0)
        (v,) = srv.step(t)                   # legacy channel: return list
        dev.apply_verdict(v.accept_len, v.token, res.tokens)
        legacy_stream.extend(drafts[-1][:v.accept_len])
        legacy_stream.append(int(v.token))
        t += 0.01

    # second observer: replay the SAME run purely off the event stream
    events = srv.pop_events()
    event_stream = [e.token for e in events if e.kind == "FIRST_TOKEN"]
    verdict_events = [e.verdict for e in events if e.kind == "VERDICT"]
    assert len(verdict_events) == len(drafts)
    for d, v in zip(drafts, verdict_events):
        event_stream.extend(d[:v.accept_len])
        event_stream.append(int(v.token))
    assert dev.session.committed[len(prompt):] == legacy_stream
    assert event_stream == legacy_stream


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["wisp", "fcfs", "edf", "priority", "wfq"])
def test_cluster_streams_match_lockstep_per_policy(dense_model, policy):
    """The event-driven cluster runtime (a pop_events() consumer) and the
    lock-step reference (a legacy-shim consumer) commit byte-identical
    per-session streams for every registered policy."""
    from repro.launch.serve import run_serving

    kw = dict(devices=2, rounds=2, k_max=3, seed=0, verbose=False,
              policy=policy)
    ev = run_serving(sync=False, **kw)
    sy = run_serving(sync=True, **kw)
    for i, (de, ds) in enumerate(zip(ev["edges"], sy["edges"])):
        assert de.response_tokens == ds.response_tokens, (policy, i)
    assert ev["server"].policy == sy["server"].policy == \
        ("wisp" if policy == "slo" else policy)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["fcfs", "priority"])
def test_cluster_streams_invariant_to_prefill_mode_per_policy(dense_model,
                                                              policy):
    """Prefill-mode invariance (timing never reaches a sampling key)
    holds under baseline policies too, not just wisp."""
    from repro.launch.serve import run_serving

    slow = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=1e-6, c=1e-3)
    streams = {}
    for mode in ("monolithic", "chunked"):
        r = run_serving(devices=2, rounds=2, k_max=3, verbose=False, seed=0,
                        prompt_len=12, prefill_mode=mode, policy=policy,
                        prefill_chunk_tokens=4, coeffs=slow)
        streams[mode] = [list(d.session.committed)
                         for d in r["result"].devices]
    assert streams["monolithic"] == streams["chunked"]


def test_admission_queue_survives_session_id_reuse():
    """Regression: tombstones are keyed per entry, not per session id —
    cancel a queued session, reuse its id for a new one, cancel that too:
    neither entry may ever be admitted (an id-keyed tombstone set would
    absorb the second cancel and ghost-admit the closed session)."""
    from repro.serving.server import AdmissionQueue

    q = AdmissionQueue()
    q.push((0, "first"))
    assert q.cancel(0)                  # close while queued
    q.push((0, "second"))               # id reused by a new session
    assert 0 in q and len(q) == 1
    assert q.cancel(0)                  # close that one too
    assert q.peek() is None and len(q) == 0 and not q
    # and the mixed case: a live entry behind a dead reused id still pops
    q.push((1, "a"))
    q.cancel(1)
    q.push((1, "b"))
    assert q.peek() == (1, "b") and q.popleft() == (1, "b")
    assert len(q) == 0


def test_deprecated_scheduler_kwarg_still_works(dense_model):
    cfg, tparams, _ = dense_model
    eng = VerificationEngine(cfg, tparams, max_slots=1, max_len=64)
    with pytest.warns(DeprecationWarning):
        srv = WISPServer(eng, RUN_COEFFS, scheduler="fcfs")
    assert srv.policy == "fcfs"


def test_fcfs_cluster_crosschecks_against_sim(dense_model):
    """--policy fcfs acceptance: the functional stack's FCFS goodput and
    violation metrics cross-check against repro.sim's FCFS system at the
    observed acceptance rate (same policy code on both engines; analytic
    prediction within a loose band of the measurement)."""
    from benchmarks.goodput import run_cluster

    rows = run_cluster(quick=True, policies=["fcfs"])
    (meas,) = [r for r in rows if r["engine"] == "cluster"]
    (pred,) = [r for r in rows if r["engine"] == "sim-crosscheck"]
    assert meas["policy"] == pred["policy"] == "fcfs"
    per_dev = meas["goodput_tok_s"] / meas["n_devices"]
    assert pred["predicted_device_goodput_tok_s"] == pytest.approx(
        per_dev, rel=1.0
    )
    assert 0.0 <= pred["predicted_violation_rate"] <= 1.0
    assert pred["predicted_waste_fraction"] == pytest.approx(
        meas["waste_fraction"], abs=0.35
    )
