"""The lossless accept/reject rule (paper Eq. 1-3): semantics + the
distribution-preservation property that makes speculative decoding exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.core.speculative import (
    committed_tokens,
    speculative_verify,
    wasted_tokens,
)


def _mk_logits(rng, B, K, V, sharp=1.0):
    return jnp.asarray(rng.normal(size=(B, K, V)) * sharp, jnp.float32)


# ---------------------------------------------------------------------------
# invariants (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    B=st.integers(1, 4),
    K=st.integers(1, 8),
    V=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
    method=st.sampled_from(["residual", "target", "greedy"]),
)
def test_verify_invariants(B, K, V, seed, method):
    rng = np.random.default_rng(seed)
    draft = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
    dlen = jnp.asarray(rng.integers(0, K + 1, size=B), jnp.int32)
    q = _mk_logits(rng, B, K, V)
    p = _mk_logits(rng, B, K + 1, V)
    out = speculative_verify(
        jax.random.PRNGKey(seed), draft, dlen, q, p, method=method
    )
    L = np.asarray(out["accept_len"])
    tok = np.asarray(out["token"])
    mask = np.asarray(out["accept_mask"])
    emitted = np.asarray(out["num_emitted"])
    dl = np.asarray(dlen)
    # 0 <= L <= draft_len
    assert (L >= 0).all() and (L <= dl).all()
    # emitted = L + 1
    assert (emitted == L + 1).all()
    # accepted mask: exactly L leading positions within the valid prefix
    assert (mask.sum(axis=1) == L).all()
    for b in range(B):
        assert mask[b, : L[b]].all()
        assert not mask[b, L[b]:].any()
    # token in vocab
    assert (tok >= 0).all() and (tok < V).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_greedy_accepts_iff_argmax(seed):
    rng = np.random.default_rng(seed)
    B, K, V = 3, 6, 17
    draft = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
    dlen = jnp.full((B,), K, jnp.int32)
    q = _mk_logits(rng, B, K, V)
    p = _mk_logits(rng, B, K + 1, V)
    out = speculative_verify(
        jax.random.PRNGKey(0), draft, dlen, q, p, method="greedy"
    )
    am = np.asarray(jnp.argmax(p[:, :K], axis=-1))
    L = np.asarray(out["accept_len"])
    d = np.asarray(draft)
    for b in range(B):
        expect = 0
        while expect < K and d[b, expect] == am[b, expect]:
            expect += 1
        assert L[b] == expect
        # correction token is the target argmax at the stop position
        assert np.asarray(out["token"])[b] == np.asarray(
            jnp.argmax(p[b, L[b]])
        )


def test_wasted_and_committed_helpers():
    draft = jnp.asarray([[5, 6, 7], [8, 9, 10]], jnp.int32)
    L = jnp.asarray([1, 3], jnp.int32)
    tok = jnp.asarray([99, 100], jnp.int32)
    out = np.asarray(committed_tokens(draft, L, tok))
    assert out[0, :2].tolist() == [5, 99]
    assert out[1, :4].tolist() == [8, 9, 10, 100]
    w = np.asarray(wasted_tokens(jnp.asarray([3, 3]), L))
    assert w.tolist() == [2, 0]


# ---------------------------------------------------------------------------
# losslessness: the committed-token marginal equals the target distribution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sharp_q", [0.5, 2.0])
def test_residual_rule_preserves_target_distribution(sharp_q):
    """With K=1 the first committed token of each round must be an exact
    sample from p regardless of q (Leviathan Thm. 1).  Empirical TV distance
    over many trials must be small."""
    rng = np.random.default_rng(0)
    V = 8
    trials = 4000
    q_logits = jnp.asarray(rng.normal(size=(1, 1, V)) * sharp_q, jnp.float32)
    p_logits = jnp.asarray(rng.normal(size=(1, 2, V)), jnp.float32)
    p = np.asarray(jax.nn.softmax(p_logits[0, 0]))
    q = np.asarray(jax.nn.softmax(q_logits[0, 0]))

    counts = np.zeros(V)
    key = jax.random.PRNGKey(0)
    for t in range(trials):
        key, kd, kv = jax.random.split(key, 3)
        # draft token ~ q
        y = jax.random.categorical(kd, q_logits[0, 0])
        out = speculative_verify(
            kv,
            y.reshape(1, 1).astype(jnp.int32),
            jnp.asarray([1], jnp.int32),
            q_logits,
            p_logits,
            method="residual",
        )
        L = int(out["accept_len"][0])
        first = int(y) if L >= 1 else int(out["token"][0])
        counts[first] += 1
    emp = counts / trials
    tv = 0.5 * np.abs(emp - p).sum()
    assert tv < 0.05, f"TV distance {tv:.3f} too large; emp={emp}, p={p}"


def test_target_method_biased_but_valid():
    """The paper's Eq. (3) as written (sample from p at the stop position)
    still emits valid tokens; kept as an ablation — just check it runs."""
    rng = np.random.default_rng(1)
    B, K, V = 2, 4, 11
    out = speculative_verify(
        jax.random.PRNGKey(1),
        jnp.asarray(rng.integers(0, V, (B, K)), jnp.int32),
        jnp.asarray([4, 2], jnp.int32),
        _mk_logits(rng, B, K, V),
        _mk_logits(rng, B, K + 1, V),
        method="target",
    )
    assert out["token"].shape == (B,)


def test_full_accept_bonus_token():
    """If p == q and u ~ U(0,1) <= 1 always accepts, L == draft_len and the
    bonus token comes from p[:, L]."""
    B, K, V = 2, 3, 5
    rng = np.random.default_rng(2)
    q = _mk_logits(rng, B, K, V)
    p = jnp.concatenate([q, _mk_logits(rng, B, 1, V)], axis=1)
    draft = jnp.asarray(rng.integers(0, V, (B, K)), jnp.int32)
    dlen = jnp.full((B,), K, jnp.int32)
    out = speculative_verify(jax.random.PRNGKey(3), draft, dlen, q, p)
    assert (np.asarray(out["accept_len"]) == K).all()
