"""Shared golden-stream scenario for the hot-path refactor regression suite.

One deterministic serving run per (backend, policy, prefill-mode) cell:
two sessions open against a tiny engine, then a fixed number of
synthetic draft rounds flow through ``WISPServer.submit`` -> ``step``.
Draft tokens and q-logits are derived from seeded generators keyed by
(session, round) only — NOT from the committed stream — so every cell is
a pure function of (engine seed, rng tags, model params) and the streams
can be captured once and replayed across refactors.

``python tests/_golden_scenario.py`` (re)generates
``tests/golden/streams.json`` — run it BEFORE a hot-path refactor to pin
the seed behavior, never after (the whole point is catching drift).
Verification is ``method="residual"`` with ``deterministic_verify=True``
(rng-tagged rows): the accept draws AND the residual correction sampling
are exercised, which is exactly the math the fused dispatch must
preserve bit-for-bit.
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.models import build
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "streams.json")

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)

#: backend name -> (config name, engine kwargs)
BACKENDS = {
    "dense": ("qwen2-7b", {"paged": False}),
    "paged": ("qwen2-7b", {"paged": True, "page_size": 4}),
    "recurrent": ("xlstm-350m", {}),
}
POLICIES = ("wisp", "fcfs")
PREFILL_MODES = ("monolithic", "chunked")

PROMPTS = {0: [1, 2, 3, 4, 5, 6], 1: [7, 8, 9, 3, 2, 1]}
ROUNDS = 4
K = 3


@functools.lru_cache(maxsize=None)
def _model_for(name: str):
    cfg = get_config(name).reduced()
    bundle = build(cfg)
    if cfg.family in ("ssm", "hybrid"):
        params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    else:
        params = bundle.init(jax.random.PRNGKey(0))
    return cfg, params


def _draft_for(vocab: int, sid: int, rnd: int):
    """Synthetic draft block keyed by (session, round) only."""
    rng = np.random.default_rng(10_000 + 997 * sid + rnd)
    toks = rng.integers(0, vocab, size=K).astype(np.int32)
    qlog = (rng.normal(size=(K, vocab)) * 1.5).astype(np.float32)
    return toks, qlog


def run_scenario(backend: str, policy: str, prefill: str,
                 *, rounds: int = ROUNDS):
    """Returns {session_id: committed token stream (list[int])}."""
    name, ekw = BACKENDS[backend]
    cfg, params = _model_for(name)
    kw = dict(ekw)
    if cfg.family in ("ssm", "hybrid"):
        kw["cache_dtype"] = jnp.float32
    engine = VerificationEngine(
        cfg, params, max_slots=4, max_len=128, method="residual", seed=7, **kw
    )
    server = WISPServer(
        engine, COEFFS, policy=policy, prefill=prefill,
        prefill_chunk_tokens=4,
    )
    now = 0.0
    streams: dict[int, list[int]] = {}
    for sid, prompt in PROMPTS.items():
        server.open_session(sid, prompt, slo_class=2, now=now)
    # chunked mode: pump dispatch epochs until every prompt finished
    while len(server.sessions) < len(PROMPTS):
        server.step(now)
        now += 0.005
    for ev in server.pop_events():
        if ev.kind == "FIRST_TOKEN":
            streams[ev.session_id] = [int(ev.token)]
    assert set(streams) == set(PROMPTS), "every session must have a first token"

    for rnd in range(rounds):
        drafts = {}
        for sid in PROMPTS:
            toks, qlog = _draft_for(cfg.vocab, sid, rnd)
            drafts[sid] = toks
            server.submit(sid, toks, qlog, now=now, t_draft=0.02,
                          t_network=0.01)
        while server.queue_depth:
            verdicts = server.step(now)
            now += 0.005
            for v in verdicts:
                toks = drafts[v.session_id]
                streams[v.session_id].extend(
                    int(t) for t in toks[: v.accept_len]
                )
                streams[v.session_id].append(int(v.token))
        server.pop_events()
    return {str(sid): s for sid, s in streams.items()}


def all_cells():
    for backend in BACKENDS:
        for policy in POLICIES:
            for prefill in PREFILL_MODES:
                yield backend, policy, prefill


def generate() -> dict:
    out = {}
    for backend, policy, prefill in all_cells():
        key = f"{backend}/{policy}/{prefill}"
        out[key] = run_scenario(backend, policy, prefill)
        print(f"{key}: "
              + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    return out


if __name__ == "__main__":
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    streams = generate()
    with open(GOLDEN_PATH, "w") as f:
        json.dump(streams, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")
