"""Shared golden-stream scenario for the hot-path refactor regression suite.

One deterministic serving run per (backend, policy, prefill-mode) cell:
two sessions open against a tiny engine, then a fixed number of
synthetic draft rounds flow through ``WISPServer.submit`` -> ``step``.
Draft tokens and q-logits are derived from seeded generators keyed by
(session, round) only — NOT from the committed stream — so every cell is
a pure function of (engine seed, rng tags, model params) and the streams
can be captured once and replayed across refactors.

``python tests/_golden_scenario.py`` (re)generates
``tests/golden/streams.json`` — run it BEFORE a hot-path refactor to pin
the seed behavior, never after (the whole point is catching drift).
Verification is ``method="residual"`` with ``deterministic_verify=True``
(rng-tagged rows): the accept draws AND the residual correction sampling
are exercised, which is exactly the math the fused dispatch must
preserve bit-for-bit.
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.models import build
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "streams.json")

COEFFS = EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3)

#: backend name -> (config name, engine kwargs)
BACKENDS = {
    "dense": ("qwen2-7b", {"paged": False}),
    "paged": ("qwen2-7b", {"paged": True, "page_size": 4}),
    "recurrent": ("xlstm-350m", {}),
}
POLICIES = ("wisp", "fcfs")
PREFILL_MODES = ("monolithic", "chunked")

PROMPTS = {0: [1, 2, 3, 4, 5, 6], 1: [7, 8, 9, 3, 2, 1]}
#: third session for the mixed-K and fleet cells (three streams make the
#: ragged batches / routing assignments less degenerate than two)
EXTRA_PROMPT = [4, 4, 2, 6, 9, 5]
ROUNDS = 4
K = 3


@functools.lru_cache(maxsize=None)
def _model_for(name: str):
    cfg = get_config(name).reduced()
    bundle = build(cfg)
    if cfg.family in ("ssm", "hybrid"):
        params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    else:
        params = bundle.init(jax.random.PRNGKey(0))
    return cfg, params


def _draft_for(vocab: int, sid: int, rnd: int):
    """Synthetic draft block keyed by (session, round) only."""
    rng = np.random.default_rng(10_000 + 997 * sid + rnd)
    toks = rng.integers(0, vocab, size=K).astype(np.int32)
    qlog = (rng.normal(size=(K, vocab)) * 1.5).astype(np.float32)
    return toks, qlog


def mixed_k_for(sid: int, rnd: int) -> int:
    """Deterministic ragged draft length for the mixed-K cells: every
    round batches sessions at DIFFERENT K (adaptive speculation makes
    this the normal shape of a dispatch epoch, DESIGN.md §11)."""
    return 1 + (sid + rnd) % 4


def _draft_ragged(vocab: int, sid: int, rnd: int):
    """Synthetic draft block with per-(session, round) draft length."""
    k = mixed_k_for(sid, rnd)
    rng = np.random.default_rng(20_000 + 997 * sid + rnd)
    toks = rng.integers(0, vocab, size=k).astype(np.int32)
    qlog = (rng.normal(size=(k, vocab)) * 1.5).astype(np.float32)
    return toks, qlog


def run_scenario(backend: str, policy: str, prefill: str,
                 *, rounds: int = ROUNDS, engine_overrides: dict | None = None,
                 spill_between_rounds: bool = False):
    """Returns {session_id: committed token stream (list[int])}.

    ``engine_overrides`` adds/overrides engine kwargs (the tiered cells
    attach a host spill pool this way); ``spill_between_rounds``
    force-spills every session's pages to the host tier after each round
    drains, so the next round's verify must page them back in mid-stream
    — the spill/reload battery's byte-identity requirement (DESIGN.md
    §12) is that this changes NOTHING about the committed streams."""
    name, ekw = BACKENDS[backend]
    cfg, params = _model_for(name)
    kw = dict(ekw)
    if cfg.family in ("ssm", "hybrid"):
        kw["cache_dtype"] = jnp.float32
    kw.update(engine_overrides or {})
    engine = VerificationEngine(
        cfg, params, max_slots=4, max_len=128, method="residual", seed=7, **kw
    )
    server = WISPServer(
        engine, COEFFS, policy=policy, prefill=prefill,
        prefill_chunk_tokens=4,
    )
    now = 0.0
    streams: dict[int, list[int]] = {}
    for sid, prompt in PROMPTS.items():
        server.open_session(sid, prompt, slo_class=2, now=now)
    # chunked mode: pump dispatch epochs until every prompt finished
    while len(server.sessions) < len(PROMPTS):
        server.step(now)
        now += 0.005
    for ev in server.pop_events():
        if ev.kind == "FIRST_TOKEN":
            streams[ev.session_id] = [int(ev.token)]
    assert set(streams) == set(PROMPTS), "every session must have a first token"

    for rnd in range(rounds):
        drafts = {}
        for sid in PROMPTS:
            toks, qlog = _draft_for(cfg.vocab, sid, rnd)
            drafts[sid] = toks
            server.submit(sid, toks, qlog, now=now, t_draft=0.02,
                          t_network=0.01)
        while server.queue_depth:
            verdicts = server.step(now)
            now += 0.005
            for v in verdicts:
                toks = drafts[v.session_id]
                streams[v.session_id].extend(
                    int(t) for t in toks[: v.accept_len]
                )
                streams[v.session_id].append(int(v.token))
        server.pop_events()
        if spill_between_rounds:
            for sid in PROMPTS:
                engine.spill_session(server.sessions[sid].slot)
    if spill_between_rounds:
        # the cell must actually exercise a mid-stream spill + reload —
        # a no-op spill would make the byte-identity assertion vacuous
        assert engine.stats["pages_spilled"] > 0, "nothing spilled"
        assert engine.stats["pages_paged_in"] > 0, "nothing paged back in"
    return {str(sid): s for sid, s in streams.items()}


def run_tiered_scenario(quantize: bool, *, rounds: int = ROUNDS):
    """Forced-spill-then-reload mid-stream on the paged backend with a
    host tier attached ({raw, int8-quantize-on} spill formats).  Must
    replay byte-identical to the untiered ``paged/wisp/monolithic``
    baseline cell: spill encodings page back in bit-exactly (int8 is
    stored only when its dequantization round-trips, DESIGN.md §12), so
    tiering can never perturb the accept rule or the correction draws."""
    return run_scenario(
        "paged", "wisp", "monolithic", rounds=rounds,
        engine_overrides={"kv_tier_pages": 64, "spill_quantize": quantize,
                          "spill_idle_epochs": 2},
        spill_between_rounds=True,
    )


def run_mixed_k_scenario(backend: str, *, rounds: int = ROUNDS):
    """Ragged-K variant (adaptive speculation, DESIGN.md §11): three
    sessions submit blocks of DIFFERENT length every round, so each
    dispatch epoch verifies a mixed-K padded batch.  Chunked prefill
    keeps prefill work interleaving with the ragged verify batches."""
    name, ekw = BACKENDS[backend]
    cfg, params = _model_for(name)
    kw = dict(ekw)
    if cfg.family in ("ssm", "hybrid"):
        kw["cache_dtype"] = jnp.float32
    engine = VerificationEngine(
        cfg, params, max_slots=4, max_len=128, method="residual", seed=7, **kw
    )
    server = WISPServer(
        engine, COEFFS, policy="wisp", prefill="chunked",
        prefill_chunk_tokens=4,
    )
    prompts = {**PROMPTS, 2: EXTRA_PROMPT}
    now = 0.0
    streams: dict[int, list[int]] = {}
    for sid, prompt in prompts.items():
        server.open_session(sid, prompt, slo_class=2, now=now)
    while len(server.sessions) < len(prompts):
        server.step(now)
        now += 0.005
    for ev in server.pop_events():
        if ev.kind == "FIRST_TOKEN":
            streams[ev.session_id] = [int(ev.token)]
    assert set(streams) == set(prompts)

    for rnd in range(rounds):
        drafts = {}
        for sid in prompts:
            toks, qlog = _draft_ragged(cfg.vocab, sid, rnd)
            drafts[sid] = toks
            server.submit(sid, toks, qlog, now=now, t_draft=0.02,
                          t_network=0.01)
        while server.queue_depth:
            verdicts = server.step(now)
            now += 0.005
            for v in verdicts:
                toks = drafts[v.session_id]
                streams[v.session_id].extend(
                    int(t) for t in toks[: v.accept_len]
                )
                streams[v.session_id].append(int(v.token))
        server.pop_events()
    assert engine.stats["mixed_k_batches"] > 0, \
        "the mixed-K cell never actually batched ragged draft lengths"
    return {str(sid): s for sid, s in streams.items()}


def run_fleet_scenario(*, verifiers: int = 3, rounds: int = ROUNDS,
                       migrate_round: int = 1):
    """Three sessions over a 3-verifier prefix-locality fleet (dense
    backend), with session 0 force-migrated off its healthy owner after
    ``migrate_round`` — pinning the ``restore_session`` committed-stream
    replay path (incl. the replicated alpha/spec_k speculation context)
    byte-for-byte, without depending on failure-detection timing."""
    from repro.fleet import build_verifier_fleet

    cfg, params = _model_for(BACKENDS["dense"][0])
    router = build_verifier_fleet(
        cfg, params, verifiers, COEFFS, max_slots=4, max_len=128,
        method="residual", policy="wisp", engine_seed=7,
    )
    prompts = {**PROMPTS, 2: EXTRA_PROMPT}
    now = 0.0
    streams: dict[int, list[int]] = {}
    for sid, prompt in prompts.items():
        router.open_session(sid, prompt, slo_class=2, now=now)
    for _, ev in router.pop_events():
        if ev.kind == "FIRST_TOKEN":
            streams[ev.session_id] = [int(ev.token)]
    assert set(streams) == set(prompts)

    for rnd in range(rounds):
        drafts = {}
        for sid in prompts:
            toks, qlog = _draft_for(cfg.vocab, sid, rnd)
            drafts[sid] = toks
            router.submit(sid, toks, qlog, now=now, t_draft=0.02,
                          t_network=0.01)
        while any(router.queue_depth(v) for v in router.verifiers):
            for vid in list(router.verifiers):
                for v in router.step(vid, now):
                    toks = drafts[v.session_id]
                    streams[v.session_id].extend(
                        int(t) for t in toks[: v.accept_len]
                    )
                    streams[v.session_id].append(int(v.token))
            now += 0.005
        router.pop_events()
        if rnd == migrate_round:
            committed = list(prompts[0]) + streams[0]
            src = router.owner[0]
            dst, _ = router.migrate_session(0, committed, rounds=rnd + 1,
                                            now=now)
            assert dst != src
    assert router.stats["migrations"] >= 1
    return {str(sid): s for sid, s in streams.items()}


def run_tenant_scenario(*, rounds: int = ROUNDS):
    """Tenant-tagged twin of the ``dense/wisp/monolithic`` baseline cell:
    the same two sessions open tagged with two tenants (weights 2 / 1,
    unlimited default buckets) under the ``"wfq"`` policy.  With no
    contention the tenancy subsystem must be inert: admission is all
    ADMIT (no throttle events) and rng-tagged verification keys draws by
    (session, committed-prefix) only, so the committed streams must stay
    BYTE-IDENTICAL to the untagged baseline (DESIGN.md §13)."""
    from repro.tenancy import TenantSpec

    cfg, params = _model_for(BACKENDS["dense"][0])
    engine = VerificationEngine(
        cfg, params, max_slots=4, max_len=128, method="residual", seed=7
    )
    server = WISPServer(
        engine, COEFFS, policy="wfq", prefill="monolithic",
        prefill_chunk_tokens=4,
        tenants=[TenantSpec("alpha", weight=2.0), TenantSpec("beta")],
    )
    tenant_of = {0: "alpha", 1: "beta"}
    now = 0.0
    streams: dict[int, list[int]] = {}
    for sid, prompt in PROMPTS.items():
        server.open_session(sid, prompt, slo_class=2, now=now,
                            tenant=tenant_of[sid])
    for ev in server.pop_events():
        if ev.kind == "FIRST_TOKEN":
            streams[ev.session_id] = [int(ev.token)]
        assert ev.kind not in ("THROTTLED", "REJECTED"), \
            "unlimited tenants must never throttle"
    assert set(streams) == set(PROMPTS)

    for rnd in range(rounds):
        drafts = {}
        for sid in PROMPTS:
            toks, qlog = _draft_for(cfg.vocab, sid, rnd)
            drafts[sid] = toks
            server.submit(sid, toks, qlog, now=now, t_draft=0.02,
                          t_network=0.01)
        while server.queue_depth:
            verdicts = server.step(now)
            now += 0.005
            for v in verdicts:
                toks = drafts[v.session_id]
                streams[v.session_id].extend(
                    int(t) for t in toks[: v.accept_len]
                )
                streams[v.session_id].append(int(v.token))
        server.pop_events()
    return {str(sid): s for sid, s in streams.items()}


def all_cells():
    for backend in BACKENDS:
        for policy in POLICIES:
            for prefill in PREFILL_MODES:
                yield backend, policy, prefill


def generate() -> dict:
    out = {}
    for backend, policy, prefill in all_cells():
        key = f"{backend}/{policy}/{prefill}"
        out[key] = run_scenario(backend, policy, prefill)
        print(f"{key}: "
              + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    for backend in BACKENDS:
        key = f"mixed-k/{backend}"
        out[key] = run_mixed_k_scenario(backend)
        print(f"{key}: "
              + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    key = "fleet/3-verifier"
    out[key] = run_fleet_scenario()
    print(f"{key}: "
          + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    for fmt, quantize in (("raw", False), ("int8", True)):
        key = f"tiered/{fmt}"
        out[key] = run_tiered_scenario(quantize)
        print(f"{key}: "
              + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    key = "tenant/wfq"
    out[key] = run_tenant_scenario()
    print(f"{key}: "
          + ", ".join(f"s{sid}:{len(s)} tok" for sid, s in out[key].items()))
    return out


if __name__ == "__main__":
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    streams = generate()
    # additive-only guard: cells captured at earlier seeds must never be
    # silently regenerated — drift there is exactly what the suite exists
    # to catch
    if os.path.exists(GOLDEN_PATH):
        with open(GOLDEN_PATH) as f:
            old = json.load(f)
        drifted = sorted(k for k in old if streams.get(k) != old[k])
        assert not drifted, f"existing golden cells drifted: {drifted}"
    with open(GOLDEN_PATH, "w") as f:
        json.dump(streams, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")
