"""SLO-aware scheduler (Algorithm 1) invariants + FCFS baseline."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: degrade property tests to skips
    from _hypothesis_stub import given, settings, st

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    FCFSScheduler,
    SchedulerConfig,
    SLOScheduler,
    VerifyRequest,
)

COEFFS = EstimatorCoeffs(a=3.3e-5, b_compute=3.5e-8, b_read=4.6e-6, c=0.015)


def mk_req(i, *, arrival=0.0, deadline=1.0, draft=6, cached=200, alpha=0.8,
           prefill=0):
    return VerifyRequest(
        req_id=i, session_id=i, slo_class=0, arrival=arrival,
        deadline=deadline, draft_len=draft, cached_len=cached, alpha=alpha,
        prefill_tokens=prefill, enqueued_at=arrival,
    )


@st.composite
def request_pool(draw):
    n = draw(st.integers(1, 24))
    reqs = []
    for i in range(n):
        reqs.append(
            mk_req(
                i,
                arrival=draw(st.floats(0, 1)),
                deadline=draw(st.floats(0.01, 3.0)),
                draft=draw(st.integers(1, 16)),
                cached=draw(st.integers(0, 4000)),
                alpha=draw(st.floats(0.1, 0.95)),
            )
        )
    return reqs


@settings(max_examples=40, deadline=None)
@given(pool=request_pool(), t_k=st.floats(0, 2.0))
def test_slo_schedule_respects_constraints(pool, t_k):
    cfg = SchedulerConfig(memory_budget_tokens=20_000, max_batch_requests=8)
    s = SLOScheduler(cfg, COEFFS)
    d = s.schedule(pool, t_k)
    # batch drawn from pending, no duplicates
    ids = [r.req_id for r in d.batch]
    assert len(ids) == len(set(ids))
    assert set(ids) <= {r.req_id for r in pool}
    # memory + size constraints
    assert len(d.batch) <= cfg.max_batch_requests
    assert s.memory_tokens(d.batch) <= cfg.memory_budget_tokens
    # every *winnable* admitted request still meets its deadline per the
    # estimator (doomed requests are exempt — they violate regardless)
    t_batch = s.batch_time(d.batch)
    for r in d.batch:
        doomed = t_k + s.v_hat(r) + cfg.guard_time > r.deadline
        if not doomed:
            assert t_k + t_batch + cfg.guard_time <= r.deadline + 1e-9


def test_critical_fast_path_prioritizes_edf():
    """A critical (near-LST) request must preempt higher-utility ones."""
    cfg = SchedulerConfig(max_batch_requests=1)
    s = SLOScheduler(cfg, COEFFS)
    crit = mk_req(1, deadline=0.08, draft=2, cached=100, alpha=0.2)   # low U
    rich = mk_req(2, deadline=5.0, draft=16, cached=0, alpha=0.95)    # high U
    d = s.schedule([rich, crit], t_k=0.05)
    assert [r.req_id for r in d.batch] == [1]
    assert d.critical == 1


def test_best_effort_fill_orders_by_utility():
    cfg = SchedulerConfig(max_batch_requests=2)
    s = SLOScheduler(cfg, COEFFS)
    lo = mk_req(1, deadline=10.0, draft=2, cached=3000, alpha=0.2)
    hi = mk_req(2, deadline=10.0, draft=12, cached=10, alpha=0.9)
    mid = mk_req(3, deadline=10.0, draft=8, cached=100, alpha=0.6)
    d = s.schedule([lo, hi, mid], t_k=0.0)
    assert [r.req_id for r in d.batch] == [2, 3]


def test_doomed_requests_still_get_served():
    """Requests past their deadline must not starve (they batch with the
    best-effort fill instead of blocking the critical path)."""
    cfg = SchedulerConfig()
    s = SLOScheduler(cfg, COEFFS)
    dead = mk_req(1, deadline=0.001, draft=4)
    live = mk_req(2, deadline=5.0, draft=4)
    d = s.schedule([dead, live], t_k=1.0)
    assert {r.req_id for r in d.batch} == {1, 2}


def test_fcfs_orders_by_arrival():
    cfg = SchedulerConfig(max_batch_requests=2)
    s = FCFSScheduler(cfg, COEFFS)
    a = mk_req(1, arrival=0.3)
    b = mk_req(2, arrival=0.1)
    c = mk_req(3, arrival=0.2)
    d = s.schedule([a, b, c], t_k=1.0)
    assert [r.req_id for r in d.batch] == [2, 3]


def test_memory_budget_blocks_admission():
    cfg = SchedulerConfig(memory_budget_tokens=500)
    s = SLOScheduler(cfg, COEFFS)
    big = mk_req(1, cached=480, draft=4, deadline=10.0)
    other = mk_req(2, cached=480, draft=4, deadline=10.0)
    d = s.schedule([big, other], t_k=0.0)
    assert len(d.batch) == 1


def test_sled_uncached_request_costs_prefill():
    """prefill_tokens inflate new_tokens (SLED semantics) and the estimate."""
    cached = mk_req(1, cached=1000, draft=6, prefill=0)
    uncached = mk_req(2, cached=0, draft=6, prefill=1000)
    assert uncached.new_tokens == 1007 and cached.new_tokens == 7
    s = SLOScheduler(SchedulerConfig(), COEFFS)
    assert s.v_hat(uncached) > s.v_hat(cached)


def mk_chunk(i, *, deadline, cached=0, chunk=256, arrival=0.0):
    """A chunked-prefill work item (kind="prefill", TTFT deadline)."""
    return VerifyRequest(
        req_id=i, session_id=i, slo_class=0, arrival=arrival,
        deadline=deadline, draft_len=0, cached_len=cached, alpha=0.0,
        prefill_tokens=chunk, kind="prefill", enqueued_at=arrival,
    )


def test_prefill_chunk_shape_and_pricing():
    """A chunk feeds exactly its prompt tokens (no draft, no re-fed last
    token), is priced by the same estimator, and values one first token."""
    c = mk_chunk(1, deadline=5.0, cached=512, chunk=256)
    assert c.new_tokens == 256
    assert c.goodput_value == 1.0
    assert c.batch_shape().cached_tokens == 512
    s = SLOScheduler(SchedulerConfig(), COEFFS)
    assert s.v_hat(c) > s.v_hat(mk_chunk(2, deadline=5.0, chunk=16))


def test_critical_verify_preempts_best_effort_prefill_chunk():
    """Interference suppression for cold prompts (DESIGN.md §8): a
    deadline-critical verification request must be admitted ahead of a
    best-effort prefill chunk — the chunk waits for a later epoch, which
    is exactly the preemption point chunking creates."""
    cfg = SchedulerConfig(max_batch_requests=1)
    s = SLOScheduler(cfg, COEFFS)
    chunk = mk_chunk(1, deadline=10.0, chunk=512)          # big, far TTFT
    crit = mk_req(2, deadline=0.08, draft=2, cached=100, alpha=0.2)
    d = s.schedule([chunk, crit], t_k=0.05)
    assert [r.req_id for r in d.batch] == [2]
    assert d.critical == 1 and d.skipped_infeasible >= 1


def test_prefill_chunk_goes_critical_near_ttft_deadline():
    """As its TTFT deadline nears, a chunk enters the EDF fast path like
    any other request — long prompts are starvable only until their LST."""
    cfg = SchedulerConfig(max_batch_requests=1)
    s = SLOScheduler(cfg, COEFFS)
    chunk = mk_chunk(1, deadline=0.14, chunk=64)           # LST imminent
    rich = mk_req(2, deadline=10.0, draft=16, cached=0, alpha=0.95)
    d = s.schedule([chunk, rich], t_k=0.1)
    assert [r.req_id for r in d.batch] == [1]
    assert d.critical == 1
