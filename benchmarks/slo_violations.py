"""Paper Table 1 + Fig. 7: per-class SLO violation rates, WISP vs FCFS
verification, swept over device count (the violation 'knee')."""
from __future__ import annotations

from repro.sim import simulate, wisp
from repro.sim.config import SLO_SPEEDS
from repro.sim.systems import fcfs_cached


def run(quick: bool = True) -> list[dict]:
    sim_time = 60.0 if quick else 180.0
    sweep = (32, 96, 160, 224, 288) if quick else (32, 64, 96, 128, 160, 192, 224, 288)
    rows = []
    for N in sweep:
        w = simulate(wisp(N, sim_time=sim_time))
        f = simulate(fcfs_cached(N, sim_time=sim_time))
        for speed in SLO_SPEEDS:
            rows.append(
                {
                    "table": "slo_violations(T1/F7)",
                    "n_devices": N,
                    "slo_tok_s": speed,
                    "wisp_violation": round(w.violation_rate(speed), 4),
                    "fcfs_violation": round(f.violation_rate(speed), 4),
                }
            )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
