"""Paper Fig. 8: violation attribution in the (t_queue, t_verify) plane —
compute-dominant (verify-time spike, Eq. 21 rho > 1.5) vs queue-dominant."""
from __future__ import annotations

import numpy as np

from repro.sim import simulate, wisp


def run(quick: bool = True) -> list[dict]:
    sim_time = 40.0 if quick else 150.0
    N = 224
    r = simulate(wisp(N, sim_time=sim_time))
    att = r.attribution(window=32, rho=1.5)
    viol = [a for a in att if a["violated"]]
    n_comp = sum(a["kind"] == "compute" for a in viol)
    n_queue = sum(a["kind"] == "queue" for a in viol)
    tq = np.array([a["t_queue"] for a in att])
    tv = np.array([a["t_verify"] for a in att])
    return [
        {
            "table": "attribution(F8)",
            "n_devices": N,
            "events": len(att),
            "violations": len(viol),
            "compute_dominant": n_comp,
            "queue_dominant": n_queue,
            "compute_share": round(n_comp / max(len(viol), 1), 3),
            "mean_t_queue_ms": round(float(tq.mean()) * 1e3, 2),
            "p99_t_queue_ms": round(float(np.percentile(tq, 99)) * 1e3, 2),
            "mean_t_verify_ms": round(float(tv.mean()) * 1e3, 2),
            "p99_t_verify_ms": round(float(np.percentile(tv, 99)) * 1e3, 2),
        }
    ]


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
