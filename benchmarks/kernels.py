"""Kernel microbenchmarks: analytic FLOPs/bytes + arithmetic intensity per
Pallas kernel across serving-relevant shapes, and interpret-mode correctness
deltas vs the jnp oracle.  (Wall-clock on this CPU container is meaningless
for TPU kernels — the roofline terms are the performance artifact; see
benchmarks/roofline.py for the compiled-HLO numbers.)"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels.verify_attention.ops import (
    verify_attention_op,
    verify_attention_ref,
)

V5E_FLOPS = 197e12
V5E_HBM = 819e9


def _verify_attention_cost(B, Hq, Hkv, K, S, D, dtype_bytes=2):
    flops = 2 * 2 * B * Hq * K * S * D            # qk + av
    bytes_rw = (
        B * S * Hkv * D * 2 * dtype_bytes         # stream K and V once
        + B * K * Hq * D * 2 * dtype_bytes        # read Q, write O
    )
    return flops, bytes_rw


def run(quick: bool = True) -> list[dict]:
    rows = []
    shapes = [
        ("decode_1", 8, 32, 8, 1, 4096, 128),
        ("verify_k8", 8, 32, 8, 9, 4096, 128),
        ("verify_k8_32k", 4, 32, 8, 9, 32768, 128),
        ("prefill_tail", 1, 32, 8, 512, 32768, 128),
    ]
    for name, B, Hq, Hkv, K, S, D in shapes:
        flops, byts = _verify_attention_cost(B, Hq, Hkv, K, S, D)
        ai = flops / byts
        ridge = V5E_FLOPS / V5E_HBM
        rows.append(
            {
                "table": "kernels",
                "kernel": "verify_attention",
                "shape": name,
                "gflops": round(flops / 1e9, 2),
                "mbytes": round(byts / 1e6, 2),
                "arith_intensity": round(ai, 2),
                "v5e_ridge_point": round(ridge, 1),
                "bound": "compute" if ai > ridge else "memory",
                "t_roofline_us": round(
                    max(flops / V5E_FLOPS, byts / V5E_HBM) * 1e6, 2
                ),
            }
        )
    # correctness deltas on a reduced shape (interpret mode, this container)
    rng = np.random.default_rng(0)
    B, Hq, Hkv, K, S, D = 2, 4, 2, 8, 512, 64
    q = jnp.asarray(rng.normal(size=(B, K, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([500, 384], jnp.int32)
    out = verify_attention_op(q, k, v, lengths)
    ref = verify_attention_ref(q, k, v, lengths)
    rows.append(
        {
            "table": "kernels",
            "kernel": "verify_attention",
            "shape": "correctness",
            "max_abs_err": float(jnp.max(jnp.abs(out - ref))),
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
