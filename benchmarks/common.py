"""Benchmark harness plumbing: every table module exposes
``run(quick=True) -> list[dict]``; rows carry a ``table`` key."""
from __future__ import annotations

import json
import os
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def save_rows(name: str, rows: list[dict]):
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def print_rows(rows: list[dict]):
    for r in rows:
        parts = [f"{k}={v}" for k, v in r.items()]
        print(",".join(parts), flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
