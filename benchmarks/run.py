"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sweeps
    PYTHONPATH=src python -m benchmarks.run --only capacity goodput
    PYTHONPATH=src python -m benchmarks.run --only goodput wdt ttft \\
        --policy wisp fcfs edf priority        # one sweep, all policies

Prints ``key=value`` CSV rows per table and writes JSON artifacts under
``artifacts/bench/``.  ``--policy`` is forwarded to every benchmark whose
``run()`` accepts a ``policies`` argument (goodput / wdt / ttft); those
emit the policy name into each result row.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import time
import traceback

from benchmarks.common import print_rows, save_rows
from repro.core.scheduler import available_policies

#: module -> paper reference
TABLES = {
    "kernels": "kernel microbench (roofline terms per kernel)",
    "roofline": "dry-run roofline, all (arch x shape x mesh) cells",
    "estimator": "Tables 7/12 + App. C (verification-time estimator)",
    "wdt": "Fig. 1 (WDT vs device goodput)",
    "slo_violations": "Table 1 + Fig. 7 (violation rates / knee)",
    "attribution": "Fig. 8 (queue-vs-compute violation attribution)",
    "goodput": "Table 3 (system goodput)",
    "predictor": "Tables 4/10/11 + Figs. 2-3 (rejection predictor)",
    "predictor_ablation": "Tables 5/6 (predictor ON/OFF ablations)",
    "capacity": "Table 2 (system capacity per SLO class)",
    "paged_serving": "§4.5 (dense vs paged engine: throughput + prefix hits)",
    "ttft": "long-prompt interference: monolithic vs chunked prefill (§8)",
    "hotpath": "verification hot-path budgets: dispatches + bytes (§9)",
    "adaptive_k": "§4.1 (static vs adaptive per-session draft length)",
    "tiered_kv": "§12 (tiered KV admission capacity at 25% device pool)",
    "fleet": "§10 (fleet goodput under verifier churn)",
    "tenancy": "§13 (multi-tenant isolation under adversarial flood)",
    "chaos": "§14 (goodput under edge-link loss: hardened vs no-retry)",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--policy", nargs="+", default=None,
                    choices=available_policies(),
                    help="scheduling policies to sweep in the benchmarks "
                         "that support it (rows carry the policy name)")
    args = ap.parse_args()

    names = args.only or list(TABLES)
    failures = []
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        print(f"# === {name}: {TABLES.get(name, '')} ===", flush=True)
        t0 = time.time()
        kwargs = {"quick": not args.full}
        if (args.policy
                and "policies" in inspect.signature(mod.run).parameters):
            kwargs["policies"] = args.policy
        try:
            rows = mod.run(**kwargs)
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            print(f"# {name} FAILED: {e!r}", flush=True)
            continue
        print_rows(rows)
        save_rows(name, rows)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
