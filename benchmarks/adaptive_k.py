"""Adaptive speculation depth: static-K vs per-session dynamic K (§4.1).

A/B on the same seed and workload (session churn until ``--horizon``)
over a deliberately heterogeneous edge fleet — draft speeds spanning
~an order of magnitude and per-device link RTTs from LAN to congested
wireless — against a saturating verifier:

  * ``static-K``   — every block drafts ``k_max`` tokens (legacy);
  * ``adaptive-K`` — the ``adaptive`` speculation controller
    (core/speculation.py, DESIGN.md §11) picks each session's next
    draft length from the calibrated acceptance estimate, measured
    draft+uplink RTT, and the verifier queue depth piggybacked on
    every verdict.

Two acceptance bars ride this table:

  1. **goodput** — adaptive-K strictly out-serves static-K on the
     heterogeneous fleet: slow devices stop burning their draft budget
     on tokens the verifier would truncate, and a deep verifier queue
     talks every session's K down before waste compounds (Eq. 7).
  2. **bytes** — adapting K moves *when* blocks are cut, never *what*
     gets committed: a fixed-work adaptive run is replayed through the
     committed-prefix oracle (serving/oracle.py) session by session,
     and every stream must match byte-identically.
"""
from __future__ import annotations

import argparse

import jax

from repro.cluster import ClusterConfig, build_fleet
from repro.configs import get_config
from repro.core.estimator import EstimatorCoeffs
from repro.launch.serve import run_serving
from repro.models import build
from repro.serving.oracle import replay_session

#: saturating epoch pricing (same rationale as benchmarks/fleet.py): the
#: reduced model's analytic coefficients never load the verifier, and an
#: idle verifier makes every K look free — queue pressure must be real
#: for the load term of the control law to have anything to suppress
COEFFS = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=2e-5, c=8e-3)

#: the heterogeneous edge: a 12 tok/s phone, a mid-range tablet, a fast
#: workstation — and links from LAN (4 ms) to congested wireless (80 ms)
DRAFT_SPEEDS = (12.0, 30.0, 90.0)
LINK_RTTS = (0.004, 0.02, 0.08)


def _measure(*, spec_policy, devices, horizon, seed, policy, k_max):
    r = run_serving(
        devices=devices, policy=policy, verbose=False, seed=seed,
        churn=True, horizon=horizon, k_max=k_max, coeffs=COEFFS,
        draft_speeds=DRAFT_SPEEDS, link_rtts=LINK_RTTS,
        spec_policy=spec_policy,
        prefill_mode="chunked", prefill_chunk_tokens=16,
    )
    m = r["metrics"]
    ks = [it.k_used for it in m.iterations if it.k_used]
    row = {
        "goodput_tok_s": round(m.goodput(r["result"].horizon), 2),
        "sessions": len(m.sessions),
        "violations": m.violations(),
        "waste_fraction": round(m.waste_fraction(), 3),
        "mean_k": round(sum(ks) / max(len(ks), 1), 2),
        "k_min": min(ks, default=0),
        "k_max_used": max(ks, default=0),
        "mixed_k_batches": r["server"].engine.stats["mixed_k_batches"],
    }
    return row, m


def _check_oracle(*, devices, rounds, seed, k_max) -> int:
    """Fixed-work adaptive run, then replay every session ALONE through
    the committed-prefix oracle under its recorded K schedule — the
    streams must match byte for byte.  Returns sessions checked."""
    r = run_serving(
        devices=devices, rounds=rounds, k_max=k_max, seed=seed,
        verbose=False, spec_policy="adaptive", draft_speeds=DRAFT_SPEEDS,
        link_rtts=LINK_RTTS, coeffs=COEFFS, max_len=128, prompt_len=6,
    )
    m, edges = r["metrics"], r["edges"]
    tcfg = get_config("qwen2-7b").reduced()
    tparams = build(tcfg).init(jax.random.PRNGKey(seed))
    dparams = build(tcfg).init(jax.random.PRNGKey(seed + 1))
    ccfg = ClusterConfig(devices=devices, rounds=rounds, k_max=k_max,
                         seed=seed, prompt_len=6, max_len=128)
    fleet = build_fleet(ccfg, tcfg.vocab)
    for s in m.sessions:
        its = sorted((it for it in m.iterations
                      if it.session_id == s.session_id),
                     key=lambda it: it.round_index)
        sched = [it.k_used for it in its]
        got = replay_session(
            tcfg, tparams, tcfg, dparams, prompt=fleet[s.device].prompt,
            k_schedule=sched, session_id=s.session_id,
            device_seed=seed + 10 + s.device, engine_seed=0, max_len=128,
        )
        want = [int(t) for t in edges[s.device].response_tokens]
        assert got == want, (
            f"adaptive-K session {s.session_id} diverged from its "
            f"committed-prefix oracle replay (schedule {sched}): "
            f"{got[:8]} vs {want[:8]}"
        )
    return len(m.sessions)


def run(quick: bool = True, policies: list | None = None) -> list[dict]:
    devices = 6 if quick else 12
    horizon = 1.0 if quick else 4.0
    k_max = 6
    seed = 0
    rows = []
    for policy in policies or ["wisp"]:
        static, _ = _measure(spec_policy="static", devices=devices,
                             horizon=horizon, seed=seed, policy=policy,
                             k_max=k_max)
        adaptive, m = _measure(spec_policy="adaptive", devices=devices,
                               horizon=horizon, seed=seed, policy=policy,
                               k_max=k_max)
        for system, row in (("static-K", static), ("adaptive-K", adaptive)):
            rows.append({"table": "adaptive_k", "system": system,
                         "policy": policy, "n_devices": devices,
                         "horizon_s": horizon, **row})
        for cls, agg in m.per_class().items():
            rows.append({"table": "adaptive_k(per-class)",
                         "system": "adaptive-K", "policy": policy,
                         "slo_class": cls, **{
                             k: round(v, 3) if isinstance(v, float) else v
                             for k, v in agg.items()}})
        # acceptance bar 1: dynamic K strictly out-serves the legacy
        # fixed-K loop on the heterogeneous fleet
        assert adaptive["goodput_tok_s"] > static["goodput_tok_s"], (
            f"adaptive-K goodput ({adaptive['goodput_tok_s']}) must beat "
            f"static-K ({static['goodput_tok_s']}) [policy={policy}]"
        )
        assert adaptive["k_min"] < k_max, \
            "adaptive controller never moved K off k_max"
    # acceptance bar 2: adapting K never changes committed bytes
    checked = _check_oracle(devices=3, rounds=3 if quick else 6,
                            seed=seed, k_max=4)
    rows.append({"table": "adaptive_k(oracle)", "system": "adaptive-K",
                 "sessions_checked": checked, "byte_identical": True})
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows, save_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", nargs="+", default=None,
                    help="scheduling policies to sweep (default: wisp)")
    args = ap.parse_args()
    rows = run(quick=not args.full, policies=args.policy)
    save_rows("adaptive_k", rows)
    print_rows(rows)
