"""Goodput under edge-link chaos (repro.chaos, ISSUE 10).

Three measured rows on the same seed and workload (session churn until
``--horizon``), all under the SAME seeded fault schedule except the
clean baseline:

  * ``clean``     — reliable link, no retries needed (the ceiling);
  * ``hardened``  — lossy/flapping link + the full recovery stack:
    per-round timeout with exponential backoff, idempotent
    re-submission, verdict replay/dedup, and link-health speculative
    degradation (K shrinks under flap, K=1 while the link is down);
  * ``ablation``  — the same faults with the recovery stack OFF (no
    retries, no degradation): a dropped message stalls its session
    until the horizon.

The acceptance bar this table pins (ISSUE 10): hardened degraded-mode
goodput must be at least ``1.3x`` the ablation's — retrying and
degrading gracefully beats waiting out the loss, by a wide margin.
"""
from __future__ import annotations

import argparse

from repro.core.estimator import EstimatorCoeffs
from repro.launch.serve import run_serving

#: same non-reduced epoch pricing the fleet benchmark uses: verification
#: must cost real virtual time for retries/timeouts to trade off against
#: anything (free epochs make every schedule look survivable)
COEFFS = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=2e-5, c=8e-3)

#: acceptance-criteria schedule (ISSUE 10): ~10% drop + duplication +
#: reordering on both directions and one 500 ms hard outage mid-run
SCHEDULE = "drop=0.1,dup=0.05,reorder=0.05,linkdown@0.25+0.5,seed=7"


def _measure(*, devices, horizon, seed, policy, schedule, link_timeout,
             link_degrade):
    r = run_serving(
        devices=devices, policy=policy, verbose=False, seed=seed,
        churn=True, horizon=horizon, k_max=4, coeffs=COEFFS,
        fault_schedule=schedule, link_timeout=link_timeout,
        link_degrade=link_degrade,
    )
    m = r["metrics"]
    c = m.chaos
    return {
        "goodput_tok_s": round(m.goodput(r["result"].horizon), 2),
        "sessions": len(m.sessions),
        "violations": m.violations(),
        "waste_fraction": round(m.waste_fraction(), 3),
        "retries": c.retries,
        "up_drops": c.uplink_drops,
        "down_drops": c.downlink_drops,
        "dup_verdicts_dropped": c.dup_verdicts_dropped,
        "verdicts_replayed": c.verdicts_replayed,
        "link_downs": c.link_down_events,
        "degraded_rounds": c.degraded_rounds,
    }


def run(quick: bool = True, schedule: str = SCHEDULE,
        link_timeout: float = 0.15, policies: list | None = None,
        min_ratio: float = 1.3) -> list[dict]:
    devices = 4 if quick else 8
    # the run must extend well past the outage window: the ablation's
    # stalled devices stay dead for the remainder while hardened devices
    # recover, which is exactly the gap the 1.3x bar measures
    horizon = 2.0 if quick else 4.0
    seed = 0
    rows = []
    for policy in policies or ["wisp"]:
        clean = _measure(devices=devices, horizon=horizon, seed=seed,
                         policy=policy, schedule=None, link_timeout=None,
                         link_degrade=False)
        hardened = _measure(devices=devices, horizon=horizon, seed=seed,
                            policy=policy, schedule=schedule,
                            link_timeout=link_timeout, link_degrade=True)
        ablation = _measure(devices=devices, horizon=horizon, seed=seed,
                            policy=policy, schedule=schedule,
                            link_timeout=None, link_degrade=False)
        for system, row in (("clean", clean), ("hardened", hardened),
                            ("no-retry ablation", ablation)):
            rows.append({"table": "chaos(edge-link)", "system": system,
                         "policy": policy, "n_devices": devices,
                         "horizon_s": horizon, **row})
        # sanity: the schedule actually bit, and recovery actually ran
        assert hardened["up_drops"] + hardened["down_drops"] > 0, \
            "fault schedule never dropped a message"
        assert hardened["retries"] > 0, "retry loop never fired"
        # the acceptance bar (ISSUE 10): retry + graceful degradation
        # must recover >= min_ratio x the goodput of waiting out the loss
        ratio = hardened["goodput_tok_s"] / max(
            ablation["goodput_tok_s"], 1e-9)
        assert ratio >= min_ratio, (
            f"hardened goodput ({hardened['goodput_tok_s']}) is only "
            f"{ratio:.2f}x the no-retry ablation "
            f"({ablation['goodput_tok_s']}); needs >= {min_ratio}x "
            f"[policy={policy}]"
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows, save_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--schedule", default=SCHEDULE,
                    help="fault-schedule DSL/preset for the faulted rows")
    ap.add_argument("--link-timeout", type=float, default=0.15)
    ap.add_argument("--min-ratio", type=float, default=1.3,
                    help="hardened/ablation goodput acceptance floor")
    ap.add_argument("--policy", nargs="+", default=None)
    args = ap.parse_args()
    rows = run(quick=not args.full, schedule=args.schedule,
               link_timeout=args.link_timeout, policies=args.policy,
               min_ratio=args.min_ratio)
    save_rows("chaos", rows)
    print_rows(rows)
