"""Paper Tables 5 + 6: predictor ON/OFF ablations.

Table 5 — draft-token acceptance rate with/without the predictor, across a
ladder of draft models (distillation depth stands in for the Qwen3 size
ladder), measured on REAL speculative rounds with the trained MLP inside
the drafting controller.

Table 6 — end-to-end system goodput with/without the predictor at several
device counts (simulator, MLP operating point measured from Table 4)."""
from __future__ import annotations

import numpy as np

from benchmarks._traces import cached_trace, distill_draft, gen_trace
from repro.core.predictor import MLPConfig, operating_point, train_mlp
from repro.sim import simulate, wisp
from repro.sim.acceptance import PredictorOperatingPoint
from repro.sim.systems import variant

#: distillation depth stands in for the Qwen3-0.6B..8B size ladder —
#: chosen so block acceptance spans the paper's Table-5 band (~0.29-0.55)
#: while the draft remains imperfect enough that logit features carry signal
LADDER = {"small": 100, "mid": 150, "large": 250}


def run(quick: bool = True) -> list[dict]:
    rows = []
    # ---- Table 5: acceptance of SENT tokens, predictor OFF vs ON --------
    measured_op = None
    for tag, steps in LADDER.items():
        feats, labels, rounds_off = cached_trace(
            tag, distill_steps=steps, rounds=120 if quick else 300
        )
        pred = train_mlp(feats, labels, MLPConfig(epochs=25, neg_weight=2.5))
        # ON: re-run the same pair with the predictor in the controller
        cfg, tp, dp = distill_draft(steps)
        from repro.serving.client import EdgeDevice  # noqa: F401 (doc link)

        _, _, rounds_on = _trace_with_predictor(
            cfg, tp, dp, pred, rounds=80 if quick else 200
        )
        off_sent = sum(r[0] for r in rounds_off)
        off_acc = sum(r[1] for r in rounds_off)
        on_sent = sum(r[0] for r in rounds_on)
        on_acc = sum(r[1] for r in rounds_on)
        acc_off = off_acc / max(off_sent, 1)
        acc_on = on_acc / max(on_sent, 1)
        rows.append(
            {
                "table": "acceptance_ablation(T5)",
                "draft": f"{tag}(distill={steps})",
                "predictor_off": round(acc_off, 3),
                "predictor_on": round(acc_on, 3),
                "improvement_pct": round(100 * (acc_on - acc_off) / max(acc_off, 1e-9), 1),
            }
        )
        m = operating_point(np.asarray(pred.predict_accept(feats)), labels)
        if tag == "mid":
            measured_op = PredictorOperatingPoint(fpr=m["fpr"], fnr=1 - m["rec1"])

    # ---- Table 6: system goodput, predictor OFF vs ON --------------------
    # The predictor's goodput win comes from saved verifier-side work, so it
    # appears in the contended regime (paper: "the relative gain increases
    # with N ... primarily helps by reducing verifier-side load"); at low N
    # the shorter blocks merely add round-trips.  Our A100-profile verifier
    # saturates near N~100, hence the larger sweep than the paper's 2..16.
    op = measured_op or PredictorOperatingPoint.mlp()
    for n in (16, 48, 96, 160) if quick else (16, 48, 96, 160, 224):
        off = simulate(variant(wisp(n, sim_time=40.0), predictor=None))
        on = simulate(variant(wisp(n, sim_time=40.0), predictor=op))
        g_off, g_on = off.goodput(), on.goodput()
        rows.append(
            {
                "table": "goodput_ablation(T6)",
                "n_devices": n,
                "predictor_off": round(g_off, 2),
                "predictor_on": round(g_on, 2),
                "improvement_pct": round(100 * (g_on - g_off) / max(g_off, 1e-9), 2),
            }
        )
    return rows


def _trace_with_predictor(cfg, tparams, dparams, predictor, *, rounds):
    from benchmarks._traces import gen_trace as _gen

    # gen_trace with a predictor-equipped device
    import numpy as np
    import jax.numpy as jnp

    from repro.serving.client import EdgeDevice
    from repro.serving.engine import VerificationEngine, VerifyItem

    engine = VerificationEngine(cfg, tparams, max_slots=2, max_len=1024,
                                cache_dtype=jnp.float32)
    dev = EdgeDevice(cfg, dparams, predictor=predictor, k_max=8, max_len=1024,
                     seed=77)
    rng = np.random.default_rng(7)
    prompt = rng.integers(2, cfg.vocab, size=12).tolist()
    slot, first = engine.new_session(prompt)
    dev.start_session(0, prompt, first)
    per_round = []
    for _ in range(rounds):
        res = dev.draft_round()
        if res.n_sent == 0:
            # predictor rejected immediately: nothing to verify, but the
            # device must still advance via the target (count as 0/0 round)
            (out,) = engine.verify(
                [VerifyItem(slot=slot,
                            draft_tokens=np.zeros((0,), np.int32),
                            q_logits=np.zeros((0, cfg.vocab), np.float32))]
            )
            dev.apply_verdict(0, out.token, [])
            continue
        (out,) = engine.verify(
            [VerifyItem(slot=slot, draft_tokens=res.tokens,
                        q_logits=res.q_logits)]
        )
        per_round.append((res.n_sent, out.accept_len))
        dev.apply_verdict(out.accept_len, out.token, res.tokens)
        if engine.fed[slot] > 900:
            engine.close_session(slot)
            prompt = rng.integers(2, cfg.vocab, size=12).tolist()
            slot, first = engine.new_session(prompt)
            dev.start_session(0, prompt, first)
    return None, None, per_round


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
