"""Shared trace generation for the predictor benchmarks (Tables 4-6, 10-11).

Builds a REAL draft/target pair on CPU: the target is a reduced random-init
transformer; the draft is the same architecture *distilled* onto the
target's greedy outputs for a configurable number of steps (more distillation
-> better aligned draft -> higher acceptance — standing in for the paper's
Qwen3-0.6B..8B ladder).  Speculative traces then log the controller's
logit features against true verification outcomes, with the paper's App.-B
labeling (tokens after the first rejection are excluded).
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticLMConfig, SyntheticStream
from repro.models import build
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine, VerifyItem

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def _make_teacher_fn(bundle):
    @jax.jit
    def teacher(params, toks):
        logits, _ = bundle.forward_train(params, {"tokens": toks})
        return logits

    return teacher


_PAIR_CACHE: dict = {}


def distill_draft(steps: int = 300, *, seed: int = 0, lr: float = 2e-3):
    """Returns (cfg, target_params, draft_params) with the draft trained to
    imitate the target for ``steps`` steps.  Cached in-process: several
    tables reuse the same pair."""
    key = (steps, seed, lr)
    if key in _PAIR_CACHE:
        return _PAIR_CACHE[key]
    out = _distill_draft(steps, seed=seed, lr=lr)
    _PAIR_CACHE[key] = out
    return out


def _train_teacher(bundle, cfg, *, steps: int, seed: int, lr: float = 2e-3):
    """Train the target LM on the synthetic bigram corpus so that token
    difficulty is REAL: bigram-structured positions become predictable,
    noise positions stay hard — the signal the rejection predictor's
    confidence/entropy features key on (paper §3.3)."""
    from repro.train.optimizer import OptConfig, opt_init, opt_update

    params = bundle.init(jax.random.PRNGKey(seed), dtype=jnp.float32)
    stream = SyntheticStream(SyntheticLMConfig(vocab=cfg.vocab, seq_len=48,
                                               seed=seed + 31))
    opt_cfg = OptConfig(name="adamw", lr=lr, warmup_steps=20)
    state = opt_init("adamw")(params)
    update = opt_update("adamw")

    @jax.jit
    def step_fn(params, state, toks, targets):
        def loss_fn(p):
            loss, _ = bundle.forward_train(
                p, {"tokens": toks, "targets": targets}
            )
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = update(params, grads, state, opt_cfg)
        return params, state, loss

    B, S = 8, 48
    for step in range(steps):
        seqs = stream.sequences(np.arange(B) + step * B)[:, : S + 1]
        params, state, _ = step_fn(
            params, state,
            jnp.asarray(seqs[:, :-1], jnp.int32),
            jnp.asarray(seqs[:, 1:], jnp.int32),
        )
    return params


def _distill_draft(steps: int, *, seed: int, lr: float):
    from repro.train.optimizer import OptConfig, opt_init, opt_update

    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    tparams = _train_teacher(bundle, cfg, steps=500, seed=seed)
    dparams = bundle.init(jax.random.PRNGKey(seed + 1), dtype=jnp.float32)
    stream = SyntheticStream(SyntheticLMConfig(vocab=cfg.vocab, seq_len=48,
                                               seed=seed))
    opt_cfg = OptConfig(name="adamw", lr=lr, warmup_steps=20)
    state = opt_init("adamw")(dparams)
    update = opt_update("adamw")

    @jax.jit
    def step_fn(params, state, toks, teacher_logits):
        def loss_fn(p):
            # soft distillation: KL(teacher || draft) — acceptance in
            # speculative decoding is the distribution overlap E[min(1,p/q)],
            # so matching full distributions (not argmax) is what raises it
            logits, _ = bundle.forward_train(p, {"tokens": toks})
            logq = jax.nn.log_softmax(logits, axis=-1)
            pt = jax.nn.softmax(teacher_logits, axis=-1)
            return -jnp.mean(jnp.sum(pt * logq, axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state, _ = update(params, grads, state, opt_cfg)
        return params, state, loss

    B, S = 8, 48
    teacher = _make_teacher_fn(bundle)
    for step in range(steps):
        ids = np.arange(B) + step * B
        toks = jnp.asarray(stream.sequences(ids)[:, :S], jnp.int32)
        t_logits = teacher(tparams, toks)
        dparams, state, loss = step_fn(dparams, state, toks, t_logits)
    return cfg, tparams, dparams


def gen_trace(cfg, tparams, dparams, *, rounds: int = 120, k_max: int = 8,
              seed: int = 0):
    """Run real speculative rounds; returns (features (N,5), labels (N,),
    per_round list of (n_sent, accept_len))."""
    engine = VerificationEngine(cfg, tparams, max_slots=2, max_len=1024,
                                cache_dtype=jnp.float32)
    dev = EdgeDevice(cfg, dparams, k_max=k_max, max_len=1024, seed=seed + 5)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(2, cfg.vocab, size=12).tolist()
    slot, first = engine.new_session(prompt)
    dev.start_session(0, prompt, first)

    feats, labels, per_round = [], [], []
    for r in range(rounds):
        res = dev.draft_round()
        if res.n_sent == 0:
            continue
        (out,) = engine.verify(
            [VerifyItem(slot=slot, draft_tokens=res.tokens,
                        q_logits=res.q_logits)]
        )
        L = out.accept_len
        # paper App. B: label accepted prefix 1, the FIRST rejected token 0,
        # drop positions after the first rejection
        for i in range(min(L, res.n_sent)):
            feats.append(res.features[i])
            labels.append(1)
        if L < res.n_sent:
            feats.append(res.features[L])
            labels.append(0)
        per_round.append((res.n_sent, L))
        dev.apply_verdict(L, out.token, res.tokens)
        if engine.fed[slot] > 900:      # restart session before overflow
            engine.close_session(slot)
            dev_prompt = rng.integers(2, cfg.vocab, size=12).tolist()
            slot, first = engine.new_session(dev_prompt)
            dev.start_session(0, dev_prompt, first)
    return np.asarray(feats, np.float32), np.asarray(labels, np.int32), per_round


def cached_trace(tag: str, distill_steps: int, rounds: int, seed: int = 0):
    """Distill + trace with an npz cache (traces feed several tables)."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"trace_{tag}_{distill_steps}_{rounds}.npz")
    if os.path.exists(path):
        z = np.load(path)
        return z["feats"], z["labels"], list(map(tuple, z["rounds"]))
    cfg, tp, dp = distill_draft(distill_steps, seed=seed)
    feats, labels, per_round = gen_trace(cfg, tp, dp, rounds=rounds, seed=seed)
    np.savez(path, feats=feats, labels=labels,
             rounds=np.asarray(per_round, np.int32))
    return feats, labels, per_round
