"""Paper Table 3: whole-system goodput (verified committed tokens/s) under
the same verifier budget, heterogeneous SLO mix.

Two engines:

  * ``--engine sim`` (default) — analytic simulator at paper scale;
  * ``--engine cluster`` — the event-driven runtime over the real models:
    measured goodput / violation / waste for WISP vs FCFS on the same seed,
    plus a `repro.sim` prediction at matched per-token acceptance for the
    cross-check (GoodSpeed-style goodput under heterogeneous edges).
"""
from __future__ import annotations

import argparse

from repro.sim import centralized, simulate, sled, wisp


def run(quick: bool = True) -> list[dict]:
    sim_time = 40.0 if quick else 150.0
    N = 128 if quick else 192
    rows = []
    for name, mk in (("sled", sled), ("centralized", centralized),
                     ("wisp", wisp)):
        r = simulate(mk(N, sim_time=sim_time))
        rows.append(
            {
                "table": "goodput(T3)",
                "system": name,
                "n_devices": N,
                "goodput_tok_s": round(r.goodput(), 1),
                "violation_rate": round(r.violation_rate(), 4),
                "acceptance": round(r.acceptance_rate(), 3),
                "waste_fraction": round(r.waste_fraction(), 3),
            }
        )
    return rows


def run_cluster(quick: bool = True) -> list[dict]:
    """Measured whole-system + per-class goodput from the functional stack
    (WISP vs FCFS, same seed), cross-checked against the simulator."""
    from benchmarks.wdt import _per_token_alpha, sim_crosscheck
    from repro.launch.serve import run_serving

    devices = 3 if quick else 8
    rounds = 3 if quick else 10
    k_max = 4

    rows = []
    measured_accept = None
    for sched in ("slo", "fcfs"):
        r = run_serving(
            devices=devices, rounds=rounds, k_max=k_max, scheduler=sched,
            verbose=False, seed=0,
        )
        m = r["metrics"]
        horizon = r["result"].horizon
        its = m.iterations
        measured_accept = sum(it.n_accepted for it in its) / max(len(its), 1)
        row = {
            "table": "goodput(cluster)",
            "engine": "cluster",
            "system": "wisp" if sched == "slo" else "fcfs",
            "n_devices": devices,
            "goodput_tok_s": round(m.goodput(horizon), 2),
            "violations": m.violations(),
            "deadline_violations": m.deadline_violations(),
            "acceptance": round(m.acceptance_rate(), 3),
            "waste_fraction": round(m.waste_fraction(), 3),
            "mean_queue_ms": round(m.mean_queue_time() * 1e3, 2),
            "spec_commit_rate": round(m.spec.commit_rate, 3),
        }
        for cls, d in m.per_class().items():
            row[f"class{cls}_goodput"] = round(
                d["committed"] / max(horizon, 1e-9), 2
            )
        rows.append(row)

    alpha_hat = _per_token_alpha(measured_accept, k_max)
    sr, cfg = sim_crosscheck(alpha_hat, k_max=k_max, quick=quick)
    rows.append(
        {
            "table": "goodput(cluster)",
            "engine": "sim-crosscheck",
            "alpha_hat_per_token": round(alpha_hat, 3),
            "predicted_device_goodput_tok_s": round(
                sr.goodput() / cfg.n_devices, 2
            ),
            "predicted_waste_fraction": round(sr.waste_fraction(), 3),
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("sim", "cluster"), default="sim")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    fn = run_cluster if args.engine == "cluster" else run
    print_rows(fn(quick=not args.full))
