"""Paper Table 3: whole-system goodput (verified committed tokens/s) under
the same verifier budget, heterogeneous SLO mix.

Two engines:

  * ``--engine sim`` (default) — analytic simulator at paper scale;
  * ``--engine cluster`` — the event-driven runtime over the real models:
    measured goodput / violation / waste per scheduling policy on the
    same seed, each cross-checked against a `repro.sim` run of the SAME
    policy at matched per-token acceptance (GoodSpeed-style goodput under
    heterogeneous edges).

``--policy`` (repeatable; also forwarded by ``benchmarks.run --policy``)
selects which registered scheduling policies the sweep compares; every
row carries the policy name.
"""
from __future__ import annotations

import argparse

from repro.core.scheduler import available_policies
from repro.sim import centralized, policy_variant, simulate, sled, wisp

#: the paper's three system columns -> (config factory, policy tag)
SYSTEMS = {
    "sled": (sled, "fcfs"),
    "centralized": (centralized, "-"),
    "wisp": (wisp, "wisp"),
}


def run(quick: bool = True, policies: list | None = None) -> list[dict]:
    sim_time = 40.0 if quick else 150.0
    N = 128 if quick else 192
    rows = []
    for name, (mk, pol) in SYSTEMS.items():
        r = simulate(mk(N, sim_time=sim_time))
        rows.append(
            {
                "table": "goodput(T3)",
                "system": name,
                "policy": pol,
                "n_devices": N,
                "goodput_tok_s": round(r.goodput(), 1),
                "violation_rate": round(r.violation_rate(), 4),
                "acceptance": round(r.acceptance_rate(), 3),
                "waste_fraction": round(r.waste_fraction(), 3),
            }
        )
    # policy ablations: WISP's engine under each requested batching rule
    for pol in policies or ():
        r = simulate(policy_variant(pol, N, sim_time=sim_time))
        rows.append(
            {
                "table": "goodput(T3)",
                "system": f"wisp-engine/{pol}",
                "policy": pol,
                "n_devices": N,
                "goodput_tok_s": round(r.goodput(), 1),
                "violation_rate": round(r.violation_rate(), 4),
                "acceptance": round(r.acceptance_rate(), 3),
                "waste_fraction": round(r.waste_fraction(), 3),
            }
        )
    return rows


def run_cluster(quick: bool = True, policies: list | None = None) -> list[dict]:
    """Measured whole-system + per-class goodput from the functional stack
    (one run per policy, same seed), each cross-checked against the
    simulator running the same policy at the observed acceptance."""
    from benchmarks.wdt import _per_token_alpha, sim_crosscheck
    from repro.launch.serve import run_serving

    devices = 3 if quick else 8
    rounds = 3 if quick else 10
    k_max = 4
    policies = list(policies) if policies else available_policies()

    rows = []
    for pol in policies:
        r = run_serving(
            devices=devices, rounds=rounds, k_max=k_max, policy=pol,
            verbose=False, seed=0,
        )
        m = r["metrics"]
        horizon = r["result"].horizon
        its = m.iterations
        measured_accept = sum(it.n_accepted for it in its) / max(len(its), 1)
        row = {
            "table": "goodput(cluster)",
            "engine": "cluster",
            "policy": pol,
            "n_devices": devices,
            "goodput_tok_s": round(m.goodput(horizon), 2),
            "violations": m.violations(),
            "deadline_violations": m.deadline_violations(),
            "acceptance": round(m.acceptance_rate(), 3),
            "waste_fraction": round(m.waste_fraction(), 3),
            "mean_queue_ms": round(m.mean_queue_time() * 1e3, 2),
            "spec_commit_rate": round(m.spec.commit_rate, 3),
        }
        for cls, d in m.per_class().items():
            row[f"class{cls}_goodput"] = round(
                d["committed"] / max(horizon, 1e-9), 2
            )
        rows.append(row)

        # same policy, analytic engine, measured acceptance: the sim and
        # the functional stack must tell the same goodput/waste story
        alpha_hat = _per_token_alpha(measured_accept, k_max)
        sr, cfg = sim_crosscheck(alpha_hat, k_max=k_max, quick=quick,
                                 policy=pol)
        rows.append(
            {
                "table": "goodput(cluster)",
                "engine": "sim-crosscheck",
                "policy": pol,
                "alpha_hat_per_token": round(alpha_hat, 3),
                "predicted_device_goodput_tok_s": round(
                    sr.goodput() / cfg.n_devices, 2
                ),
                "predicted_violation_rate": round(sr.violation_rate(), 4),
                "predicted_waste_fraction": round(sr.waste_fraction(), 3),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("sim", "cluster"), default="sim")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", nargs="+", default=None,
                    choices=available_policies(),
                    help="scheduling policies to sweep (default: all "
                         "registered, cluster engine)")
    args = ap.parse_args()
    fn = run_cluster if args.engine == "cluster" else run
    print_rows(fn(quick=not args.full, policies=args.policy))
