"""Paper Table 3: whole-system goodput (verified committed tokens/s) under
the same verifier budget, heterogeneous SLO mix."""
from __future__ import annotations

from repro.sim import centralized, simulate, sled, wisp


def run(quick: bool = True) -> list[dict]:
    sim_time = 40.0 if quick else 150.0
    N = 128 if quick else 192
    rows = []
    for name, mk in (("sled", sled), ("centralized", centralized),
                     ("wisp", wisp)):
        r = simulate(mk(N, sim_time=sim_time))
        rows.append(
            {
                "table": "goodput(T3)",
                "system": name,
                "n_devices": N,
                "goodput_tok_s": round(r.goodput(), 1),
                "violation_rate": round(r.violation_rate(), 4),
                "acceptance": round(r.acceptance_rate(), 3),
                "waste_fraction": round(r.waste_fraction(), 3),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
