"""Paper Fig. 1: wasted drafting tokens vs device goodput (fixed drafting
capacity 50 tok/s), swept over draft quality — plus the WDT decomposition
Eq. 9."""
from __future__ import annotations

import dataclasses

from repro.sim import simulate, wisp
from repro.sim.config import DevicePopulation
from repro.sim.systems import variant


def run(quick: bool = True) -> list[dict]:
    sim_time = 30.0 if quick else 90.0
    rows = []
    # sweep per-token acceptance (draft quality) at fixed 50 tok/s drafting
    for alpha in (0.6, 0.7, 0.8, 0.9):
        cfg = variant(
            wisp(16, sim_time=sim_time, predictor=None),
            population=DevicePopulation(
                draft_speeds=(50.0,), base_acceptance=(alpha,)
            ),
        )
        r = simulate(cfg)
        live = [x for x in r.records if x.t_arrival >= cfg.warmup]
        drafted = sum(x.n_drafted for x in live)
        wasted = sum(x.wasted for x in live)
        t_draft = sum(x.t_draft for x in live)
        t_wdt = wasted / 50.0
        rows.append(
            {
                "table": "wdt(F1)",
                "per_token_alpha": alpha,
                "wasted_tokens_per_s": round(wasted / (sim_time - cfg.warmup), 2),
                "device_goodput_tok_s": round(
                    r.goodput() / cfg.n_devices, 2
                ),
                "waste_fraction": round(r.waste_fraction(), 3),
                "t_wdt_over_t_draft": round(t_wdt / max(t_draft, 1e-9), 3),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
