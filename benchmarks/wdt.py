"""Paper Fig. 1: wasted drafting tokens vs device goodput (fixed drafting
capacity 50 tok/s), swept over draft quality — plus the WDT decomposition
Eq. 9.

Two engines:

  * ``--engine sim`` (default) — `repro.sim`'s analytic acceptance model at
    fleet scale;
  * ``--engine cluster`` — the event-driven cluster runtime over the *real*
    models: WDT is measured from actually-discarded tokens, then the
    per-token acceptance observed in that run is fed back into `repro.sim`
    so the analytic prediction can be cross-checked against the functional
    stack on the same waste metric.
"""
from __future__ import annotations

import argparse

from repro.core.scheduler import available_policies
from repro.sim import simulate
from repro.sim.config import DevicePopulation
from repro.sim.systems import policy_variant, variant


def run(quick: bool = True, policies: list | None = None) -> list[dict]:
    sim_time = 30.0 if quick else 90.0
    rows = []
    # sweep per-token acceptance (draft quality) at fixed 50 tok/s
    # drafting, under each requested batch-selection policy (WDT is a
    # drafting-side quantity, but the policy shapes queueing -> goodput)
    for pol in policies or ("wisp",):
        for alpha in (0.6, 0.7, 0.8, 0.9):
            cfg = variant(
                policy_variant(pol, 16, sim_time=sim_time, predictor=None),
                population=DevicePopulation(
                    draft_speeds=(50.0,), base_acceptance=(alpha,)
                ),
            )
            r = simulate(cfg)
            live = [x for x in r.records if x.t_arrival >= cfg.warmup]
            wasted = sum(x.wasted for x in live)
            t_draft = sum(x.t_draft for x in live)
            t_wdt = wasted / 50.0
            rows.append(
                {
                    "table": "wdt(F1)",
                    "policy": pol,
                    "per_token_alpha": alpha,
                    "wasted_tokens_per_s": round(
                        wasted / (sim_time - cfg.warmup), 2
                    ),
                    "device_goodput_tok_s": round(
                        r.goodput() / cfg.n_devices, 2
                    ),
                    "waste_fraction": round(r.waste_fraction(), 3),
                    "t_wdt_over_t_draft": round(t_wdt / max(t_draft, 1e-9), 3),
                }
            )
    return rows


def _per_token_alpha(mean_accept: float, k: int) -> float:
    """Invert E[L] = a(1-a^K)/(1-a) (iid accept, stop at first rejection)
    for the per-token probability a — bisection, E[L] is monotone in a."""
    lo, hi = 1e-4, 1.0 - 1e-4
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        el = mid * (1.0 - mid ** k) / (1.0 - mid)
        if el < mean_accept:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def sim_crosscheck(alpha_hat: float, *, k_max: int, quick: bool,
                   speed: float = 50.0, policy: str = "wisp"):
    """Simulate a 16-device fleet at the measured per-token acceptance,
    under the same scheduling policy the functional run used — the
    analytic prediction both cluster benchmarks cross-check against."""
    cfg = variant(
        policy_variant(policy, 16, sim_time=30.0 if quick else 90.0,
                       predictor=None, k_max=k_max),
        population=DevicePopulation(
            draft_speeds=(speed,), base_acceptance=(alpha_hat,)
        ),
    )
    return simulate(cfg), cfg


def run_cluster(quick: bool = True, policy: str = "wisp") -> list[dict]:
    """Measured WDT from the functional stack, cross-checked against the
    analytic simulator configured with the acceptance that run exhibited."""
    from repro.launch.serve import run_serving

    devices = 3 if quick else 6
    rounds = 3 if quick else 10
    k_max = 4
    speed = 50.0

    r = run_serving(
        devices=devices, rounds=rounds, k_max=k_max, policy=policy,
        verbose=False, draft_speeds=(speed,), seed=0,
    )
    m = r["metrics"]
    horizon = r["result"].horizon
    its = m.iterations
    drafted = sum(it.n_drafted for it in its)
    sent = sum(it.n_sent for it in its)
    accepted = sum(it.n_accepted for it in its)
    t_draft = m.t_drafting
    mean_accept = accepted / max(len(its), 1)

    alpha_hat = _per_token_alpha(mean_accept, k_max)
    sr, sim_cfg = sim_crosscheck(alpha_hat, k_max=k_max, quick=quick,
                                 speed=speed, policy=policy)

    return [
        {
            "table": "wdt(cluster)",
            "engine": "cluster",
            "policy": policy,
            "devices": devices,
            "rounds": rounds,
            "drafted": drafted,
            "sent": sent,
            "accepted": accepted,
            "spec_discarded": m.spec.discarded,
            "measured_waste_fraction": round(m.waste_fraction(), 3),
            "measured_wdt_s": round(m.t_wdt, 4),
            "t_wdt_over_t_draft": round(m.t_wdt / max(t_draft, 1e-9), 3),
            "goodput_tok_s": round(m.goodput(horizon), 2),
            "alpha_hat_per_token": round(alpha_hat, 3),
        },
        {
            "table": "wdt(cluster)",
            "engine": "sim-crosscheck",
            "policy": policy,
            "alpha_hat_per_token": round(alpha_hat, 3),
            "predicted_waste_fraction": round(sr.waste_fraction(), 3),
            "predicted_device_goodput_tok_s": round(
                sr.goodput() / sim_cfg.n_devices, 2
            ),
        },
    ]


if __name__ == "__main__":
    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("sim", "cluster"), default="sim")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", nargs="+", default=None,
                    choices=available_policies(),
                    help="scheduling policies to sweep")
    args = ap.parse_args()
    if args.engine == "cluster":
        rows = []
        for pol in args.policy or ("wisp",):
            rows.extend(run_cluster(quick=not args.full, policy=pol))
    else:
        rows = run(quick=not args.full, policies=args.policy)
    print_rows(rows)
