"""Tiered KV admission-capacity benchmark (DESIGN.md §12).

Sizes the device page pool at 25% of the session working set and measures
how many sessions each configuration can ADMIT (prefill + one verify
round) before the pool walls:

  * ``untiered`` — the single-tier baseline: ``OutOfPages`` is a hard
    admission failure once the device pool is referenced end-to-end;
  * ``tiered``   — a host-DRAM spill pool under the device pool: cold
    sessions' private pages spill on demand (prefix-refcount-aware, LRU),
    so admission continues until slots or host+device capacity run out.

TTFT is wall-clock ``new_session`` latency (prefill samples the first
token).  The capacity claim is honest only at equal TTFT, so the gate
compares p99 over the COMMON admission prefix — the sessions both
configurations actually admitted, i.e. the baseline's own operating
point — where the tier must be latency-neutral.  Later tiered admissions
pay their spill cost inside their own TTFT and are reported separately.

Asserted budgets (the CI smoke gate):

  * tiered admission capacity STRICTLY exceeds the untiered baseline and
    is >= 2x at the 25% pool (the acceptance criterion);
  * common-prefix p99 TTFT stays within noise of the baseline;
  * the tiered run actually spilled (the capacity did not come for free
    from slack in the pool sizing).

Rows are written to ``BENCH_tiered_kv.json`` at the repo root (the CI
artifact alongside ``BENCH_hotpath.json``).

Usage: PYTHONPATH=src:. python benchmarks/tiered_kv.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serving.engine import NoFreeSlots, VerificationEngine, VerifyItem
from repro.serving.kv_cache import OutOfPages

from benchmarks.common import print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_tiered_kv.json")

PAGE_SIZE = 4
PROMPT_LEN = 8          # 2 full pages
K = 3                   # one verify round grows a session to <= 3 pages
PAGES_PER_SESSION = 3   # prompt (2) + decode tail (1): the working set unit
POOL_FRACTION = 0.25    # device pool = 25% of the working set


def _make_engine(n_sessions: int, *, tiered: bool):
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    working_set = n_sessions * PAGES_PER_SESSION
    device_pages = max(int(working_set * POOL_FRACTION), PAGES_PER_SESSION)
    eng = VerificationEngine(
        cfg, params, max_slots=n_sessions + 1, max_len=32, method="greedy",
        seed=0, paged=True, page_size=PAGE_SIZE,
        n_pages=device_pages + 1,                     # + reserved scratch
        kv_tier_pages=working_set * 2 if tiered else 0,
    )
    return cfg, eng, device_pages, working_set


def _admit(cfg, eng, n_sessions: int) -> tuple[int, list[float]]:
    """Admit sessions one at a time (distinct prompts, so no prefix
    sharing hides the footprint); each runs one greedy verify round then
    goes idle.  Returns (admitted, per-session TTFT seconds)."""
    rng = np.random.default_rng(0)

    def one_session(i):
        prompt = rng.integers(2, cfg.vocab, size=PROMPT_LEN).astype(np.int32)
        t0 = time.perf_counter()
        slot, _first = eng.new_session(prompt)
        ttft = time.perf_counter() - t0
        draft = rng.integers(0, cfg.vocab, size=K).astype(np.int32)
        eng.verify([VerifyItem(slot=slot, draft_tokens=draft,
                               rng_tag=(i, 0))])
        return slot, ttft

    # warmup: compile the prefill + B=1 verify buckets off the clock,
    # plus the spill/page-in dispatch pair (no-op on the untiered engine)
    slot, _ = one_session(-1)
    eng.spill_session(slot)
    eng.prefetch_session(slot)
    eng.close_session(slot)

    ttfts = []
    for i in range(n_sessions):
        try:
            _, ttft = one_session(i)
        except (OutOfPages, NoFreeSlots):
            break
        ttfts.append(ttft)
    return len(ttfts), ttfts


def _p99_ms(xs) -> float:
    return round(float(np.percentile(np.asarray(xs), 99)) * 1e3, 3)


def run(quick: bool = True) -> list[dict]:
    n_sessions = 12 if quick else 24
    rows, ttfts = [], {}
    for config in ("untiered", "tiered"):
        cfg, eng, device_pages, working_set = _make_engine(
            n_sessions, tiered=config == "tiered")
        admitted, tt = _admit(cfg, eng, n_sessions)
        ttfts[config] = tt
        rows.append({
            "table": "tiered_kv", "config": config,
            "device_pages": device_pages,
            "host_pages": eng.kv.tier.cfg.host_pages
            if eng.tiered else 0,
            "working_set_pages": working_set,
            "pool_fraction": POOL_FRACTION,
            "offered_sessions": n_sessions,
            "admitted_sessions": admitted,
            "p99_ttft_ms": _p99_ms(tt),
            "pages_spilled": eng.stats["pages_spilled"],
            "pages_paged_in": eng.stats["pages_paged_in"],
            "spill_bytes": eng.stats["spill_bytes"],
            "pagein_bytes": eng.stats["pagein_bytes"],
        })

    by = {r["config"]: r for r in rows}
    cap_u = by["untiered"]["admitted_sessions"]
    cap_t = by["tiered"]["admitted_sessions"]
    # -- budget assertions (CI gate) --------------------------------------
    assert cap_t > cap_u, (
        f"tiered admission capacity {cap_t} does not exceed the untiered "
        f"baseline {cap_u} at a {POOL_FRACTION:.0%} device pool"
    )
    assert cap_t >= 2 * cap_u, (
        f"acceptance: tiered capacity {cap_t} is not >= 2x the untiered "
        f"baseline {cap_u} at a {POOL_FRACTION:.0%} device pool"
    )
    assert by["tiered"]["pages_spilled"] > 0, (
        "the tiered run never spilled — the pool sizing is not actually "
        "constraining admission and the capacity comparison is vacuous"
    )
    # equal-TTFT gate at the baseline's operating point: p99 over the
    # common admission prefix (4x + 50ms absorbs CPU timer noise on the
    # tiny reduced model; the claim is latency-NEUTRALITY, these sessions
    # never touch the tier)
    common = min(cap_u, cap_t)
    p99_u = _p99_ms(ttfts["untiered"][:common])
    p99_t = _p99_ms(ttfts["tiered"][:common])
    assert p99_t <= 4 * p99_u + 50.0, (
        f"tiered p99 TTFT {p99_t}ms not comparable to untiered {p99_u}ms "
        f"over the common {common}-session admission prefix"
    )
    by["tiered"]["p99_ttft_common_prefix_ms"] = p99_t
    by["untiered"]["p99_ttft_common_prefix_ms"] = p99_u
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small session count (CI)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    with open(OUT_PATH, "w") as f:
        json.dump(rows, f, indent=1)
    print_rows(rows)
    by = {r["config"]: r for r in rows}
    print(
        f"[tiered_kv] admission capacity at "
        f"{by['tiered']['pool_fraction']:.0%} pool: "
        f"{by['untiered']['admitted_sessions']} -> "
        f"{by['tiered']['admitted_sessions']} sessions "
        f"({by['tiered']['admitted_sessions'] / by['untiered']['admitted_sessions']:.1f}x), "
        f"common-prefix p99 TTFT "
        f"{by['untiered']['p99_ttft_common_prefix_ms']}ms -> "
        f"{by['tiered']['p99_ttft_common_prefix_ms']}ms"
    )
    print(f"[tiered_kv] budgets OK; wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
