"""Paper Tables 4 + 10 + 11: rejection-predictor operating points (MLP vs
tree-family baseline) on REAL speculative traces, + single-sample inference
latency on this host (stands in for the RPi measurements)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks._traces import cached_trace
from repro.core.predictor import (
    MLPConfig,
    auc_score,
    operating_point,
    train_mlp,
    train_stumps,
)


def _latency_stats(fn, x, n=300):
    ts = []
    fn(x)  # warm
    for _ in range(n):
        t0 = time.perf_counter()
        fn(x)
        ts.append((time.perf_counter() - t0) * 1e3)
    a = np.asarray(ts)
    return {
        "mean_ms": round(a.mean(), 4),
        "median_ms": round(np.median(a), 4),
        "std_ms": round(a.std(), 4),
        "p95_ms": round(np.percentile(a, 95), 4),
        "p99_ms": round(np.percentile(a, 99), 4),
    }


def run(quick: bool = True) -> list[dict]:
    feats, labels, _ = cached_trace("mid", distill_steps=100,
                                    rounds=400 if quick else 800)
    n = len(labels)
    split = int(n * 0.75)
    Xtr, ytr, Xte, yte = feats[:split], labels[:split], feats[split:], labels[split:]

    rows = []
    # Fig. 2/3: per-feature Pearson correlation with acceptance
    from repro.core.features import FEATURE_NAMES

    corr = {
        name: round(float(np.corrcoef(feats[:, i], labels)[0, 1]), 4)
        for i, name in enumerate(FEATURE_NAMES)
    }
    rows.append({"table": "feature_correlation(F2/F3)", **corr})

    mlp = train_mlp(Xtr, ytr, MLPConfig(epochs=25, neg_weight=2.5))
    stump = train_stumps(Xtr, ytr, n_rounds=60)
    models = {
        "mlp": (lambda X: np.asarray(mlp.predict_accept(X)),
                lambda X: np.asarray(mlp.proba(X))),
        "stumps(tree)": (stump.predict_accept, stump.proba),
    }
    for name, (pred, proba) in models.items():
        m = operating_point(pred(Xte), yte)
        rows.append(
            {
                "table": "predictor(T4)",
                "model": name,
                "n_train": len(ytr),
                "n_test": len(yte),
                "acc": round(m["acc"], 4),
                "auc": round(auc_score(proba(Xte), yte), 4),
                "rec1": round(m["rec1"], 4),
                "spec": round(m["spec"], 4),
                "fpr": round(m["fpr"], 4),
                "bal_acc": round(m["bal_acc"], 4),
            }
        )
        c = m["confusion"]
        rows.append({"table": "predictor_confusion(T10)", "model": name, **c})

    # Table 11: single-sample latency on this host CPU
    one = Xte[:1]
    rows.append({"table": "predictor_latency(T11)", "model": "mlp",
                 **_latency_stats(lambda x: np.asarray(mlp.proba(x)), one)})
    rows.append({"table": "predictor_latency(T11)", "model": "stumps(tree)",
                 **_latency_stats(stump.proba, one)})
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
