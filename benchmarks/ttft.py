"""Long-prompt interference: monolithic vs chunked prompt prefill
(DESIGN.md §8) under session churn on the event-driven cluster runtime.

The workload opens cold sessions with prompts much longer than a
verification block.  With **monolithic** prefill every open seizes the
verifier for one blocking, estimator-priced span *outside* the SLO
scheduler, so deadline-critical verification requests queue behind it —
the head-of-line interference the paper's Algorithm 1 is supposed to
prevent.  With **chunked** prefill the same prompts are split into
fixed-budget chunks that compete under Algorithm 1 against a TTFT
deadline, letting critical verifications run between chunks.

Both runs use the identical fleet and per-device workload generators
(same seed: same prompts, think times, response targets per device), so
the load offered is equal; the realized interleaving differs only through
scheduling-induced timing, which is exactly the variable under test.
(Byte-identical committed streams across prefill modes are asserted in
``tests/test_chunked_prefill.py`` on the fixed-work driver, where the
closed loop cannot reorder session ids.)  The benchmark asserts the
paper's claim: verification-deadline violations under long-prompt churn
are strictly lower with chunked prefill at equal load.
"""
from __future__ import annotations

import argparse

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import available_policies

#: virtual-hardware coefficients: a 64-token prompt prefills in ~0.2
#: virtual seconds while a k=4 verify block takes ~17 ms — prefill spans
#: comparable to the SLO-class deadline budgets, the regime where
#: head-of-line blocking shows (coefficients define the virtual verifier;
#: both modes use the same ones)
COEFFS = EstimatorCoeffs(a=3e-3, b_compute=1e-7, b_read=2e-6, c=2e-3)


def _run_mode(mode: str, *, quick: bool, policy: str = "wisp"):
    from repro.launch.serve import run_serving

    return run_serving(
        devices=3 if quick else 4,
        churn=True,
        horizon=5.0 if quick else 8.0,
        rounds=0,
        k_max=4,
        policy=policy,
        verbose=False,
        seed=0,
        prompt_len=64 if quick else 96,
        prefill_mode=mode,
        prefill_chunk_tokens=16,
        coeffs=COEFFS,
        think_time_mean=0.05,
        response_len_mean=8.0 if quick else 10.0,
    )


def _row(mode: str, policy: str, r) -> dict:
    m = r["metrics"]
    horizon = r["result"].horizon
    server = r["server"]
    ttft_slo_viol = sum(rec.violated for rec in server.prefill_log)
    return {
        "table": "ttft",
        "policy": policy,
        "prefill": mode,
        "sessions": len(m.sessions),
        "ttft_p50_ms": round(m.ttft_quantile(0.5) * 1e3, 1),
        "ttft_p99_ms": round(m.ttft_quantile(0.99) * 1e3, 1),
        "deadline_violations": m.deadline_violations(),
        "iterations": len(m.iterations),
        "deadline_violation_rate": round(m.deadline_violation_rate(), 4),
        "mean_queue_ms": round(m.mean_queue_time() * 1e3, 2),
        "goodput_tok_s": round(m.goodput(horizon), 1),
        "prefill_chunks": r["server"].engine.stats["prefill_chunks"],
        "ttft_slo_violations": ttft_slo_viol,
    }


def run(quick: bool = True, policies: list | None = None) -> list[dict]:
    rows = []
    for pol in policies or ("wisp",):
        runs = {m: _run_mode(m, quick=quick, policy=pol)
                for m in ("monolithic", "chunked")}
        prows = [_row(m, pol, r) for m, r in runs.items()]
        mono, chunk = prows[0], prows[1]
        if pol == "wisp":
            # the acceptance claim (asserted for the paper's scheduler;
            # baselines are reported, not gated): chunked prefill restores
            # the interference bound
            assert (
                chunk["deadline_violations"] < mono["deadline_violations"]
            ), (
                "chunked prefill must strictly reduce verification-deadline "
                f"violations under long-prompt churn: chunked="
                f"{chunk['deadline_violations']} vs monolithic="
                f"{mono['deadline_violations']}"
            )
        prows.append({
            "table": "ttft",
            "policy": pol,
            "prefill": "delta",
            "deadline_violations_removed":
                mono["deadline_violations"] - chunk["deadline_violations"],
            "mean_queue_ms_saved":
                round(mono["mean_queue_ms"] - chunk["mean_queue_ms"], 2),
        })
        rows.extend(prows)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", nargs="+", default=None,
                    choices=available_policies(),
                    help="scheduling policies to sweep (default: wisp)")
    args = ap.parse_args()
    print_rows(run(quick=not args.full, policies=args.policy))
