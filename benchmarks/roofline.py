"""Roofline table: reads the dry-run artifacts (artifacts/dryrun/*.json)
and prints the per-(arch x shape x mesh) three-term roofline — the §Roofline
deliverable.  Run ``python -m repro.launch.dryrun --all --mesh both`` first
(or let benchmarks.run skip gracefully)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_artifacts(mesh: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    return rows


def run(quick: bool = True) -> list[dict]:
    rows = []
    for rec in load_artifacts():
        if rec.get("status") == "skipped":
            rows.append(
                {
                    "table": "roofline",
                    "arch": rec["arch"],
                    "shape": rec["shape"],
                    "mesh": rec.get("mesh", "-"),
                    "status": "skipped",
                    "why": rec.get("why", ""),
                }
            )
            continue
        if rec.get("status") != "ok":
            rows.append(
                {
                    "table": "roofline",
                    "arch": rec["arch"],
                    "shape": rec["shape"],
                    "mesh": rec.get("mesh", "-"),
                    "status": rec.get("status", "?"),
                }
            )
            continue
        r = rec["roofline"]
        rows.append(
            {
                "table": "roofline",
                "arch": rec["arch"],
                "shape": rec["shape"],
                "mesh": rec["mesh"],
                "status": "ok",
                "t_compute_ms": round(r["t_compute"] * 1e3, 2),
                "t_memory_ms": round(r["t_memory"] * 1e3, 2),
                "t_collective_ms": round(r["t_collective"] * 1e3, 2),
                "dominant": r["dominant"],
                "roofline_fraction": round(r["roofline_fraction"], 3),
                "useful_fraction": round(r["useful_fraction"], 3),
                "hbm_args_gib": round(
                    r["memory_per_device"]["args_bytes"] / 2**30, 2
                ),
                "hbm_temp_gib": round(
                    r["memory_per_device"]["temp_bytes"] / 2**30, 2
                ),
            }
        )
    if not rows:
        rows.append({"table": "roofline", "status": "no dry-run artifacts"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
