"""Fleet goodput under verifier churn (repro.fleet, ISSUE 6).

Three measured rows on the same seed and workload (session churn until
``--horizon``):

  * ``1-verifier``   — the single-server baseline runtime;
  * ``N-verifier``   — the fleet router, no failures (scale-up headroom);
  * ``N-verifier/churn`` — the fleet with one verifier killed at
    ``--fail-at`` (a fraction of the horizon): heartbeat detection,
    session migration via committed-stream replay, hedged re-dispatch.

The acceptance bar this table pins: the fleet's goodput **under churn**
stays strictly above the healthy single-verifier baseline — losing a
replica mid-run still beats never having had the replicas.
"""
from __future__ import annotations

import argparse

from repro.core.estimator import EstimatorCoeffs
from repro.launch.serve import run_serving

#: epoch pricing of a full-size (not ``--reduced``) target on one chip —
#: the reduced model's analytic coefficients price epochs so cheap that a
#: single verifier never saturates and the fleet comparison degenerates
#: (verification must be the bottleneck for replicas to matter, exactly
#: the regime the paper serves in)
COEFFS = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=2e-5, c=8e-3)


def _measure(*, devices, horizon, seed, policy, verifiers, fail_at):
    r = run_serving(
        devices=devices, policy=policy, verbose=False, seed=seed,
        churn=True, horizon=horizon, k_max=4, coeffs=COEFFS,
        prefill_mode="chunked", prefill_chunk_tokens=16,
        verifiers=verifiers, fail_at=fail_at,
    )
    m = r["metrics"]
    row = {
        "goodput_tok_s": round(m.goodput(r["result"].horizon), 2),
        "sessions": len(m.sessions),
        "violations": m.violations(),
        "waste_fraction": round(m.waste_fraction(), 3),
    }
    if verifiers > 1:
        fs = r["server"].stats
        row.update(
            verifier_downs=fs["verifier_downs"],
            migrations=fs["migrations"],
            reopens=fs["reopens"],
            redispatches=fs["redispatches"],
        )
    return row


def run(quick: bool = True, verifiers: int = 3, fail_frac: float = 0.5,
        policies: list | None = None) -> list[dict]:
    devices = 6 if quick else 10
    horizon = 1.0 if quick else 4.0
    seed = 0
    rows = []
    for policy in policies or ["wisp"]:
        base = _measure(devices=devices, horizon=horizon, seed=seed,
                        policy=policy, verifiers=1, fail_at=())
        healthy = _measure(devices=devices, horizon=horizon, seed=seed,
                           policy=policy, verifiers=verifiers, fail_at=())
        churn = _measure(
            devices=devices, horizon=horizon, seed=seed, policy=policy,
            verifiers=verifiers,
            fail_at=((0, fail_frac * horizon, None),),
        )
        for system, row in (("1-verifier", base),
                            (f"{verifiers}-verifier", healthy),
                            (f"{verifiers}-verifier/churn", churn)):
            rows.append({"table": "fleet(churn)", "system": system,
                         "policy": policy, "n_devices": devices,
                         "horizon_s": horizon, **row})
        # the acceptance bar: a fleet that lost a verifier mid-run still
        # out-serves the verifier that was never backed up
        assert churn["verifier_downs"] >= 1, "failure injection never fired"
        assert churn["goodput_tok_s"] > base["goodput_tok_s"], (
            f"fleet goodput under churn ({churn['goodput_tok_s']}) must "
            f"beat the 1-verifier baseline ({base['goodput_tok_s']}) "
            f"[policy={policy}]"
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows, save_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--verifiers", type=int, default=3)
    ap.add_argument("--fail-at", type=float, default=0.5,
                    help="kill verifier 0 at this fraction of the horizon")
    ap.add_argument("--policy", nargs="+", default=None,
                    help="scheduling policies to sweep (default: wisp)")
    args = ap.parse_args()
    rows = run(quick=not args.full, verifiers=args.verifiers,
               fail_frac=args.fail_at, policies=args.policy)
    save_rows("fleet", rows)
    print_rows(rows)
