"""Dense vs paged verification engine at high session counts: throughput
(committed tokens / s of engine wall time), prefix-cache hit rate, and
KV-pool pressure.

Sessions arrive in prompt "families" (shared system-prompt prefixes, the
multi-tenant serving pattern): the paged engine should (i) admit more
concurrent sessions than its raw pool size suggests, because family members
share prefix pages, and (ii) commit the same token streams as the dense
engine (losslessness is asserted, not assumed).

CPU wall-clock here compares the two host paths of the SAME model at the
same shapes — the interesting artifacts are the hit rate, the pages-in-use
curve, and the committed-token parity, not absolute tok/s.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Timer
from repro.configs import get_config
from repro.models import build
from repro.serving.engine import VerificationEngine, VerifyItem
from repro.serving.kv_cache import OutOfPages


def _mk_engine(cfg, params, paged, *, n_sessions, max_len, page_size):
    return VerificationEngine(
        cfg, params,
        max_slots=n_sessions, max_len=max_len,
        method="greedy", paged=paged, page_size=page_size,
    )


def _drive(engine, prompts, rounds, k, vocab, rng):
    """Open every session, run ``rounds`` verify epochs over all of them in
    one batch per epoch, return (committed_streams, engine_seconds)."""
    slots, streams = [], []
    t_total = 0.0
    for p in prompts:
        with Timer() as t:
            slot, first = engine.new_session(p)
        t_total += t.dt
        slots.append(slot)
        streams.append([first])
    for _ in range(rounds):
        items = []
        drafts = []
        for slot, stream in zip(slots, streams):
            # half plausible (last committed token repeated), half garbage —
            # gives a mix of accepts and rejections without a draft model
            d = np.asarray(
                [stream[-1]] + list(rng.integers(0, vocab, size=k - 1)),
                np.int32,
            )
            drafts.append(d)
            items.append(VerifyItem(
                slot=slot, draft_tokens=d,
                q_logits=np.zeros((k, vocab), np.float32),
            ))
        with Timer() as t:
            outs = engine.verify(items)
        t_total += t.dt
        for o, d, stream in zip(outs, drafts, streams):
            stream.extend(list(d[: o.accept_len]) + [o.token])
    return streams, t_total


def run(quick: bool = True) -> list[dict]:
    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)

    n_sessions = 8 if quick else 32
    rounds = 3 if quick else 10
    k = 4
    page_size = 16
    max_len = 128
    n_families = 2
    # family = shared 2-page system prefix + short per-session suffix
    fams = [list(rng.integers(0, cfg.vocab, size=2 * page_size))
            for _ in range(n_families)]
    prompts = [
        fams[i % n_families] + list(rng.integers(0, cfg.vocab, size=3))
        for i in range(n_sessions)
    ]

    rows = []
    results = {}
    for mode, paged in (("dense", False), ("paged", True)):
        eng = _mk_engine(cfg, params, paged, n_sessions=n_sessions,
                         max_len=max_len, page_size=page_size)
        streams, secs = _drive(eng, prompts, rounds, k, cfg.vocab, rng=np.random.default_rng(1))
        results[mode] = streams
        committed = sum(len(s) for s in streams)
        st = eng.prefix_cache_stats()
        rows.append({
            "table": "paged_serving",
            "mode": mode,
            "sessions": n_sessions,
            "rounds": rounds,
            "committed_tokens": committed,
            "tok_per_s": round(committed / max(secs, 1e-9), 1),
            "prefix_hits": st["hits"],
            "prefix_hit_rate": round(
                st["hits"] / max(st["hits"] + st["misses"], 1), 3),
            "pages_in_use": st["pages_in_use"],
            "budget_tokens": eng.memory_budget_tokens(),
        })
    assert results["dense"] == results["paged"], \
        "paged engine diverged from dense committed streams"

    # capacity under a constrained pool: prefix sharing stretches how many
    # sessions fit; unique prompts (no shareable prefix) are the control
    n_pages = 2 * n_families + n_sessions // 2 + 1        # deliberately tight
    for label, plist in (
        ("paged_admission_shared", prompts),
        ("paged_admission_unique",
         [list(rng.integers(0, cfg.vocab, size=2 * page_size + 3))
          for _ in range(n_sessions)]),
    ):
        eng = VerificationEngine(
            cfg, params, max_slots=n_sessions, max_len=max_len,
            method="greedy", paged=True, page_size=page_size,
            n_pages=n_pages,
        )
        opened = 0
        try:
            for p in plist:
                eng.new_session(p)
                opened += 1
        except (OutOfPages, RuntimeError):
            pass
        st = eng.prefix_cache_stats()
        rows.append({
            "table": "paged_serving",
            "mode": label,
            "sessions": opened,
            "pages_in_use": st["pages_in_use"],
            "prefix_hits": st["hits"],
            "budget_tokens": eng.memory_budget_tokens(),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows, save_rows

    rows = run(quick=True)
    print_rows(rows)
    save_rows("paged_serving", rows)
