"""Paper Table 2: system capacity (max devices) per token-speed SLO class,
for WISP / SLED / centralized serving on the A100+Qwen3-32B profile."""
from __future__ import annotations

from repro.sim import capacity_search, centralized, sled, wisp
from repro.sim.config import SLO_SPEEDS


def run(quick: bool = True) -> list[dict]:
    sim_time = 30.0 if quick else 120.0
    n_hi = 1024 if quick else 2048
    systems = {"wisp": wisp, "sled": sled, "centralized": centralized}
    caps: dict[str, dict[float, int]] = {s: {} for s in systems}
    rows = []
    for speed in SLO_SPEEDS:
        for sys_name, mk in systems.items():
            cap = capacity_search(
                lambda n, mk=mk, s=speed: mk(
                    n, homogeneous_slo=s, sim_time=sim_time
                ),
                eps=0.10,
                n_hi_cap=n_hi,
            )
            caps[sys_name][speed] = cap
    for speed in SLO_SPEEDS:
        w, s, c = (caps[k][speed] for k in ("wisp", "sled", "centralized"))
        rows.append(
            {
                "table": "capacity(T2)",
                "slo_tok_s": speed,
                "wisp": w,
                "sled": s,
                "centralized": c,
                "speedup_vs_sled": round(w / max(s, 1), 2),
                "speedup_vs_central": round(w / max(c, 1), 2),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
