"""Multi-tenant isolation under an adversarial flood (DESIGN.md §13, ISSUE 9).

Two measured rows on the same seed and device workload (the
``adversarial-flood`` tenant mix: a modest interactive *victim* next to a
zero-think *flood* hammering the verifier):

  * ``plain-wisp`` — the tenant-agnostic stack: policy "wisp", no
    admission contract (the flood's rate limit and queue bound stripped);
  * ``wfq``        — the tenancy subsystem on: the mix's token-bucket
    contract at admission plus the "wfq" weighted-fair policy at batch
    selection.

Contention is deliberate: full-size epoch pricing, a 2-request batch cap
and fast (250 tok/s) drafting make verifier queueing — not the edge —
the victim's bottleneck, which is the regime where batch-selection
policy matters at all.

The acceptance bars this table pins:

  * victim goodput under wfq >= 1.3x plain-wisp (isolation);
  * Jain's weighted fairness strictly higher (fair share);
  * aggregate goodput within 10% of plain-wisp (isolation is suppression
    of interference, not of throughput).
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.cluster.workload import TENANT_MIXES
from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import SchedulerConfig
from repro.launch.serve import run_serving

#: full-size epoch pricing (same rationale as benchmarks/fleet.py): the
#: reduced model's analytic coefficients price epochs so cheap that the
#: verifier never saturates and every policy trivially serves everyone
COEFFS = EstimatorCoeffs(a=2e-3, b_compute=1e-7, b_read=2e-5, c=8e-3)

MIX = TENANT_MIXES["adversarial-flood"]
#: the same device workload with the admission contract stripped — what
#: the flood looks like to a serving stack that has no tenancy subsystem
PLAIN_MIX = tuple(
    dataclasses.replace(tw, rate_tokens_per_s=None, max_queued=None)
    for tw in MIX
)
WEIGHTS = {tw.name: tw.weight for tw in MIX}


def _measure(*, horizon, seed, policy, mix):
    r = run_serving(
        policy=policy, tenant_mix=mix, verbose=False, seed=seed,
        churn=True, horizon=horizon, k_max=4, coeffs=COEFFS,
        draft_speeds=(250.0,),
        sched_cfg=SchedulerConfig(max_batch_requests=2),
    )
    m = r["metrics"]
    h = r["result"].horizon
    pt = m.per_tenant(h)
    return {
        "goodput_tok_s": round(m.goodput(h), 2),
        "victim_tok_s": round(pt["victim"]["goodput_tok_s"], 2),
        "flood_tok_s": round(pt["flood"]["goodput_tok_s"], 2),
        "jain_fairness": round(m.jain_fairness(h, WEIGHTS), 3),
        "victim_sessions": pt["victim"]["sessions"],
        "rejections": sum(v["rejections"] for v in pt.values()),
        "violations": m.violations(),
    }


def run(quick: bool = True) -> list[dict]:
    horizon = 2.0 if quick else 6.0
    seed = 0
    plain = _measure(horizon=horizon, seed=seed, policy="wisp",
                     mix=PLAIN_MIX)
    wfq = _measure(horizon=horizon, seed=seed, policy="wfq", mix=MIX)
    rows = [
        {"table": "tenancy(flood)", "system": system,
         "horizon_s": horizon, **row}
        for system, row in (("plain-wisp", plain), ("wfq", wfq))
    ]
    # the acceptance bars (module docstring)
    assert wfq["victim_tok_s"] >= 1.3 * plain["victim_tok_s"], (
        f"wfq must hold victim goodput >= 1.3x plain-wisp "
        f"({wfq['victim_tok_s']} vs {plain['victim_tok_s']})"
    )
    assert wfq["jain_fairness"] > plain["jain_fairness"], (
        f"wfq must raise Jain's weighted fairness "
        f"({wfq['jain_fairness']} vs {plain['jain_fairness']})"
    )
    assert wfq["goodput_tok_s"] >= 0.9 * plain["goodput_tok_s"], (
        f"wfq aggregate goodput must stay within 10% of plain-wisp "
        f"({wfq['goodput_tok_s']} vs {plain['goodput_tok_s']})"
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows, save_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    save_rows("tenancy", rows)
    print_rows(rows)
