"""Paper Tables 7/12 + App. C: verification-time estimator profiling.

The paper profiles vLLM micro-batches on A100; here the measured target is
the functional verification engine on CPU (reduced config) — the point of
the table is the *pipeline*: design a stratified config set (compute-bound /
memory-bound / mixed), measure, fit OLS with bootstrap CIs, validate on
held-out configs."""
from __future__ import annotations

import time

import numpy as np

from repro.core.estimator import batch_features, evaluate, fit_ols
from repro.core.estimator import BatchShape


def _measure_engine_dataset(n_train=60, n_test=25, seed=0):
    """Profile the real (CPU, reduced-config) verification engine across
    stratified batch shapes, mirroring App. C's five categories."""
    import jax
    from repro.configs import get_config
    from repro.models import build
    from repro.serving.engine import VerificationEngine, VerifyItem

    cfg = get_config("qwen2-7b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = VerificationEngine(cfg, params, max_slots=8, max_len=512)
    rng = np.random.default_rng(seed)
    slots = []
    for i in range(8):
        s, _ = eng.new_session(rng.integers(2, cfg.vocab, size=24).tolist())
        slots.append(s)

    def one_config(kind):
        nb = int(rng.integers(1, 5))
        items, shapes = [], []
        for _ in range(nb):
            slot = slots[int(rng.integers(0, len(slots)))]
            if kind == "compute":
                k = int(rng.integers(8, 16))
            elif kind == "memory":
                k = int(rng.integers(1, 4))
            else:
                k = int(rng.integers(1, 16))
            toks = rng.integers(0, cfg.vocab, size=k).astype(np.int32)
            items.append(VerifyItem(slot=slot, draft_tokens=toks,
                                    q_logits=np.zeros((k, cfg.vocab),
                                                      np.float32)))
            shapes.append(BatchShape(new_tokens=k + 1,
                                     cached_tokens=int(eng.fed[slot])))
        feats = batch_features(shapes)
        # warm the jit cache shape buckets first
        t0 = time.perf_counter()
        eng.verify(items)
        dt = time.perf_counter() - t0
        return feats, dt

    kinds = ["compute", "memory", "mixed"]
    # warmup (compile per bucket)
    for kind in kinds:
        one_config(kind)
    data = []
    for i in range(n_train + n_test):
        data.append(one_config(kinds[i % 3]))
    X = np.stack([d[0] for d in data])
    y = np.array([d[1] for d in data])
    return (X[:n_train], y[:n_train]), (X[n_train:], y[n_train:])


def run(quick: bool = True) -> list[dict]:
    (Xtr, ytr), (Xte, yte) = _measure_engine_dataset(
        n_train=40 if quick else 123, n_test=16 if quick else 50
    )
    fit = fit_ols(Xtr, ytr, bootstrap=200)
    test = evaluate(fit.coeffs, Xte, yte)
    rows = [
        {
            "table": "estimator(T7/T12)",
            "split": "train",
            "samples": len(ytr),
            "r2": round(fit.r2, 4),
            "rmse_ms": round(fit.rmse * 1e3, 2),
            "mae_ms": round(fit.mae * 1e3, 2),
            "mape_pct": round(fit.mape, 2),
            "max_err_ms": round(fit.max_err * 1e3, 2),
        },
        {
            "table": "estimator(T7/T12)",
            "split": "test",
            "samples": len(yte),
            "r2": round(test["r2"], 4),
            "rmse_ms": round(test["rmse"] * 1e3, 2),
            "mae_ms": round(test["mae"] * 1e3, 2),
            "mape_pct": round(test["mape"], 2),
            "max_err_ms": round(test["max_err"] * 1e3, 2),
        },
        {
            "table": "estimator_coeffs(T12)",
            "a_us_per_token": round(fit.coeffs.a * 1e6, 3),
            "b_compute_ns_per_inter": round(fit.coeffs.b_compute * 1e9, 4),
            "b_read_us_per_cached": round(fit.coeffs.b_read * 1e6, 4),
            "c_ms": round(fit.coeffs.c * 1e3, 3),
            "ci95_a": fit.ci95["a"] if fit.ci95 else None,
        },
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows

    print_rows(run())
