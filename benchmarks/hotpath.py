"""Verification hot-path microbenchmark: dispatches/epoch, host<->device
bytes/epoch, and verify wall time per backend (DESIGN.md §9).

Measures the engine's own counters (``VerificationEngine.stats`` /
``dispatch_counts``) around batched ``verify`` epochs for every backend
(dense-slot attention, paged attention, recurrent) and every draft-q
representation (dense logits, compact top-C table, greedy/none), then
**asserts the hot-path budgets** so CI fails on a regression:

  * the fused per-epoch program dispatches exactly ONCE per verify call on
    every backend — in particular the recurrent backend is O(1) in K
    (measured at two draft lengths), where the pre-refactor stepwise loop
    was K+2 dispatches and K+2 live state copies;
  * at V >= 32k with C = 64, compact-q staging is >= 10x smaller than
    dense-q staging; greedy stages no q bytes at all.

Rows are written to ``BENCH_hotpath.json`` at the repo root: rows with
``phase="seed"`` are the pre-refactor baseline measured at the seed commit
(dispatch counts measured by wrapping the seed engine's jitted callables;
staged bytes computed from the seed staging buffers' shapes) and are
preserved verbatim; ``phase="current"`` rows are refreshed every run —
the file is the repo's hot-path perf trajectory.

Usage: PYTHONPATH=src:. python benchmarks/hotpath.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.speculative import compact_from_logits
from repro.models import build
from repro.serving.engine import VerificationEngine, VerifyItem

from benchmarks.common import print_rows

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_hotpath.json")

V = 32768          # vocab large enough that q staging dominates (>= 32k)
C = 64             # compact top-C table width

BACKENDS = ("dense", "paged", "recurrent")
Q_MODES = ("dense", "compact", "greedy")


def _make_engine(backend: str, q_mode: str, max_slots: int):
    name = {"dense": "qwen2-7b", "paged": "qwen2-7b",
            "recurrent": "xlstm-350m"}[backend]
    cfg = dataclasses.replace(get_config(name).reduced(), vocab=V,
                              name=name + "-hotpath")
    bundle = build(cfg)
    method = "greedy" if q_mode == "greedy" else "residual"
    if cfg.family in ("ssm", "hybrid"):
        params = bundle.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        kw = {"cache_dtype": jnp.float32}
    else:
        params = bundle.init(jax.random.PRNGKey(0))
        kw = {"paged": backend == "paged"}
        if backend == "paged":
            kw["page_size"] = 16
    return cfg, VerificationEngine(cfg, params, max_slots=max_slots,
                                   max_len=256, method=method, seed=0, **kw)


def _items(slots, K: int, q_mode: str, rnd: int):
    out = []
    for i, s in enumerate(slots):
        g = np.random.default_rng(100 * rnd + i)
        toks = g.integers(0, V, size=K).astype(np.int32)
        qlog = (g.normal(size=(K, V)) * 1.5).astype(np.float32)
        it = VerifyItem(slot=s, draft_tokens=toks, rng_tag=(i, rnd))
        if q_mode == "dense":
            it.q_logits = qlog
        elif q_mode == "compact":
            it.q_compact = compact_from_logits(qlog, toks, C=C)
        out.append(it)
    return out


def bench_cell(backend: str, q_mode: str, *, B: int, K: int,
               epochs: int) -> dict:
    cfg, eng = _make_engine(backend, q_mode, B)
    rng = np.random.default_rng(0)
    slots = [eng.new_session(rng.integers(0, V, size=8).astype(np.int32))[0]
             for _ in range(B)]
    eng.verify(_items(slots, K, q_mode, 0))             # warmup / compile
    base = dict(eng.stats)
    base_verify = eng.dispatch_counts["verify"]
    t0 = time.perf_counter()
    for r in range(1, 1 + epochs):
        eng.verify(_items(slots, K, q_mode, r))
    dt = (time.perf_counter() - t0) / epochs
    d = {k: eng.stats[k] - base[k] for k in
         ("dispatches", "h2d_bytes", "h2d_q_bytes", "d2h_bytes")}
    return {
        "table": "hotpath", "phase": "current", "backend": backend,
        "method": eng.method,
        "q_mode": {"greedy": "none"}.get(q_mode, q_mode),
        "B": B, "K": K, "V": V, "C": C if q_mode == "compact" else None,
        "dispatches_per_epoch": d["dispatches"] / epochs,
        "verify_dispatches_per_epoch":
            (eng.dispatch_counts["verify"] - base_verify) / epochs,
        "h2d_bytes_per_epoch": d["h2d_bytes"] // epochs,
        "h2d_q_bytes_per_epoch": d["h2d_q_bytes"] // epochs,
        "d2h_bytes_per_epoch": d["d2h_bytes"] // epochs,
        "state_copies": 1,            # the scan carries one selected state
        "t_verify_ms": round(dt * 1e3, 3),
    }


def run(quick: bool = True) -> list[dict]:
    B = 2 if quick else 4
    K = 8
    epochs = 2 if quick else 8
    rows = []
    for backend in BACKENDS:
        for q_mode in Q_MODES:
            rows.append(bench_cell(backend, q_mode, B=B, K=K, epochs=epochs))
    # O(1)-in-K evidence: the recurrent fused program must cost the same
    # dispatch count at half the draft length
    rows.append(bench_cell("recurrent", "dense", B=B, K=K // 2,
                           epochs=epochs))

    # -- budget assertions (CI gate) -------------------------------------
    for r in rows:
        assert r["verify_dispatches_per_epoch"] == 1.0, (
            f"hot-path regression: {r['backend']}/{r['q_mode']} runs "
            f"{r['verify_dispatches_per_epoch']} fused verify dispatches "
            f"per epoch (budget: 1)"
        )
    rec = [r for r in rows if r["backend"] == "recurrent"]
    ks = {r["K"]: r["verify_dispatches_per_epoch"] for r in rec}
    assert len(set(ks.values())) == 1, (
        f"recurrent verify dispatches must be O(1) in K, got {ks}"
    )
    by = {(r["backend"], r["q_mode"]): r for r in rows if r["K"] == K}
    for backend in BACKENDS:
        dense_q = by[(backend, "dense")]["h2d_q_bytes_per_epoch"]
        compact_q = by[(backend, "compact")]["h2d_q_bytes_per_epoch"]
        greedy_q = by[(backend, "none")]["h2d_q_bytes_per_epoch"]
        assert greedy_q == 0, f"{backend}: greedy staged {greedy_q} q bytes"
        assert dense_q >= 10 * max(compact_q, 1), (
            f"{backend}: compact q staging {compact_q}B is not >= 10x "
            f"smaller than dense {dense_q}B at V={V}, C={C}"
        )
        dense_all = by[(backend, "dense")]["h2d_bytes_per_epoch"]
        compact_all = by[(backend, "compact")]["h2d_bytes_per_epoch"]
        assert dense_all >= 10 * compact_all, (
            f"{backend}: total staged bytes {compact_all}B not >= 10x "
            f"below dense {dense_all}B"
        )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few epochs (CI)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    # preserve the committed seed-baseline rows; refresh the current rows
    seed_rows = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            seed_rows = [r for r in json.load(f) if r.get("phase") == "seed"]
    with open(OUT_PATH, "w") as f:
        json.dump(seed_rows + rows, f, indent=1)
    print_rows(rows)
    if seed_rows:
        base = {r["backend"]: r for r in seed_rows}
        cur = {(r["backend"], r["q_mode"]): r for r in rows if r["K"] == 8}
        for backend in BACKENDS:
            s, c = base[backend], cur[(backend, "compact")]
            # seed and current rows may have been measured at different
            # batch sizes (--smoke shrinks B): compare PER-ROW bytes
            sb = s["h2d_bytes_per_epoch"] / s["B"]
            cb = c["h2d_bytes_per_epoch"] / c["B"]
            print(
                f"[hotpath] {backend}: dispatches/epoch "
                f"{s['dispatches_per_epoch']:.0f} -> "
                f"{c['dispatches_per_epoch']:.0f}, staged bytes/epoch/row "
                f"{sb:.0f} -> {cb:.0f} ({sb / cb:.0f}x)"
            )
    print(f"[hotpath] budgets OK; wrote {os.path.abspath(OUT_PATH)}")


if __name__ == "__main__":
    main()
