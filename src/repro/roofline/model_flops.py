"""Analytic parameter / FLOP counting for the roofline's MODEL_FLOPS term.

MODEL_FLOPS per token = 6 * N (dense train) or 6 * N_active (MoE),
2 * N[_active] for inference; attention FLOPs added separately where the
context length matters.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig


def _attn_params(cfg: ArchConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    out = cfg.n_heads * hd * d
    return qkv + out


def _dense_mlp_params(cfg: ArchConfig) -> int:
    mult = 3 if cfg.gated_mlp else 2
    return mult * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ArchConfig, active: bool) -> int:
    m = cfg.moe
    fe = m.d_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * fe
    routed = (m.top_k if active else m.num_experts) * per_expert
    shared = m.num_shared_experts * per_expert
    router = cfg.d_model * m.num_experts
    return routed + shared + router


def _mamba2_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.expand * d
    n = ssm.state_dim
    return d * (2 * di + 2 * n + cfg.n_heads) + di * d + ssm.conv_kernel * (di + 2 * n)


def _mlstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    di = 2 * d
    return d * 2 * di + 3 * di * di + di * 2 * cfg.n_heads + di * d


def _slstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    hd = d // cfg.n_heads
    f = int(d * 4 / 3)
    return d * 4 * d + 4 * cfg.n_heads * hd * hd + d * 2 * f + f * d


def _layer_params(cfg: ArchConfig) -> tuple[int, int]:
    """(total_per_layer_sum, active_per_layer_sum) over all layers."""
    fam = cfg.family
    if fam in ("dense", "vlm"):
        per = _attn_params(cfg) + _dense_mlp_params(cfg)
        total = cfg.n_layers * per
        if fam == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            total += n_cross * (_attn_params(cfg) + _dense_mlp_params(cfg))
        return total, total
    if fam == "moe":
        attn = _attn_params(cfg)
        tot = cfg.n_layers * (attn + _moe_params(cfg, active=False))
        act = cfg.n_layers * (attn + _moe_params(cfg, active=True))
        return tot, act
    if fam == "ssm":
        per = cfg.ssm.slstm_every
        n_groups = cfg.n_layers // per
        tot = n_groups * ((per - 1) * _mlstm_params(cfg) + _slstm_params(cfg))
        return tot, tot
    if fam == "hybrid":
        n_apps = (cfg.n_layers + cfg.ssm.attn_every - 1) // cfg.ssm.attn_every
        shared = _attn_params(cfg) + _dense_mlp_params(cfg)
        tot = cfg.n_layers * _mamba2_params(cfg) + shared
        act = cfg.n_layers * _mamba2_params(cfg) + n_apps * shared
        return tot, act
    if fam == "audio":
        enc = cfg.encoder_layers * (_attn_params(cfg) + _dense_mlp_params(cfg))
        dec = cfg.n_layers * (2 * _attn_params(cfg) + _dense_mlp_params(cfg))
        t = enc + dec
        return t, t
    raise ValueError(fam)


def param_count(cfg: ArchConfig) -> int:
    body, _ = _layer_params(cfg)
    emb = cfg.vocab * cfg.d_model
    unemb = 0 if cfg.tie_embeddings else cfg.vocab * cfg.d_model
    return body + emb + unemb


def active_param_count(cfg: ArchConfig) -> int:
    """Per-token-active params (MoE counts only routed top-k + shared)."""
    _, act = _layer_params(cfg)
    emb = cfg.vocab * cfg.d_model  # unembed matmul is per-token active
    return act + emb


def uncounted_sequential_flops(cfg: ArchConfig, seq: int, batch: int) -> float:
    """FLOPs inside per-token recurrence loops that stay rolled even in the
    dry-run's cost-unroll mode (trip count seq > loops.UNROLL_LIMIT), so
    ``cost_analysis`` counts their body once.  Only the xLSTM family has
    such a loop (the sLSTM recurrent gate matmul); everything else is
    chunk-parallel.  Returns the *global* FLOPs shortfall."""
    if cfg.family != "ssm" or not cfg.ssm or not cfg.ssm.slstm_every:
        return 0.0
    n_groups = cfg.n_layers // cfg.ssm.slstm_every
    d = cfg.d_model
    hd = d // cfg.n_heads
    per_token = 2 * batch * 4 * d * hd + 40.0 * batch * d   # rec matmul + gates
    return n_groups * (seq - 1) * per_token   # body counted once already


def model_flops(cfg: ArchConfig, n_tokens: int, *, training: bool) -> float:
    """6*N*D (train) or 2*N*D (inference) with N = active params."""
    n = active_param_count(cfg)
    mult = 6.0 if training else 2.0
    return mult * n * n_tokens


def decode_attention_flops(
    cfg: ArchConfig, kv_len: int, batch: int, t_new: int = 1
) -> float:
    """QK+AV FLOPs for t_new query tokens against a kv_len cache."""
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.family == "hybrid":
        n_attn = (cfg.n_layers + cfg.ssm.attn_every - 1) // cfg.ssm.attn_every
    elif cfg.family == "audio":
        # decoder self-attn over kv_len + cross-attn over encoder frames
        n_attn = cfg.n_layers
        cross = 2 * 2 * batch * cfg.n_heads * t_new * cfg.encoder_frames * hd
        return n_attn * (2 * 2 * batch * cfg.n_heads * t_new * kv_len * hd + cross)
    else:
        n_attn = cfg.n_layers
    return n_attn * 2 * 2 * batch * cfg.n_heads * t_new * kv_len * hd


def attention_flops(cfg: ArchConfig, seq: int, batch: int, *, causal=True) -> float:
    """Quadratic attention term for full-sequence passes (per forward)."""
    if cfg.family == "ssm":
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.family == "hybrid":
        n_attn = (cfg.n_layers + cfg.ssm.attn_every - 1) // cfg.ssm.attn_every
    elif cfg.family == "audio":
        n_attn = cfg.encoder_layers + 2 * cfg.n_layers
    else:
        n_attn = cfg.n_layers
    per_layer = 2 * 2 * batch * cfg.n_heads * seq * seq * hd
    if causal:
        per_layer /= 2
    return n_attn * per_layer
