"""Parse collective traffic out of compiled (SPMD-partitioned) HLO text.

Shapes in the partitioned module are PER-DEVICE.  For each collective we
estimate the per-device link traffic from the printed result shape and the
replica-group size n (ring algorithms):

    all-gather         out = full tensor     -> bytes * (n-1)/n
    all-reduce         out = full tensor     -> 2 * bytes * (n-1)/n
    reduce-scatter     out = 1/n shard       -> bytes * (n-1)
    all-to-all         out                   -> bytes * (n-1)/n
    collective-permute out                   -> bytes

``collective_bytes_global`` multiplies per-device traffic by the number of
participating devices, matching the roofline's
``collective_bytes / (chips * link_bw)`` convention.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-gather.3 = f32[256,512]{0,1} all-gather(%x), channel_id=1,
#       replica_groups={{0,1,2,3},{4,5,6,7}}, ...
_INSTR = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Yield dicts {kind, bytes_per_device_result, group_size} per op."""
    out = []
    for line in hlo_text.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; avoid double counting
        dtype, dims, kind = m.groups()
        size = _shape_bytes(dtype, dims)
        # handle tuple results of async collectives crudely: count once
        g = _GROUPS.search(line)
        if g:
            group = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA.search(line)
            group = int(gi.group(2)) if gi else 1
        out.append({"kind": kind, "bytes": size, "group": group})
    return out


def _per_device_traffic(kind: str, nbytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return nbytes * (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * nbytes * (n - 1) / n
    if kind == "reduce-scatter":
        return nbytes * (n - 1)
    if kind == "all-to-all":
        return nbytes * (n - 1) / n
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def collective_summary(hlo_text: str) -> dict:
    """Aggregate: per-kind counts, per-device traffic bytes, and global
    collective_bytes (per-device traffic x participants)."""
    ops = parse_collectives(hlo_text)
    per_kind: dict[str, dict] = {}
    total_dev = 0.0
    total_global = 0.0
    for op in ops:
        t = _per_device_traffic(op["kind"], op["bytes"], op["group"])
        e = per_kind.setdefault(
            op["kind"], {"count": 0, "bytes_per_device": 0.0, "bytes_global": 0.0}
        )
        e["count"] += 1
        e["bytes_per_device"] += t
        e["bytes_global"] += t * op["group"]
        total_dev += t
        total_global += t * op["group"]
    return {
        "per_kind": per_kind,
        "bytes_per_device": total_dev,
        "bytes_global": total_global,
        "n_collectives": len(ops),
    }
