"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs_global   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_global   / (chips * HBM_BW)
    collective = collective_bytes   / (chips * LINK_BW)

cost_analysis() reports the per-device partitioned program; global terms are
per-device * chips.  collective_bytes comes from the HLO parser.
"""
from __future__ import annotations

import dataclasses

from repro.roofline.hlo_parse import collective_summary

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    collective_bytes_global: float
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float
    collectives: dict
    memory_per_device: dict

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline: time the chip must spend
        anyway (compute) / the binding term."""
        return self.t_compute / max(self.bound_time, 1e-30)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(
            dominant=self.dominant,
            bound_time=self.bound_time,
            useful_fraction=self.useful_fraction,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    memory_stats,
    model_flops: float,
    collectives_override: dict | None = None,
) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collectives_override or collective_summary(hlo_text)
    mem = {
        "args_bytes": getattr(memory_stats, "argument_size_in_bytes", 0),
        "output_bytes": getattr(memory_stats, "output_size_in_bytes", 0),
        "temp_bytes": getattr(memory_stats, "temp_size_in_bytes", 0),
        "code_bytes": getattr(memory_stats, "generated_code_size_in_bytes", 0),
    }
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        collective_bytes_global=coll["bytes_global"],
        model_flops=model_flops,
        t_compute=flops_dev / PEAK_FLOPS,
        t_memory=bytes_dev / HBM_BW,
        t_collective=coll["bytes_per_device"] / LINK_BW,
        collectives=coll["per_kind"],
        memory_per_device=mem,
    )
