"""Sharded host data loading.

``ShardedLoader`` wraps a per-host batch iterator and produces globally
sharded ``jax.Array`` batches for a mesh:

  * each host generates only its addressable slice of the global batch
    (index-sharded by host id — deterministic via the synthetic stream's
    stateless random access, so no host ever reads another's slice);
  * arrays are assembled with ``jax.make_array_from_process_local_data``;
  * the loader state is just the step counter — checkpointable and
    elastically restorable on a different host count (the stream is
    indexed by global sample id, not by host).
"""
from __future__ import annotations

import numpy as np

import jax

from repro.data.synthetic import SyntheticLMConfig, SyntheticStream


class ShardedLoader:
    def __init__(
        self,
        cfg: SyntheticLMConfig,
        global_batch: int,
        sharding,                      # NamedSharding for (B, S) batches
        *,
        start_step: int = 0,
        extras_fn=None,                # cfg-specific extra inputs (vlm/audio)
    ):
        self.cfg = cfg
        self.global_batch = global_batch
        self.sharding = sharding
        self.step = start_step
        self.extras_fn = extras_fn
        self.stream = SyntheticStream(cfg)
        self._host_id = jax.process_index()
        self._n_hosts = jax.process_count()
        if global_batch % self._n_hosts:
            raise ValueError("global batch must divide host count")
        self._per_host = global_batch // self._n_hosts

    def _global_ids(self) -> np.ndarray:
        lo = self.step * self.global_batch + self._host_id * self._per_host
        return np.arange(lo, lo + self._per_host, dtype=np.int64)

    def __iter__(self):
        return self

    def __next__(self):
        seqs = self.stream.sequences(self._global_ids())
        local = {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}
        batch = {
            k: jax.make_array_from_process_local_data(self.sharding, v)
            for k, v in local.items()
        }
        if self.extras_fn is not None:
            batch.update(self.extras_fn(self.step))
        self.step += 1
        return batch

    # -- checkpointable state --------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict):
        self.step = int(d["step"])
