"""Deterministic synthetic LM corpus.

A seeded order-1 Markov stream over the vocabulary with Zipfian marginals:
cheap to generate at any offset (stateless hashing — no materialized corpus),
deterministic across restarts/hosts, and non-trivial for a model to fit
(bigram structure gives a learnable signal; loss drops measurably within a
few hundred steps on a ~100M model, which the train example asserts).

Layout contract: sample ``i`` of the infinite stream is fully determined by
``(seed, i)``, so any host can produce any slice — the property the sharded
loader and the elastic-restart path rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2          # marginal skew
    n_clusters: int = 64         # bigram block structure


def _hash_u64(x: np.ndarray) -> np.ndarray:
    """splitmix64 — vectorized stateless hashing."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x &= np.uint64(0xFFFFFFFFFFFFFFFF)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x &= np.uint64(0xFFFFFFFFFFFFFFFF)
    return x ^ (x >> np.uint64(31))


def _zipf_cdf(vocab: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, vocab + 1) ** a
    return np.cumsum(w) / w.sum()


class SyntheticStream:
    """Order-1 Markov token stream with stateless random access."""

    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        self._cdf = _zipf_cdf(cfg.vocab, cfg.zipf_a)
        # token -> cluster; next token drawn from the cluster's shifted zipf
        self._cluster = (
            _hash_u64(np.arange(cfg.vocab, dtype=np.uint64) ^ np.uint64(cfg.seed))
            % np.uint64(cfg.n_clusters)
        ).astype(np.int64)

    def sequences(self, index: np.ndarray) -> np.ndarray:
        """index: (B,) sequence ids -> (B, seq_len+1) int32 tokens."""
        cfg = self.cfg
        B = len(index)
        S = cfg.seq_len + 1
        base = index.astype(np.uint64) * np.uint64(1_000_003) + np.uint64(
            cfg.seed * 7_919
        )
        u = np.empty((B, S))
        for t in range(S):
            u[:, t] = (
                _hash_u64(base + np.uint64(t)) >> np.uint64(11)
            ).astype(np.float64) / float(1 << 53)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = np.searchsorted(self._cdf, u[:, 0])
        for t in range(1, S):
            # shift the zipf draw by the previous token's cluster: bigram
            prev_c = self._cluster[toks[:, t - 1]]
            raw = np.searchsorted(self._cdf, u[:, t])
            toks[:, t] = (raw + prev_c * 17) % self.cfg.vocab
        return toks.astype(np.int32)


def synthetic_batch_iter(cfg: SyntheticLMConfig, batch: int, start_step: int = 0):
    """Yields {'tokens': (B,S), 'targets': (B,S)} forever, deterministically
    resumable from any step."""
    stream = SyntheticStream(cfg)
    step = start_step
    while True:
        idx = np.arange(batch, dtype=np.int64) + step * batch
        seqs = stream.sequences(idx)
        yield {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}
        step += 1
