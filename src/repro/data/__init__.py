"""Data pipeline: deterministic synthetic corpora + sharded host loading."""
from repro.data.synthetic import SyntheticLMConfig, synthetic_batch_iter
from repro.data.pipeline import ShardedLoader

__all__ = ["SyntheticLMConfig", "synthetic_batch_iter", "ShardedLoader"]
