"""Event-driven cluster runtime over the *real* serving stack.

  events   — virtual clock + deterministic event heap
  workload — fleet specs, session churn, per-device rng streams
  runtime  — per-device processes overlapping drafting with verification
  metrics  — measured WDT / speculation / queueing / per-class violations

`repro.sim` answers "what would thousands of devices do" with analytic
latency + acceptance models; `repro.cluster` answers "what does the real
stack do" by clocking the actual EdgeDevice / WISPServer / NetworkModel
objects through a discrete-event loop (see docs/ARCHITECTURE.md §6).
"""
from repro.cluster.events import Event, EventKind, EventQueue
from repro.cluster.metrics import (
    ClusterMetrics,
    SessionRecord,
    SpecStats,
)
from repro.cluster.runtime import ClusterResult, ClusterRuntime
from repro.cluster.workload import (
    TENANT_MIXES,
    ClusterConfig,
    DeviceSpec,
    DeviceWorkload,
    TenantWorkload,
    build_fleet,
    build_tenant_registry,
)

__all__ = [
    "Event",
    "EventKind",
    "EventQueue",
    "ClusterMetrics",
    "SessionRecord",
    "SpecStats",
    "ClusterResult",
    "ClusterRuntime",
    "ClusterConfig",
    "DeviceSpec",
    "DeviceWorkload",
    "TENANT_MIXES",
    "TenantWorkload",
    "build_fleet",
    "build_tenant_registry",
]
