"""Cluster workload: device fleet + session churn configuration.

The fleet is fixed (N edge devices, heterogeneous draft speeds and SLO
classes); *sessions* churn on top of it.  In fixed-work mode every device
runs one session for exactly ``rounds`` speculate-verify rounds — the shape
the lock-step driver (`launch/serve.py --sync`) can replay for the
stream-equivalence guarantee.  In churn mode a device that finishes a
response thinks for an Exp(think_time_mean) pause and opens a fresh session
(Poisson session arrivals per device, stationary load, like `repro.sim`),
with geometric response-length targets; admission runs through the server's
queue, so capacity exhaustion turns arrivals into queueing, not crashes.

Fleet draws are deterministic per seed: draft speeds and SLO classes
cycle round-robin over the configured choices (every class is populated at
any fleet size), prompts come from one seeded generator.  Both drivers
(`launch/serve.py` event-driven and ``--sync``) build their fleet here, so
they always replay the same workload for a seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClusterConfig:
    """Knobs of the event-driven cluster runtime."""

    devices: int = 4
    #: fixed-work mode: verify-rounds per session (None => churn mode)
    rounds: int | None = 8
    #: churn mode: virtual-seconds horizon (required when rounds is None)
    horizon: float | None = None
    k_max: int = 6
    draft_speeds: tuple = (30.0, 50.0, 80.0)
    slo_class_choices: tuple = (1, 2, 3, 4)
    prompt_len: int = 8
    max_len: int = 512
    seed: int = 0
    #: overlap drafting with in-flight verification (commit-or-rollback)
    speculate: bool = True
    #: per-session draft-length policy from the speculation-controller
    #: registry (core/speculation.py): "static" (every block gets k_max,
    #: the legacy behavior) or "adaptive" (per-block K from predicted
    #: acceptance, measured RTT and verifier load, DESIGN.md §11)
    spec_policy: str = "static"
    #: heterogeneous edge links: per-device base RTTs (seconds), cycled
    #: round-robin like draft_speeds (device i gets link_rtts[i % len]);
    #: empty = every device shares the server NetworkModel's base_rtt
    link_rtts: tuple = ()
    # -- churn ------------------------------------------------------------
    think_time_mean: float = 0.25    # Exp pause between sessions per device
    response_len_mean: float = 24.0  # geometric response-token target
    # -- server timing ----------------------------------------------------
    dispatch_interval: float = 0.004
    #: verify-time jitter: t = estimator * LogNormal(0, sigma); 0 = exact
    latency_noise_sigma: float = 0.0
    # -- prompt prefill (DESIGN.md §8) ------------------------------------
    #: how prompt prefill is charged on the virtual clock:
    #:   "zero"       — legacy: prefill is instantaneous and free (the
    #:                  model compute runs, but no virtual time passes —
    #:                  understates interference for long prompts);
    #:   "monolithic" — prefill seizes the verifier for one blocking,
    #:                  estimator-priced span per prompt, OUTSIDE the
    #:                  scheduler (head-of-line interference, the paper's
    #:                  unsuppressed baseline);
    #:   "chunked"    — prefill is split into prefill_chunk_tokens-sized
    #:                  work items scheduled by Algorithm 1 against a TTFT
    #:                  deadline, interleaving with verification.
    prefill_mode: str = "zero"
    prefill_chunk_tokens: int = 32
    # -- edge->server draft payload (DESIGN.md §9) ------------------------
    #: q representation the edge devices ship with each drafted block:
    #: "dense" (full (K,V) logit rows, exact residual — the default),
    #: "compact" (per-token log-prob + top-C/tail table, O(K·C) payload,
    #: exact accept test / bounded-error residual) or "none" (greedy
    #: verification reads no q).  Drivers construct their EdgeDevices
    #: with the matching ``q_mode``; the runtime's uplink accounting
    #: prices whatever representation actually rides the request.
    q_mode: str = "dense"
    q_top_c: int = 64
    # -- verifier fleet (repro.fleet; ignored by the single-server runtime) -
    #: number of verifier replicas behind the prefix-locality router
    verifiers: int = 1
    #: deterministic failure injection: (verifier_index, t_fail,
    #: t_recover_or_None) tuples fed to `repro.runtime.FailurePlan` — the
    #: verifier stops executing/answering in [t_fail, t_recover)
    fail_at: tuple = ()
    #: deterministic straggler injection: (verifier_index, t0, t1, factor)
    #: tuples — the verifier's epochs run ``factor``x slower in [t0, t1)
    straggle: tuple = ()
    # -- edge-link fault domain (DESIGN.md §14) ----------------------------
    #: fault schedule for the edge<->server link + verifier fleet: a
    #: `repro.chaos.FaultSchedule`, a preset name ("lossy"/"flap"/"storm")
    #: or a DSL string ("drop=0.1,dup=0.05,linkdown@0.25+0.5,seed=7");
    #: None = perfectly reliable link (legacy).  Legacy ``fail_at`` /
    #: ``straggle`` rows are merged in by `resolve_fault_schedule`.
    fault_schedule: object = None
    #: per-round edge timeout (seconds) before an idempotent re-submission;
    #: None disables retries (a dropped message stalls its session — the
    #: ablation the chaos benchmark measures against)
    link_timeout: float | None = None
    #: exponential backoff factor between successive retries of one round
    link_backoff: float = 2.0
    #: uniform jitter fraction on each armed timeout (decorrelates retry
    #: storms; drawn from the (seed, session, round, attempt) key)
    link_retry_jitter: float = 0.1
    #: consecutive round-timeouts after which the link is declared DOWN
    #: (latches the speculation controller into K=1 until hysteretic
    #: recovery — only acted on when ``link_degrade`` is set)
    link_down_after: int = 3
    #: let link health degrade speculation depth (K shrinks under flap,
    #: K=1 while down).  Off by default: degradation lawfully changes the
    #: committed streams (like adaptive-K), so byte-identity holds only
    #: when this is off.
    link_degrade: bool = False
    #: per-message log-normal latency jitter sigma on the modelled network
    #: (seeded from cfg.seed; 0 = byte-identical to the fixed-RTT model)
    jitter_sigma: float = 0.0
    #: seconds between per-verifier liveness beats (also the failover
    #: sweep cadence floor; sweeps additionally run every dispatch epoch)
    heartbeat_interval: float = 0.05
    #: missed-beat window after which a verifier is declared dead
    heartbeat_timeout: float = 0.15
    #: hedge an in-flight round past hedge_factor x (eta + hedge_guard)
    hedge_factor: float = 8.0
    hedge_guard: float = 0.01
    # -- host KV spill tier (DESIGN.md §12) --------------------------------
    #: host-DRAM spill pool size in pages under each verifier's device page
    #: pool; 0 = no tier (OutOfPages stays a hard admission wall)
    kv_tier_pages: int = 0
    #: int8-quantize pages on spill (per-page scales; bit-exact-or-raw)
    spill_quantize: bool = False
    #: engine dispatches a session must sit idle before its private pages
    #: become spill candidates
    spill_idle_epochs: int = 2
    # -- multi-tenant serving (DESIGN.md §13) ------------------------------
    #: per-tenant device groups (`TenantWorkload` tuples).  Empty = the
    #: legacy single-tenant fleet: every device belongs to the implicit
    #: unlimited "default" tenant and all the draws below are untouched —
    #: which is what keeps the golden streams byte-identical.  Non-empty:
    #: the fleet is the concatenation of the groups (``cfg.devices`` is
    #: ignored) and each group's think/response overrides shape its load.
    tenant_workloads: tuple = ()
    #: fixed-work mode backoff before a REJECTED open retries (churn mode
    #: retries after the device's usual Exp(think_time_mean) pause)
    reject_retry: float = 0.25


@dataclasses.dataclass(frozen=True)
class TenantWorkload:
    """One tenant's device group + workload shape + admission contract.

    The workload half (``devices``, think/response overrides) shapes the
    offered load; the contract half (weight / rate / burst / budgets) is
    compiled into a `TenantSpec` by ``build_tenant_registry``.  ``None``
    overrides inherit the fleet-wide `ClusterConfig` values."""

    name: str
    devices: int = 1
    weight: float = 1.0
    slo_class: int | None = None
    think_time_mean: float | None = None
    response_len_mean: float | None = None
    rate_tokens_per_s: float | None = None
    burst_tokens: float = 512.0
    max_tokens_in_flight: int | None = None
    max_concurrency: int | None = None
    max_queued: int | None = None


#: named tenant mixes for `launch/serve.py --tenant-mix` and the tenancy
#: benchmark.  "victim" is always the well-behaved interactive tenant the
#: fairness assertions protect.
TENANT_MIXES: dict[str, tuple] = {
    # interactive chat arriving in bursts next to a steady batch consumer
    "bursty-chat": (
        TenantWorkload("chat", devices=2, weight=2.0, slo_class=2,
                       think_time_mean=0.05, response_len_mean=12.0),
        TenantWorkload("batch", devices=2, weight=1.0, slo_class=4,
                       think_time_mean=0.5, response_len_mean=48.0),
    ),
    # one relentless batch tenant, one light interactive tenant
    "steady-batch": (
        TenantWorkload("batch", devices=3, weight=1.0, slo_class=4,
                       think_time_mean=0.01, response_len_mean=64.0),
        TenantWorkload("interactive", devices=1, weight=2.0, slo_class=2,
                       think_time_mean=0.2, response_len_mean=16.0),
    ),
    # adversarial flood: many zero-think devices hammering the verifier
    # against a modest victim; the flood is rate-limited and the victim
    # is not — the configuration the tenancy benchmark asserts on
    "adversarial-flood": (
        TenantWorkload("victim", devices=2, weight=2.0, slo_class=2,
                       think_time_mean=0.05, response_len_mean=16.0),
        TenantWorkload("flood", devices=6, weight=1.0, slo_class=4,
                       think_time_mean=0.0005, response_len_mean=64.0,
                       rate_tokens_per_s=150.0, burst_tokens=48.0,
                       max_queued=4),
    ),
}


def build_tenant_registry(cfg: "ClusterConfig"):
    """Compile ``cfg.tenant_workloads`` into a `TenantRegistry` (one per
    run — share it across a verifier fleet for fleet-global budgets)."""
    from repro.tenancy import TenantRegistry, TenantSpec

    return TenantRegistry([
        TenantSpec(
            tenant=tw.name,
            weight=tw.weight,
            slo_class=tw.slo_class,
            rate_tokens_per_s=tw.rate_tokens_per_s,
            burst_tokens=tw.burst_tokens,
            max_tokens_in_flight=tw.max_tokens_in_flight,
            max_concurrency=tw.max_concurrency,
            max_queued=tw.max_queued,
        )
        for tw in cfg.tenant_workloads
    ])


@dataclasses.dataclass
class DeviceSpec:
    """One edge device's static draw: speed, SLO class, first prompt.

    ``tenant`` + the ``None``-able overrides come from the device's
    `TenantWorkload` group (defaults for the legacy single-tenant fleet)."""

    idx: int
    draft_speed: float
    slo_class: int
    prompt: list
    tenant: str = "default"
    think_time_mean: float | None = None
    response_len_mean: float | None = None


def build_fleet(cfg: ClusterConfig, vocab: int) -> list[DeviceSpec]:
    """Deterministic heterogeneous fleet: draft speeds and SLO classes are
    cycled round-robin (like `sim.DevicePopulation` — every class is
    populated at any fleet size, so per-class comparisons never divide by
    zero), prompts drawn from one generator seeded with cfg.seed.

    With ``cfg.tenant_workloads`` set, the fleet is the concatenation of
    the tenant groups: each group contributes ``tw.devices`` devices that
    inherit the group's tenant / SLO class / think-response overrides,
    while speeds keep cycling round-robin over the global index (so the
    speed mix stays comparable across tenant splits)."""
    rng = np.random.default_rng(cfg.seed)
    fleet = []
    if cfg.tenant_workloads:
        i = 0
        for tw in cfg.tenant_workloads:
            for _ in range(tw.devices):
                speed = float(cfg.draft_speeds[i % len(cfg.draft_speeds)])
                prompt = rng.integers(2, vocab, size=cfg.prompt_len).tolist()
                slo = tw.slo_class if tw.slo_class is not None else int(
                    cfg.slo_class_choices[i % len(cfg.slo_class_choices)])
                fleet.append(DeviceSpec(
                    idx=i, draft_speed=speed, slo_class=int(slo),
                    prompt=prompt, tenant=tw.name,
                    think_time_mean=tw.think_time_mean,
                    response_len_mean=tw.response_len_mean))
                i += 1
        return fleet
    for i in range(cfg.devices):
        speed = float(cfg.draft_speeds[i % len(cfg.draft_speeds)])
        prompt = rng.integers(2, vocab, size=cfg.prompt_len).tolist()
        slo_class = int(cfg.slo_class_choices[i % len(cfg.slo_class_choices)])
        fleet.append(DeviceSpec(idx=i, draft_speed=speed,
                                slo_class=slo_class, prompt=prompt))
    return fleet


class DeviceWorkload:
    """Deterministic per-device stream of follow-up sessions (churn mode).

    Each device owns an independent generator keyed by (seed, device), so
    the session sequence a device sees is invariant to what the rest of the
    fleet does — a prerequisite for the event-ordering determinism test.
    """

    def __init__(self, cfg: ClusterConfig, vocab: int, device_idx: int,
                 spec: DeviceSpec | None = None):
        self.cfg = cfg
        self.vocab = vocab
        self.rng = np.random.default_rng(cfg.seed * 7919 + 613 * device_idx + 1)
        self._think_mean = cfg.think_time_mean
        self._resp_mean = cfg.response_len_mean
        if spec is not None:
            if spec.think_time_mean is not None:
                self._think_mean = spec.think_time_mean
            if spec.response_len_mean is not None:
                self._resp_mean = spec.response_len_mean

    def think_time(self) -> float:
        return float(self.rng.exponential(self._think_mean))

    def next_prompt(self) -> list:
        return self.rng.integers(2, self.vocab, size=self.cfg.prompt_len).tolist()

    def response_target(self) -> int:
        return int(self.rng.geometric(1.0 / self._resp_mean))
