"""Cluster workload: device fleet + session churn configuration.

The fleet is fixed (N edge devices, heterogeneous draft speeds and SLO
classes); *sessions* churn on top of it.  In fixed-work mode every device
runs one session for exactly ``rounds`` speculate-verify rounds — the shape
the lock-step driver (`launch/serve.py --sync`) can replay for the
stream-equivalence guarantee.  In churn mode a device that finishes a
response thinks for an Exp(think_time_mean) pause and opens a fresh session
(Poisson session arrivals per device, stationary load, like `repro.sim`),
with geometric response-length targets; admission runs through the server's
queue, so capacity exhaustion turns arrivals into queueing, not crashes.

Fleet draws are deterministic per seed: draft speeds and SLO classes
cycle round-robin over the configured choices (every class is populated at
any fleet size), prompts come from one seeded generator.  Both drivers
(`launch/serve.py` event-driven and ``--sync``) build their fleet here, so
they always replay the same workload for a seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClusterConfig:
    """Knobs of the event-driven cluster runtime."""

    devices: int = 4
    #: fixed-work mode: verify-rounds per session (None => churn mode)
    rounds: int | None = 8
    #: churn mode: virtual-seconds horizon (required when rounds is None)
    horizon: float | None = None
    k_max: int = 6
    draft_speeds: tuple = (30.0, 50.0, 80.0)
    slo_class_choices: tuple = (1, 2, 3, 4)
    prompt_len: int = 8
    max_len: int = 512
    seed: int = 0
    #: overlap drafting with in-flight verification (commit-or-rollback)
    speculate: bool = True
    #: per-session draft-length policy from the speculation-controller
    #: registry (core/speculation.py): "static" (every block gets k_max,
    #: the legacy behavior) or "adaptive" (per-block K from predicted
    #: acceptance, measured RTT and verifier load, DESIGN.md §11)
    spec_policy: str = "static"
    #: heterogeneous edge links: per-device base RTTs (seconds), cycled
    #: round-robin like draft_speeds (device i gets link_rtts[i % len]);
    #: empty = every device shares the server NetworkModel's base_rtt
    link_rtts: tuple = ()
    # -- churn ------------------------------------------------------------
    think_time_mean: float = 0.25    # Exp pause between sessions per device
    response_len_mean: float = 24.0  # geometric response-token target
    # -- server timing ----------------------------------------------------
    dispatch_interval: float = 0.004
    #: verify-time jitter: t = estimator * LogNormal(0, sigma); 0 = exact
    latency_noise_sigma: float = 0.0
    # -- prompt prefill (DESIGN.md §8) ------------------------------------
    #: how prompt prefill is charged on the virtual clock:
    #:   "zero"       — legacy: prefill is instantaneous and free (the
    #:                  model compute runs, but no virtual time passes —
    #:                  understates interference for long prompts);
    #:   "monolithic" — prefill seizes the verifier for one blocking,
    #:                  estimator-priced span per prompt, OUTSIDE the
    #:                  scheduler (head-of-line interference, the paper's
    #:                  unsuppressed baseline);
    #:   "chunked"    — prefill is split into prefill_chunk_tokens-sized
    #:                  work items scheduled by Algorithm 1 against a TTFT
    #:                  deadline, interleaving with verification.
    prefill_mode: str = "zero"
    prefill_chunk_tokens: int = 32
    # -- edge->server draft payload (DESIGN.md §9) ------------------------
    #: q representation the edge devices ship with each drafted block:
    #: "dense" (full (K,V) logit rows, exact residual — the default),
    #: "compact" (per-token log-prob + top-C/tail table, O(K·C) payload,
    #: exact accept test / bounded-error residual) or "none" (greedy
    #: verification reads no q).  Drivers construct their EdgeDevices
    #: with the matching ``q_mode``; the runtime's uplink accounting
    #: prices whatever representation actually rides the request.
    q_mode: str = "dense"
    q_top_c: int = 64
    # -- verifier fleet (repro.fleet; ignored by the single-server runtime) -
    #: number of verifier replicas behind the prefix-locality router
    verifiers: int = 1
    #: deterministic failure injection: (verifier_index, t_fail,
    #: t_recover_or_None) tuples fed to `repro.runtime.FailurePlan` — the
    #: verifier stops executing/answering in [t_fail, t_recover)
    fail_at: tuple = ()
    #: deterministic straggler injection: (verifier_index, t0, t1, factor)
    #: tuples — the verifier's epochs run ``factor``x slower in [t0, t1)
    straggle: tuple = ()
    #: seconds between per-verifier liveness beats (also the failover
    #: sweep cadence floor; sweeps additionally run every dispatch epoch)
    heartbeat_interval: float = 0.05
    #: missed-beat window after which a verifier is declared dead
    heartbeat_timeout: float = 0.15
    #: hedge an in-flight round past hedge_factor x (eta + hedge_guard)
    hedge_factor: float = 8.0
    hedge_guard: float = 0.01
    # -- host KV spill tier (DESIGN.md §12) --------------------------------
    #: host-DRAM spill pool size in pages under each verifier's device page
    #: pool; 0 = no tier (OutOfPages stays a hard admission wall)
    kv_tier_pages: int = 0
    #: int8-quantize pages on spill (per-page scales; bit-exact-or-raw)
    spill_quantize: bool = False
    #: engine dispatches a session must sit idle before its private pages
    #: become spill candidates
    spill_idle_epochs: int = 2


@dataclasses.dataclass
class DeviceSpec:
    """One edge device's static draw: speed, SLO class, first prompt."""

    idx: int
    draft_speed: float
    slo_class: int
    prompt: list


def build_fleet(cfg: ClusterConfig, vocab: int) -> list[DeviceSpec]:
    """Deterministic heterogeneous fleet: draft speeds and SLO classes are
    cycled round-robin (like `sim.DevicePopulation` — every class is
    populated at any fleet size, so per-class comparisons never divide by
    zero), prompts drawn from one generator seeded with cfg.seed."""
    rng = np.random.default_rng(cfg.seed)
    fleet = []
    for i in range(cfg.devices):
        speed = float(cfg.draft_speeds[i % len(cfg.draft_speeds)])
        prompt = rng.integers(2, vocab, size=cfg.prompt_len).tolist()
        slo_class = int(cfg.slo_class_choices[i % len(cfg.slo_class_choices)])
        fleet.append(DeviceSpec(idx=i, draft_speed=speed,
                                slo_class=slo_class, prompt=prompt))
    return fleet


class DeviceWorkload:
    """Deterministic per-device stream of follow-up sessions (churn mode).

    Each device owns an independent generator keyed by (seed, device), so
    the session sequence a device sees is invariant to what the rest of the
    fleet does — a prerequisite for the event-ordering determinism test.
    """

    def __init__(self, cfg: ClusterConfig, vocab: int, device_idx: int):
        self.cfg = cfg
        self.vocab = vocab
        self.rng = np.random.default_rng(cfg.seed * 7919 + 613 * device_idx + 1)

    def think_time(self) -> float:
        return float(self.rng.exponential(self.cfg.think_time_mean))

    def next_prompt(self) -> list:
        return self.rng.integers(2, self.vocab, size=self.cfg.prompt_len).tolist()

    def response_target(self) -> int:
        return int(self.rng.geometric(1.0 / self.cfg.response_len_mean))
