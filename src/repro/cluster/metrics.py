"""Measured cluster metrics: WDT, speculation outcomes, queueing, goodput.

Everything here is *measured* from the event-driven execution of the real
models — accept/reject outcomes come from actual target verification, waste
from tokens that really were drafted and really were thrown away — in
contrast to `repro.sim`, whose acceptance is an analytic model.  Timing
(draft steps, verify epochs, transport) runs on the virtual clock, so the
numbers are reproducible and hardware-independent.

Waste accounting extends the paper's Eq. 7 to the pipelined runtime.  A
drafted token can die three ways:

  * **rejected**   — submitted, refused by the target (lock-step waste,
                     ``IterationLog.wasted``);
  * **discarded**  — drafted speculatively during an overlap window, then
                     rolled back because the verdict invalidated the guess;
  * **spent guess**— the bonus-token guess a max-stopped block pays for,
                     when the verdict contradicts it.

Measured WDT seconds = Σ tau_d · (all three), accumulated per device so
heterogeneous draft speeds weight correctly.
"""
from __future__ import annotations

import dataclasses

from repro.core.wdt import IterationLog, WDTStats


@dataclasses.dataclass
class SpecStats:
    """Speculative-continuation outcomes (cluster runtime only)."""

    guesses: int = 0          # speculations begun
    commits: int = 0          # verdicts confirming guess (overlap salvaged)
    rollbacks: int = 0        # verdicts invalidating it
    abandoned: int = 0        # session ended with speculation outstanding
    salvaged: int = 0         # overlap-drafted tokens kept on commit
    discarded: int = 0        # overlap-drafted tokens rolled back
    guess_tokens_spent: int = 0   # extra decode steps paid for guesses
    guess_tokens_dead: int = 0    # ...of which the verdict contradicted

    @property
    def commit_rate(self) -> float:
        n = self.commits + self.rollbacks
        return self.commits / max(n, 1)


@dataclasses.dataclass
class ChaosStats:
    """Edge-link fault-domain counters (DESIGN.md §14).

    Transport counters (``uplink_*`` / ``downlink_*``) are what the
    `FaultyTransport` actually did to messages; recovery counters
    (``retries`` .. ``degraded_rounds``) are how the edge reacted; the
    dedup counters prove idempotency did its job (every duplicate or
    stale message was absorbed without touching the committed stream)."""

    retries: int = 0                  # re-submissions fired by RETRY_TIMER
    timeouts: int = 0                 # round timeouts observed
    link_down_events: int = 0         # DOWN latches (consecutive timeouts)
    link_up_events: int = 0           # hysteretic recoveries
    degraded_rounds: int = 0          # rounds whose K was shrunk by health
    uplink_drops: int = 0             # draft requests lost in flight
    uplink_dups: int = 0              # draft requests duplicated in flight
    downlink_drops: int = 0           # verdicts lost in flight
    downlink_dups: int = 0            # verdicts duplicated in flight
    dup_verdicts_dropped: int = 0     # device-side stale/dup verdict drops
    stale_requests_dropped: int = 0   # runtime-side stale request drops
    dup_submits_dropped: int = 0      # server-side in-flight dup drops
    verdicts_replayed: int = 0        # server re-sent a cached verdict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SessionRecord:
    """One completed (or horizon-truncated) session: the SLO unit."""

    session_id: int
    device: int
    slo_class: int
    slo_speed: float
    t_open: float
    t_close: float
    committed: int            # response tokens committed
    rounds: int
    #: time-to-first-token: from the client's open request to the first
    #: token reaching the device (prefill + queueing + downlink).  0.0
    #: under prefill_mode="zero", where prefill costs no virtual time.
    ttft: float = 0.0
    tenant: str = "default"

    @property
    def speed(self) -> float:
        return self.committed / max(self.t_close - self.t_open, 1e-9)

    @property
    def violated(self) -> bool:
        return self.speed < self.slo_speed


class ClusterMetrics:
    """Accumulates per-iteration logs, speculation outcomes and session
    records; aggregates per SLO class."""

    def __init__(self, slo_classes: dict):
        self.slo_classes = dict(slo_classes)
        self.iterations: list[IterationLog] = []
        self.sessions: list[SessionRecord] = []
        self.per_session: dict[int, WDTStats] = {}
        self.spec = SpecStats()
        #: edge-link fault-domain counters (all zero on a reliable link)
        self.chaos = ChaosStats()
        self.queue_samples: list[tuple[float, int]] = []
        #: admission-control sheds per tenant (REJECTED events)
        self.rejections: dict[str, int] = {}
        #: measured WDT seconds (tau-weighted; see module docstring)
        self.t_wdt = 0.0
        #: device-busy drafting seconds (every real decode step costs tau)
        self.t_drafting = 0.0

    # -- recording --------------------------------------------------------
    def add_iteration(self, it: IterationLog, tau_d: float):
        self.iterations.append(it)
        st = self.per_session.setdefault(it.session_id, WDTStats())
        st.add(it, tau_d)
        self.t_wdt += it.wdt(tau_d)
        self.t_drafting += it.n_drafted * tau_d

    def add_spec_outcome(self, *, committed: bool, overlap_tokens: int,
                         guess_tokens: int, tau_d: float):
        """One resolved speculation: ``overlap_tokens`` spec-block tokens and
        ``guess_tokens`` (0 or 1) guess steps virtually completed during the
        wait.  Salvaged overlap tokens are NOT charged here — they become the
        head of the next submitted block and are charged by that block's
        ``add_iteration``; only dead work (rollback) and guess steps (never
        part of any block) are accounted now."""
        self.spec.guesses += 1
        self.spec.guess_tokens_spent += guess_tokens
        self.t_drafting += guess_tokens * tau_d
        if committed:
            self.spec.commits += 1
            self.spec.salvaged += overlap_tokens
        else:
            self.spec.rollbacks += 1
            self.spec.discarded += overlap_tokens
            self.spec.guess_tokens_dead += guess_tokens
            self.t_drafting += overlap_tokens * tau_d
            self.t_wdt += (overlap_tokens + guess_tokens) * tau_d

    def add_spec_abandoned(self, *, overlap_tokens: int, guess_tokens: int,
                           tau_d: float):
        """Speculation outstanding when its session ended (churn mode): the
        overlap work is dead, but no guess was ever judged."""
        self.spec.guesses += 1
        self.spec.abandoned += 1
        self.spec.discarded += overlap_tokens
        self.spec.guess_tokens_spent += guess_tokens
        self.spec.guess_tokens_dead += guess_tokens
        self.t_drafting += (overlap_tokens + guess_tokens) * tau_d
        self.t_wdt += (overlap_tokens + guess_tokens) * tau_d

    def close_session(self, rec: SessionRecord):
        self.sessions.append(rec)

    def add_rejection(self, tenant: str):
        self.rejections[tenant] = self.rejections.get(tenant, 0) + 1

    def sample_queue(self, t: float, depth: int):
        self.queue_samples.append((t, depth))

    # -- aggregates -------------------------------------------------------
    @property
    def total(self) -> WDTStats:
        tot = WDTStats()
        for it in self.iterations:
            tot.add(it, 0.0)          # tau folded into self.t_wdt already
        return tot

    def goodput(self, horizon: float) -> float:
        """Committed tokens per virtual second across the fleet."""
        return sum(it.n_committed for it in self.iterations) / max(horizon, 1e-9)

    def waste_fraction(self) -> float:
        """Dead drafted tokens / all drafted tokens (incl. speculation).
        A guess that committed was paid for but *became* a committed token,
        so only rolled-back guess steps count as dead."""
        drafted = (sum(it.n_drafted for it in self.iterations)
                   + self.spec.discarded + self.spec.guess_tokens_spent)
        dead = (sum(it.wasted for it in self.iterations)
                + self.spec.discarded + self.spec.guess_tokens_dead)
        return dead / max(drafted, 1)

    def acceptance_rate(self) -> float:
        sent = sum(it.n_sent for it in self.iterations)
        return sum(it.n_accepted for it in self.iterations) / max(sent, 1)

    def mean_queue_time(self) -> float:
        if not self.iterations:
            return 0.0
        return sum(it.t_queue for it in self.iterations) / len(self.iterations)

    def per_class(self) -> dict:
        """Per-SLO-class measured aggregates (sessions + iterations)."""
        out = {}
        for cls, speed in sorted(self.slo_classes.items()):
            its = [it for it in self.iterations if it.slo_class == cls]
            ses = [s for s in self.sessions if s.slo_class == cls]
            out[cls] = {
                "slo_tok_s": speed,
                "sessions": len(ses),
                "session_violations": sum(s.violated for s in ses),
                "iterations": len(its),
                "deadline_violations": sum(it.violated for it in its),
                "committed": sum(it.n_committed for it in its),
                "mean_queue_s": (sum(it.t_queue for it in its) / len(its))
                if its else 0.0,
                "mean_speed_tok_s": (sum(s.speed for s in ses) / len(ses))
                if ses else 0.0,
            }
        return out

    def per_tenant(self, horizon: float) -> dict:
        """Per-tenant measured aggregates from the session records:
        goodput (committed response tokens / horizon), session counts,
        SLO violations, mean TTFT and admission rejections."""
        out = {}
        tenants = sorted({s.tenant for s in self.sessions}
                         | set(self.rejections))
        for tn in tenants:
            ses = [s for s in self.sessions if s.tenant == tn]
            out[tn] = {
                "sessions": len(ses),
                "committed": sum(s.committed for s in ses),
                "goodput_tok_s": sum(s.committed for s in ses)
                / max(horizon, 1e-9),
                "session_violations": sum(s.violated for s in ses),
                "mean_ttft_s": (sum(s.ttft for s in ses) / len(ses))
                if ses else 0.0,
                "rejections": self.rejections.get(tn, 0),
            }
        return out

    def jain_fairness(self, horizon: float,
                      weights: dict[str, float] | None = None) -> float:
        """Jain's index over weight-normalized per-tenant goodput:
        J = (Σ x)² / (n · Σ x²) with x_i = goodput_i / weight_i.  1.0 is
        a perfectly weighted-fair allocation; 1/n is maximally unfair.
        Returns 1.0 with fewer than two tenants."""
        pt = self.per_tenant(horizon)
        xs = [v["goodput_tok_s"] / max((weights or {}).get(tn, 1.0), 1e-9)
              for tn, v in pt.items()]
        if len(xs) < 2:
            return 1.0
        denom = len(xs) * sum(x * x for x in xs)
        if denom <= 0.0:
            return 1.0
        return sum(xs) ** 2 / denom

    def violations(self) -> int:
        """Session-level SLO violations (the paper's unit)."""
        return sum(s.violated for s in self.sessions)

    def deadline_violations(self) -> int:
        """Iteration-level deadline misses (Eq. 6 budget)."""
        return sum(it.violated for it in self.iterations)

    def deadline_violation_rate(self) -> float:
        return self.deadline_violations() / max(len(self.iterations), 1)

    # -- TTFT (chunked-prefill observability) -----------------------------
    def ttfts(self) -> list[float]:
        """Per-session time-to-first-token, session-close order."""
        return [s.ttft for s in self.sessions]

    def ttft_quantile(self, q: float) -> float:
        """Nearest-rank TTFT quantile (q in [0, 1]); 0.0 with no sessions."""
        xs = sorted(self.ttfts())
        if not xs:
            return 0.0
        i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[i]
