"""Virtual clock + deterministic event heap for the cluster runtime.

The heap orders events by ``(time, kind, seq)``:

  * ``time``  — virtual seconds;
  * ``kind``  — the EventKind value doubles as a same-instant priority:
    verifier completions land before deliveries (verdicts, then first
    tokens of completed prefills), deliveries before session/request
    arrivals, arrivals before device work, and dispatch epochs last — so
    an epoch firing at time t sees *every* request that arrived at t
    (continuous batching, no same-instant races);
  * ``seq``   — a monotone counter breaking remaining ties in push order,
    which is itself deterministic given a fixed seed.

Determinism is load-bearing: two runs with the same seed must pop the
identical event sequence (tested by ``tests/test_cluster.py``), because the
measured WDT/goodput numbers are only comparable across schedulers if the
workload unfolds identically.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq


class EventKind(enum.IntEnum):
    """Event types; the value is the same-timestamp priority (lower first)."""

    GPU_DONE = 0        # verifier busy period ends
    VERDICT = 1         # a verdict reaches its edge device
    FIRST_TOKEN = 2     # a completed prompt prefill's first token arrives
    SESSION_OPEN = 3    # a device asks to open a new session
    REQUEST = 4         # a drafted block arrives at the server (post-uplink)
    DEV_STEP = 5        # one draft-model token completes on a device
    DISPATCH = 6        # server dispatch epoch (its own timer)
    # Values 0-6 double as golden same-instant priorities — never renumber
    # them.  New kinds take values 7+ and route through the runtime's
    # ``_handle_event`` fallback.
    HEARTBEAT = 7       # fleet: one verifier's liveness beat + failover sweep
    RETRY_TIMER = 8     # chaos: a device's per-round re-submission timeout


@dataclasses.dataclass
class Event:
    time: float
    kind: EventKind
    payload: object = None


class EventQueue:
    """Min-heap of events with the deterministic total order above."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def push(self, time: float, kind: EventKind, payload=None):
        self._seq += 1
        heapq.heappush(self._heap, (time, int(kind), self._seq, payload))

    def pop(self) -> Event:
        time, kind, _, payload = heapq.heappop(self._heap)
        return Event(time=time, kind=EventKind(kind), payload=payload)

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)
