"""Event-driven cluster runtime: real models under per-device virtual clocks.

This is the piece that makes the paper's two bottlenecks *happen* instead of
being accounted analytically: edge drafting and server verification epochs
overlap in (virtual) time, so Wasted Drafting Time, queueing and
interference are **measured** from the actual token streams the real draft
and target models produce — `repro.sim` replays the same control logic
against an analytic acceptance model instead.

The machinery (docs/ARCHITECTURE.md has the timeline):

  * every device process steps its draft model one token per
    ``1/draft_speed`` virtual seconds (``DEV_STEP`` events);
  * a completed block travels ``uplink_time`` and lands in the server's
    pending pool (``REQUEST``); the server fires dispatch epochs on its own
    timer (``DISPATCH``), runs Algorithm 1 + real verification, and holds
    the verifier busy for the estimator-predicted epoch time (optionally
    noise-scaled), ``GPU_DONE`` releasing it;
  * verdicts ride the downlink back (``VERDICT``);
  * while a block is in flight the device *keeps drafting*: it samples a
    guess for the server's bonus token and speculatively drafts the next
    block after it (`EdgeDevice.begin_speculation`).  The verdict either
    commits the speculation — the overlap-drafted tokens become the head of
    the next block, and the round's effective draft latency shrinks to the
    post-verdict remainder — or rolls it back by the cache position pointer,
    the overlapped tokens becoming measured waste.

Prompt prefill is charged on the virtual clock per ``cfg.prefill_mode``
(DESIGN.md §8): ``"zero"`` keeps the legacy free-and-instant open,
``"monolithic"`` seizes the verifier for one estimator-priced blocking
span per prompt (head-of-line interference — verification queues behind
every cold prompt), and ``"chunked"`` admits the session immediately and
lets the server's SLO scheduler interleave fixed-budget prefill chunks
with verification; the first token rides a ``FIRST_TOKEN`` event back to
the device when the final chunk's epoch completes.  TTFT is measured
per session either way.

Server outcomes reach the runtime through the server's **typed event
stream** (`repro.serving.events`, docs/API.md): after every server call
the runtime drains ``pop_events()`` and routes ``FIRST_TOKEN`` /
``VERDICT`` events onto its own virtual-clock event heap (delivered
after the verify span + downlink), so first-token and verdict plumbing
share one channel for every prefill mode and scheduling policy.

Determinism: drafting keys are position-folded (`core/controller.py`),
verification draws are (session, committed_len)-keyed
(`core/speculative.py`), events are totally ordered (`cluster/events.py`)
and all workload randomness comes from seeded generators — so a run is a
pure function of its config, and the committed streams are byte-identical
to the lock-step driver's (`tests/test_cluster.py`) **and invariant to
the prefill mode and scheduling policy** (timing never reaches a
sampling key).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.chaos import FaultyTransport, resolve_fault_schedule
from repro.cluster.events import EventKind, EventQueue
from repro.cluster.metrics import ClusterMetrics, SessionRecord
from repro.cluster.workload import ClusterConfig, DeviceSpec, DeviceWorkload
from repro.core.estimator import BatchShape
from repro.core.wdt import IterationLog
from repro.serving.events import LinkDown, LinkUp, RetryEvent


@dataclasses.dataclass
class _DeviceProc:
    """Per-device process state the event loop threads through."""

    idx: int
    device: object                    # EdgeDevice
    profile: DeviceSpec
    workload: DeviceWorkload
    tau: float                        # seconds per drafted token
    #: this device's edge<->server link (heterogeneous-link fleets price
    #: each device's uplink/downlink on its own NetworkModel)
    net: object = None
    #: `FaultyTransport` over ``net`` when the fault schedule has link
    #: faults; None = perfectly reliable link (legacy fast path)
    chaos: object = None
    #: uplink attempts for the in-flight round (0 = first send; part of
    #: the fate/jitter key so every retry draws fresh network luck)
    attempt: int = 0
    #: verdict sends for the in-flight round (replays redraw fates too)
    down_attempts: int = 0
    #: consecutive round timeouts — reaches cfg.link_down_after => DOWN
    timeouts_in_row: int = 0
    link_down: bool = False           # runtime's mirror of the spec latch
    down_since: float = 0.0
    state: str = "idle"               # idle|admission|prefill|draft|wait|think|done
    gen: int = 0                      # event generation; stale steps dropped
    drafter: object = None            # live BlockDrafter while drafting
    inflight: object = None           # DraftResult awaiting its verdict
    #: the in-flight block's REQUEST event already landed on the server —
    #: i.e. the (possibly dead) verifier holds the round, so a fleet
    #: migration must re-dispatch it; False means the REQUEST is still on
    #: the uplink and will reach the *new* owner by itself
    request_arrived: bool = False
    round_start: float = 0.0          # when the stream head last advanced
    next_step_at: float = 0.0         # completion time of the in-flight token
    last_t_draft: float = 0.0         # effective draft latency submitted
    last_t_net: float = 0.0
    # speculation (while state == "wait")
    spec_active: bool = False
    spec_guess: int | None = None
    spec_drafter: object = None
    spec_cost: int = 0                # guess decode steps the guess needs
    guess_steps_done: int = 0         # ...virtually completed so far
    spec_tokens_done: int = 0         # spec-block tokens virtually completed
    # session bookkeeping
    session_id: int = -1
    rounds_done: int = 0
    response_target: int | None = None
    t_open: float = 0.0
    t_request: float = 0.0            # when SESSION_OPEN fired (TTFT clock)
    ttft: float = 0.0                 # first token arrival - t_request
    sessions_done: int = 0

    def clear_spec(self):
        self.spec_active = False
        self.spec_guess = None
        self.spec_drafter = None
        self.spec_cost = 0
        self.guess_steps_done = 0
        self.spec_tokens_done = 0


@dataclasses.dataclass
class ClusterResult:
    cfg: ClusterConfig
    metrics: ClusterMetrics
    horizon: float                    # virtual seconds the run covered
    server: object
    devices: list                     # EdgeDevice, fleet order
    fleet: list                       # DeviceSpec


class ClusterRuntime:
    """Drives EdgeDevices + WISPServer + NetworkModel on a virtual clock."""

    def __init__(self, server, edge_devices, fleet, cfg: ClusterConfig, *,
                 vocab: int):
        self.server = server
        self.cfg = cfg
        self.net = server.network
        if cfg.jitter_sigma:
            # jittered copy, not a mutation: the server's own NetworkModel
            # (used by legacy call sites without message keys) stays nominal
            self.net = dataclasses.replace(
                self.net, jitter_sigma=float(cfg.jitter_sigma),
                jitter_seed=int(cfg.seed),
            )
        #: resolved fault plan (always a FaultSchedule; empty = reliable)
        self.fault_schedule = resolve_fault_schedule(cfg)
        #: runtime-emitted chaos events (RETRY / LINK_DOWN / LINK_UP), in
        #: virtual-clock order — the fleet_log of the edge-link domain
        self.chaos_log: list = []
        self.events = EventQueue()
        self.metrics = ClusterMetrics(server.slo_classes)
        self.fleet = fleet
        self.devs = [
            _DeviceProc(
                idx=i, device=ed, profile=sp,
                workload=DeviceWorkload(cfg, vocab, i, spec=sp),
                tau=1.0 / sp.draft_speed,
                net=self._device_net(i),
            )
            for i, (ed, sp) in enumerate(zip(edge_devices, fleet))
        ]
        if self.fault_schedule.has_link_faults():
            for d in self.devs:
                d.chaos = FaultyTransport(d.net, self.fault_schedule)
        self.verifier_busy = False
        self.now = 0.0
        self._disp_t: float | None = None
        self._next_sid = 0
        self._by_session: dict[int, _DeviceProc] = {}
        self._pending_open: dict[int, list] = {}    # sid -> prompt (queued)
        #: monolithic prefill spans waiting for the verifier, FIFO:
        #: (sid, first_token, prompt_len) — models `new_session` as the
        #: blocking engine call it is in that mode
        self._prefill_fifo: list[tuple] = []
        self._noise_rng = np.random.default_rng(cfg.seed + 90_001)
        self._done_devices = 0

    def _device_net(self, idx: int):
        """Device ``idx``'s link model: the shared server NetworkModel, or
        — under ``cfg.link_rtts`` — a per-device variant with its cycled
        base RTT (mixed link heterogeneity, like draft_speeds)."""
        rtts = self.cfg.link_rtts
        if not rtts:
            return self.net
        return dataclasses.replace(
            self.net, base_rtt=float(rtts[idx % len(rtts)])
        )

    # -- edge-link fault domain (DESIGN.md §14) ------------------------------
    def _net_key(self, dircode: int, sid: int, rnd: int, n: int):
        """Per-message jitter key (None when jitter is off, so the
        NetworkModel's zero-jitter fast path stays byte-identical)."""
        if not self.cfg.jitter_sigma:
            return None
        return (dircode, sid, rnd + 1, n)

    def _retry_timeout(self, sid: int, rnd: int, att: int) -> float:
        """Timeout armed for attempt ``att`` of one round: exponential
        backoff plus a seeded uniform jitter fraction (decorrelates retry
        storms across devices; keyed by message identity like fates)."""
        cfg = self.cfg
        u = np.random.default_rng(
            (int(cfg.seed), 77, int(sid), int(rnd) + 1, int(att))
        ).random()
        return float(cfg.link_timeout) * (cfg.link_backoff ** att) \
            * (1.0 + cfg.link_retry_jitter * u)

    def _emit_chaos(self, ev) -> None:
        self.chaos_log.append(ev)

    def _send_request(self, dev: _DeviceProc, t: float) -> float:
        """Put the in-flight block on the uplink (first send and every
        retry): price the uplink, sample fates when the link is faulty,
        and arm the per-round retry timer.  Returns the priced uplink
        time (the nominal transit the metrics charge)."""
        res = dev.inflight
        sid, rnd, att = dev.session_id, dev.rounds_done, dev.attempt
        t_up = dev.net.uplink_time(
            res.n_sent, res.q_payload(),
            key=self._net_key(0, sid, rnd, att),
        )
        payload = (dev.idx, sid, rnd)
        if dev.chaos is not None:
            times = dev.chaos.deliveries("up", (sid, rnd, att), t, t_up)
            ch = self.metrics.chaos
            if not times:
                ch.uplink_drops += 1
            elif len(times) > 1:
                ch.uplink_dups += len(times) - 1
            for ts in times:
                self.events.push(ts, EventKind.REQUEST, payload)
        else:
            self.events.push(t + t_up, EventKind.REQUEST, payload)
        if self.cfg.link_timeout is not None:
            self.events.push(t + self._retry_timeout(sid, rnd, att),
                             EventKind.RETRY_TIMER, (dev.idx, sid, rnd, att))
        return t_up

    def _on_retry_timer(self, payload, t: float) -> None:
        """A per-round timeout fired.  Stale timers (the round resolved,
        the session moved on, or a later attempt superseded this one) are
        dropped; a live timer means the request or its verdict is lost —
        re-submit idempotently under the same (session, round) key with a
        fresh attempt index (fresh fate draws) and a longer next timeout."""
        idx, sid, rnd, att = payload
        dev = self.devs[idx]
        if (dev.session_id != sid or dev.inflight is None
                or dev.rounds_done != rnd or dev.attempt != att):
            return
        ch = self.metrics.chaos
        ch.timeouts += 1
        dev.timeouts_in_row += 1
        down = dev.timeouts_in_row >= self.cfg.link_down_after
        dev.device.observe_link(False, down=down)
        if down and not dev.link_down:
            dev.link_down = True
            dev.down_since = t
            ch.link_down_events += 1
            self._emit_chaos(LinkDown(sid, t, device=dev.idx))
        ch.retries += 1
        dev.attempt += 1
        self._emit_chaos(RetryEvent(
            sid, t, round_index=rnd, attempt=dev.attempt,
            backoff=self._retry_timeout(sid, rnd, dev.attempt),
        ))
        self._send_request(dev, t)

    def _note_link_ok(self, dev: _DeviceProc, t: float) -> None:
        """A verdict applied: feed the health EWMA one success and clear
        the DOWN latch once the controller's hysteresis lets go."""
        dev.timeouts_in_row = 0
        dev.device.observe_link(True)
        if dev.link_down and not dev.device.spec.link_down:
            dev.link_down = False
            self.metrics.chaos.link_up_events += 1
            self._emit_chaos(LinkUp(dev.session_id, t, device=dev.idx,
                                    outage=t - dev.down_since))

    def _serving_nodes(self) -> list:
        """Server objects whose chaos_stats fold into the run's metrics
        (the fleet runtime returns every verifier replica)."""
        return [self.server]

    # -- server timing ------------------------------------------------------
    def _verify_time(self, served) -> float:
        """Virtual verification duration of an epoch: the estimator's batch
        time, optionally jittered (profiling error / contention)."""
        dt = self.server.scheduler.batch_time(served)
        if self.cfg.latency_noise_sigma:
            dt *= float(np.exp(self._noise_rng.normal(
                0.0, self.cfg.latency_noise_sigma)))
        return dt

    def _schedule_dispatch(self, t: float):
        if self._disp_t is not None and self._disp_t <= t:
            return
        self._disp_t = t
        self.events.push(t, EventKind.DISPATCH)

    # -- monolithic prefill spans (prefill_mode="monolithic") ----------------
    def _prefill_span_time(self, prompt_len: int) -> float:
        """Virtual duration of one blocking whole-prompt prefill, priced by
        the same estimator that prices verification batches (a prompt is a
        cold request: all-new tokens, nothing cached), jittered like them."""
        dt = self.server.coeffs.predict(
            [BatchShape(new_tokens=prompt_len, cached_tokens=0)]
        )
        if self.cfg.latency_noise_sigma:
            dt *= float(np.exp(self._noise_rng.normal(
                0.0, self.cfg.latency_noise_sigma)))
        return dt

    def _queue_prefill_span(self, sid: int, first: int, prompt_len: int,
                            t: float):
        self._prefill_fifo.append((sid, first, prompt_len))
        self._maybe_start_prefill(t)

    def _maybe_start_prefill(self, t: float):
        """Start the next blocking prefill span if the verifier is idle.
        Monolithic `new_session` runs OUTSIDE the scheduler, so it takes
        the engine ahead of any pending verification — exactly the
        head-of-line interference chunked prefill removes."""
        if self.verifier_busy or not self._prefill_fifo:
            return
        sid, first, plen = self._prefill_fifo.pop(0)
        dt = self._prefill_span_time(plen)
        self.verifier_busy = True
        self.events.push(t + dt, EventKind.GPU_DONE)
        self.events.push(t + dt + self.net.downlink_time(),
                         EventKind.FIRST_TOKEN, (sid, first))

    # -- session lifecycle --------------------------------------------------
    def _open_session(self, dev: _DeviceProc, prompt: list, t: float):
        sid = self._next_sid
        self._next_sid += 1
        self._by_session[sid] = dev
        dev.session_id = sid
        dev.t_request = t
        # reset NOW, not at first token: a device truncated by the horizon
        # while still prefilling/queued must not satisfy the end-of-run
        # "rounds_done > 0" record guard with the PREVIOUS session's
        # counters (phantom SessionRecord with stale t_open/ttft/committed)
        dev.rounds_done = 0
        # until a FIRST_TOKEN event starts the session, the device idles:
        # admitted-and-prefilling (chunked), waiting on the blocking span
        # (monolithic), or capacity-queued (any mode)
        dev.state = "admission"
        self._pending_open[sid] = prompt
        self._admit_session(dev, sid, prompt, t)

    def _admit_session(self, dev: _DeviceProc, sid: int, prompt: list,
                       t: float):
        """Hand the new session to the serving tier (the fleet runtime
        overrides this with prefix-locality routing)."""
        self.server.open_session(
            sid, prompt, slo_class=dev.profile.slo_class,
            draft_speed=dev.profile.draft_speed, queue_on_full=True, now=t,
            tenant=dev.profile.tenant,
        )
        self._drain_server_events(t)
        if (self.cfg.prefill_mode == "chunked"
                and dev.state == "admission"
                and not self.verifier_busy
                and (self.server.queue_depth or self.server.throttle_backlog)):
            self._schedule_dispatch(t)

    def _start_session(self, dev: _DeviceProc, sid: int, prompt: list,
                       first: int, t: float):
        dev.device.start_session(sid, prompt, first)
        dev.t_open = t
        dev.ttft = t - dev.t_request
        dev.rounds_done = 0
        dev.response_target = (
            None if self.cfg.rounds is not None
            else dev.workload.response_target()
        )
        dev.clear_spec()
        dev.inflight = None
        self._begin_block(dev, t)

    def _begin_block(self, dev: _DeviceProc, t: float):
        dev.drafter = dev.device.begin_round()
        if getattr(dev.device.spec, "degraded_last", False):
            self.metrics.chaos.degraded_rounds += 1
        dev.state = "draft"
        dev.round_start = t
        dev.gen += 1
        dev.next_step_at = t + dev.tau
        self.events.push(dev.next_step_at, EventKind.DEV_STEP,
                         (dev.idx, dev.gen))

    def _close_session(self, dev: _DeviceProc, t: float):
        sid = dev.session_id
        rec = SessionRecord(
            session_id=sid,
            device=dev.idx,
            slo_class=dev.profile.slo_class,
            slo_speed=self.server.slo_classes[dev.profile.slo_class],
            t_open=dev.t_open,
            t_close=t,
            committed=len(dev.device.response_tokens),
            rounds=dev.rounds_done,
            ttft=dev.ttft,
            tenant=dev.profile.tenant,
        )
        self.metrics.close_session(rec)
        self._server_close(sid, t)
        self._by_session.pop(sid, None)
        dev.sessions_done += 1
        dev.clear_spec()
        if self.cfg.rounds is not None:          # fixed-work mode: retire
            dev.state = "done"
            self._done_devices += 1
        else:                                    # churn: think, then re-open
            dev.state = "think"
            self.events.push(t + dev.workload.think_time(),
                             EventKind.SESSION_OPEN, dev.idx)

    def _server_close(self, sid: int, t: float):
        """Tear the session down on the serving tier (the fleet runtime
        overrides this to route the close to the session's owner)."""
        self.server.close_session(sid, now=t)
        # the close may have admitted a capacity-queued session
        self._drain_server_events(t)
        # chunked mode: a capacity-queued session admitted by this close
        # just enqueued its first prefill chunk — make sure an epoch fires
        if ((self.server.queue_depth or self.server.throttle_backlog)
                and not self.verifier_busy):
            self._schedule_dispatch(t)

    def _drain_server_events(self, t: float, t_sent: float | None = None):
        """Route the server's typed event stream (docs/API.md) onto the
        cluster's virtual clock.  ``VERDICT`` events leave the server at
        ``t_sent`` (epoch end for dispatch epochs, now for replays) and
        ride the downlink through `_push_verdict` — which is where
        per-message jitter and chaos fates apply.  ``FIRST_TOKEN`` events
        depend on how the mode charges prefill:

          * ``zero``       — prefill is free and instant; the session
            starts right now;
          * ``monolithic`` — the token exists, but the blocking
            estimator-priced prefill span still has to run (FIFO on the
            verifier) before it rides the downlink;
          * ``chunked``    — the final chunk's epoch just completed; the
            token rides the downlink from ``t_sent`` (session control
            plane: framed/reliable, no chaos fates — DESIGN.md §14).

        ``REJECTED`` (tenant admission shed) aborts the open and puts the
        device into a retry backoff.  ``ADMITTED`` / ``THROTTLED`` /
        ``PREEMPTED`` / ``TTFT_RECORD`` / ``CLOSED`` need no runtime
        action (device timing is measured runtime-side)."""
        t_out = t if t_sent is None else t_sent
        for ev in self.server.pop_events():
            if ev.kind == "VERDICT":
                self._push_verdict(ev.verdict, t_out)
            elif ev.kind == "REJECTED":
                self._on_rejected(ev.session_id, t)
            elif ev.kind == "FIRST_TOKEN":
                sid = ev.session_id
                if self.cfg.prefill_mode == "monolithic":
                    dev = self._by_session.get(sid)
                    if dev is None:           # closed under us
                        self._pending_open.pop(sid, None)
                        continue
                    dev.state = "prefill"
                    self._queue_prefill_span(
                        sid, ev.token, len(self._pending_open[sid]), t
                    )
                elif self.cfg.prefill_mode == "chunked":
                    self.events.push(t_out + self.net.downlink_time(),
                                     EventKind.FIRST_TOKEN,
                                     (sid, ev.token))
                else:
                    self._on_first_token((sid, ev.token), t)

    def _push_verdict(self, v, t_sent: float) -> None:
        """One verdict leaves the server at ``t_sent`` and rides the
        downlink: per-message jitter prices its latency and — on a faulty
        link — the schedule decides whether this copy arrives at all,
        twice, or late.  The downlink send index ``n`` joins the fate key
        so replays of the same round draw fresh fates."""
        dev = self._by_session.get(v.session_id)
        rnd = int(getattr(v, "round_index", -1))
        n = 0
        if dev is not None:
            n = dev.down_attempts
            dev.down_attempts += 1
        lat = self.net.downlink_time(
            key=self._net_key(1, v.session_id, rnd, n))
        if dev is not None and dev.chaos is not None:
            times = dev.chaos.deliveries(
                "down", (v.session_id, rnd + 1, n), t_sent, lat)
            ch = self.metrics.chaos
            if not times:
                ch.downlink_drops += 1
            elif len(times) > 1:
                ch.downlink_dups += len(times) - 1
            for ts in times:
                self.events.push(ts, EventKind.VERDICT, v)
        else:
            self.events.push(t_sent + lat, EventKind.VERDICT, v)

    def _on_first_token(self, payload, t: float):
        """A completed prefill's first token reaches its device: the
        session leaves the prefill/admission limbo and starts drafting."""
        sid, first = payload
        dev = self._by_session.get(sid)
        if dev is None:
            self._pending_open.pop(sid, None)
            return                      # session closed under us
        prompt = self._pending_open.pop(sid)
        self._start_session(dev, sid, prompt, first, t)

    def _on_rejected(self, sid: int, t: float):
        """Tenant admission control shed this open (REJECTED event): the
        device backs off and retries — after its usual think pause in
        churn mode, after ``cfg.reject_retry`` in fixed-work mode (where
        every device must eventually complete its rounds)."""
        dev = self._by_session.pop(sid, None)
        self._pending_open.pop(sid, None)
        if dev is None:
            return                      # closed under us
        self.metrics.add_rejection(dev.profile.tenant)
        dev.session_id = -1
        dev.state = "think"
        backoff = (dev.workload.think_time() if self.cfg.rounds is None
                   else self.cfg.reject_retry)
        self.events.push(t + backoff, EventKind.SESSION_OPEN, dev.idx)

    # -- block submission + speculation -------------------------------------
    def _submit(self, dev: _DeviceProc, t: float):
        res = dev.device.finish_round(dev.drafter)
        dev.drafter = None
        dev.inflight = res
        dev.request_arrived = False
        dev.attempt = 0
        dev.down_attempts = 0
        dev.last_t_draft = t - dev.round_start
        # price the q representation that actually rides this request
        # (CompactQ table / modelled dense top-k / ids only, DESIGN.md §9)
        # on the DEVICE's link (heterogeneous links under cfg.link_rtts)
        t_up = self._send_request(dev, t)
        dev.last_t_net = t_up + dev.net.downlink_time()
        dev.state = "wait"
        dev.gen += 1
        # a device knows its own quota: never speculate past a known-final
        # round (fixed-work mode; churn responses end server-side, so the
        # device speculates and abandoned work is accounted as waste)
        final_round = (
            self.cfg.rounds is not None
            and dev.rounds_done + 1 >= self.cfg.rounds
        )
        if self.cfg.speculate and not final_round:
            guess, sdrafter, cost = dev.device.begin_speculation(res)
            dev.spec_active = True
            dev.spec_guess = guess
            dev.spec_drafter = sdrafter
            dev.spec_cost = cost
            dev.guess_steps_done = 0
            dev.spec_tokens_done = 0
            dev.next_step_at = t + dev.tau
            self.events.push(dev.next_step_at, EventKind.DEV_STEP,
                             (dev.idx, dev.gen))

    # -- event handlers ------------------------------------------------------
    def _on_dev_step(self, dev: _DeviceProc, gen: int, t: float):
        if gen != dev.gen:
            return                      # superseded by a verdict/submission
        if dev.state == "draft":
            more = dev.drafter.step()
            if more:
                dev.next_step_at = t + dev.tau
                self.events.push(dev.next_step_at, EventKind.DEV_STEP,
                                 (dev.idx, dev.gen))
            else:
                self._submit(dev, t)
        elif dev.state == "wait" and dev.spec_active:
            if dev.guess_steps_done < dev.spec_cost:
                # the guess decode (run eagerly at submit) completes now
                dev.guess_steps_done += 1
                more = True
            else:
                more = dev.spec_drafter.step()
                dev.spec_tokens_done += 1
            if more:
                dev.next_step_at = t + dev.tau
                self.events.push(dev.next_step_at, EventKind.DEV_STEP,
                                 (dev.idx, dev.gen))
            # else: speculative block complete; idle until the verdict

    def _on_request(self, dev: _DeviceProc, t: float, rnd: int | None = None):
        res = dev.inflight
        if res is None or (rnd is not None and dev.rounds_done != rnd):
            # a late duplicate of an already-resolved round (the verdict
            # raced a duplicated/retried request copy): nothing to verify
            self.metrics.chaos.stale_requests_dropped += 1
            return
        dev.request_arrived = True
        rid = self.server.submit(
            dev.session_id, res.tokens, res.q_logits,
            q_compact=res.q_compact,
            now=t, t_draft=dev.last_t_draft, t_network=dev.last_t_net,
            round_index=dev.rounds_done,
        )
        # a replayed verdict (the server already resolved this round; our
        # verdict copy died on the downlink) is emitted during submit —
        # put it back on the downlink right away
        self._drain_server_events(t, t_sent=t)
        if rid is not None and not self.verifier_busy:
            self._schedule_dispatch(t)

    def _on_dispatch(self, t: float, payload=None):
        self._disp_t = None
        if self.verifier_busy:
            return
        if not (self.server.queue_depth or self.server.throttle_backlog):
            return
        self.server.step(t, verify_time=self._verify_time)
        self.metrics.sample_queue(t, self.server.queue_depth)
        if self.server.last_served:
            # the epoch executed work (verify items and/or prefill chunks):
            # the verifier is busy for its estimator-priced duration, and
            # everything it produced (VERDICT events, chunked-prefill
            # FIRST_TOKEN events) is delivered after the downlink
            dt = self.server.last_verify_time
            self.verifier_busy = True
            self.events.push(t + dt, EventKind.GPU_DONE)
            self._drain_server_events(t, t_sent=t + dt)
        else:
            # the epoch may still have admitted capacity-queued sessions
            # (zero/monolithic: their FIRST_TOKEN fired) even though
            # nothing was schedulable
            self._drain_server_events(t)
            if self.server.queue_depth or self.server.throttle_backlog:
                # nothing schedulable yet (criticality windows still
                # closed, or work held by the tenant rate limiter): the
                # server's own timer retries next epoch
                self._schedule_dispatch(t + self.cfg.dispatch_interval)

    def _on_gpu_done(self, t: float, payload=None):
        self.verifier_busy = False
        # monolithic mode: a blocked open_session's prefill span takes the
        # engine before any dispatch epoch can (it is a blocking call)
        self._maybe_start_prefill(t)
        if self.verifier_busy:
            return
        if self.server.queue_depth or self.server.throttle_backlog:
            self._schedule_dispatch(t)

    def _on_verdict(self, v, t: float):
        dev = self._by_session.get(v.session_id)
        if dev is None:
            return                      # session closed under us
        rnd = int(getattr(v, "round_index", -1))
        if dev.inflight is None or (rnd >= 0 and rnd != dev.rounds_done):
            # duplicated / reordered / already-superseded verdict copy:
            # the (session, round) idempotency key says it must never
            # touch the stream twice (DESIGN.md §14)
            self.metrics.chaos.dup_verdicts_dropped += 1
            return
        self._note_link_ok(dev, t)
        res, dev.inflight = dev.inflight, None
        dev.request_arrived = False
        dev.gen += 1                    # halt speculation events
        overlap, guess_steps = dev.spec_tokens_done, dev.guess_steps_done
        committed = dev.device.resolve_verdict(
            v.accept_len, v.token, res,
            guess=dev.spec_guess, speculated=dev.spec_active,
            round_index=dev.rounds_done,
        )
        # close the adaptive-speculation loop (DESIGN.md §11): measured
        # acceptance + this round's RTT + the verifier queue depth the
        # verdict piggybacked feed the device's next-K choice
        dev.device.observe_verdict(
            v.accept_len, res.k_used, rtt=dev.last_t_net,
            queue_depth=getattr(v, "queue_depth", None),
            features=res.features,
        )
        done = (
            dev.rounds_done + 1 >= self.cfg.rounds
            if self.cfg.rounds is not None
            else len(dev.device.response_tokens) >= dev.response_target
        )
        if dev.spec_active:
            if done:
                self.metrics.add_spec_abandoned(
                    overlap_tokens=overlap, guess_tokens=guess_steps,
                    tau_d=dev.tau,
                )
            else:
                self.metrics.add_spec_outcome(
                    committed=committed, overlap_tokens=overlap,
                    guess_tokens=guess_steps, tau_d=dev.tau,
                )
        self.metrics.add_iteration(
            IterationLog(
                session_id=v.session_id,
                round_index=dev.rounds_done,
                n_drafted=res.n_drafted,
                n_sent=res.n_sent,
                n_accepted=v.accept_len,
                n_committed=v.emitted,
                t_draft=dev.last_t_draft,
                t_network=dev.last_t_net,
                t_queue=v.t_queue,
                t_verify=v.t_verify,
                deadline=v.deadline,
                slo_class=dev.profile.slo_class,
                violated=v.violated,
                k_used=res.k_used,
            ),
            tau_d=dev.tau,
        )
        dev.rounds_done += 1

        if done:
            dev.clear_spec()
            self._close_session(dev, t)
            return
        if committed:
            # speculation committed: the overlap-drafted tokens head the
            # next block; only the remainder costs post-verdict time
            dev.drafter = dev.spec_drafter
            next_at = dev.next_step_at
            dev.clear_spec()
            dev.state = "draft"
            dev.round_start = t
            if dev.drafter.done:
                self._submit(dev, t)
            else:
                dev.next_step_at = max(next_at, t)
                self.events.push(dev.next_step_at, EventKind.DEV_STEP,
                                 (dev.idx, dev.gen))
        else:
            # rollback: cache pointer snapped back; draft afresh
            dev.clear_spec()
            self._begin_block(dev, t)

    # -- subclass hooks -------------------------------------------------------
    def _before_run(self) -> None:
        """Called once before the first event fires (the fleet runtime
        seeds its recurring per-verifier heartbeat events here)."""

    def _handle_event(self, ev) -> None:
        """Fallback for event kinds the base loop does not know (values
        ≥ 7, e.g. HEARTBEAT — the 0–6 kinds double as same-instant
        priorities and are handled inline)."""
        if ev.kind == EventKind.RETRY_TIMER:
            self._on_retry_timer(ev.payload, ev.time)
            return
        raise RuntimeError(f"unhandled event kind {ev.kind!r}")

    # -- main loop -----------------------------------------------------------
    def run(self) -> ClusterResult:
        cfg = self.cfg
        if cfg.rounds is None and cfg.horizon is None:
            raise ValueError("churn mode needs cfg.horizon")
        self._before_run()
        for dev in self.devs:
            self.events.push(0.0, EventKind.SESSION_OPEN, dev.idx)
        end = 0.0
        while self.events:
            ev = self.events.pop()
            if cfg.horizon is not None and ev.time > cfg.horizon:
                end = cfg.horizon
                break
            self.now = end = ev.time
            k = ev.kind
            if k == EventKind.SESSION_OPEN:
                dev = self.devs[ev.payload]
                prompt = (
                    dev.profile.prompt if dev.sessions_done == 0
                    else dev.workload.next_prompt()
                )
                self._open_session(dev, prompt, ev.time)
            elif k == EventKind.DEV_STEP:
                idx, gen = ev.payload
                self._on_dev_step(self.devs[idx], gen, ev.time)
            elif k == EventKind.REQUEST:
                idx, sid, rnd = ev.payload
                dev = self.devs[idx]
                if dev.session_id == sid:
                    self._on_request(dev, ev.time, rnd)
                else:                   # the session ended while in flight
                    self.metrics.chaos.stale_requests_dropped += 1
            elif k == EventKind.DISPATCH:
                self._on_dispatch(ev.time, ev.payload)
            elif k == EventKind.GPU_DONE:
                self._on_gpu_done(ev.time, ev.payload)
            elif k == EventKind.VERDICT:
                self._on_verdict(ev.payload, ev.time)
            elif k == EventKind.FIRST_TOKEN:
                self._on_first_token(ev.payload, ev.time)
            else:
                self._handle_event(ev)
            if cfg.rounds is not None and self._done_devices == len(self.devs):
                break
        if any(d.state in ("admission", "prefill") for d in self.devs) \
                and not self.events:
            raise RuntimeError(
                "deadlock: sessions queued for admission/prefill but no "
                "event can free capacity (engine smaller than one session?)"
            )
        # Horizon-truncated sessions (churn mode): sessions still open at
        # the break must be recorded, or violation stats inherit a
        # survivorship bias — the slow (violating) sessions are exactly the
        # ones most likely to still be in flight at the horizon.
        for dev in self.devs:
            if dev.session_id in self._by_session and dev.rounds_done > 0:
                self.metrics.close_session(SessionRecord(
                    session_id=dev.session_id,
                    device=dev.idx,
                    slo_class=dev.profile.slo_class,
                    slo_speed=self.server.slo_classes[dev.profile.slo_class],
                    t_open=dev.t_open,
                    t_close=end,
                    committed=len(dev.device.response_tokens),
                    rounds=dev.rounds_done,
                    ttft=dev.ttft,
                    tenant=dev.profile.tenant,
                ))
        # fold server-side idempotency counters into the run's chaos stats
        for node in self._serving_nodes():
            st = getattr(node, "chaos_stats", None)
            if st:
                self.metrics.chaos.dup_submits_dropped += st["dup_submits"]
                self.metrics.chaos.verdicts_replayed += st["verdict_replays"]
        return ClusterResult(
            cfg=cfg,
            metrics=self.metrics,
            horizon=end,
            server=self.server,
            devices=[d.device for d in self.devs],
            fleet=self.fleet,
        )
