"""Edge<->server transport with a simulated network (gRPC stand-in).

The real deployment uses gRPC (paper §4); in this container both ends run
in-process and the transport contributes *modelled* latency:

    t = base_rtt/2 + payload_bytes / bandwidth

Payload accounting matches the wire protocol: uplink carries draft token
ids plus the q-statistics the acceptance rule needs, downlink carries
(accept_len, token).  The q payload depends on the representation the
draft side chose (DESIGN.md §9):

  * ``CompactQ``     — the actual compact table: per drafted token a
    float32 token log-prob, C × (id: 4B + logit: 2B) top entries and a
    float16 tail mass (O(K·C); exact accept test, bounded-error residual);
  * dense q-logits / unspecified — the legacy modelled top-k
    sparsification at ``q_topk`` entries (the residual-distribution tail
    mass is renormalized, the lossless-in-practice compression the
    paper's SLED baseline also uses);
  * ``None``         — token ids only (a greedy verifier reads no q).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class NetworkModel:
    base_rtt: float = 0.010        # 10 ms edge<->cloud
    uplink_bw: float = 12.5e6      # 100 Mbit/s in bytes/s
    downlink_bw: float = 25e6      # 200 Mbit/s
    q_topk: int = 32               # modelled sparsification of dense q
    #: per-message RTT variance: the propagation term is scaled by a
    #: seeded LogNormal(0, jitter_sigma) factor drawn from the message
    #: key a caller passes to ``uplink_time``/``downlink_time`` — fates
    #: are a function of message identity, not call order, so runs stay
    #: deterministic.  0 (the default) is byte-identical to the fixed-RTT
    #: model: no rng is ever constructed and no float op changes.
    jitter_sigma: float = 0.0
    jitter_seed: int = 0

    def _jitter(self, key) -> float:
        """LogNormal latency factor for one message (1.0 when jitter is
        off or the caller passed no key — legacy call sites price the
        nominal link)."""
        if not self.jitter_sigma or key is None:
            return 1.0
        g = np.random.default_rng(
            (int(self.jitter_seed), *(int(k) % (2 ** 31) for k in key))
        )
        return float(np.exp(g.normal(0.0, self.jitter_sigma)))

    def uplink_bytes(self, n_draft_tokens: int, q="modelled") -> int:
        """Uplink payload for one drafted block.  ``q`` selects the
        q-statistics representation: a `CompactQ` (anything exposing
        ``wire_bytes()``) is priced at its actual table size, ``None``
        means ids-only (greedy), and the default prices the legacy
        modelled top-k sparsification of dense logits."""
        ids = n_draft_tokens * 4                     # token ids
        if q is None:
            q_bytes = 0
        elif hasattr(q, "wire_bytes"):
            q_bytes = q.wire_bytes()
        else:
            q_bytes = n_draft_tokens * self.q_topk * 6
        return 64 + ids + q_bytes

    def downlink_bytes(self) -> int:
        return 64 + 8

    def uplink_time(self, n_draft_tokens: int, q="modelled", *,
                    key=None) -> float:
        return self.base_rtt / 2 * self._jitter(key) + \
            self.uplink_bytes(n_draft_tokens, q) / self.uplink_bw

    def downlink_time(self, *, key=None) -> float:
        return self.base_rtt / 2 * self._jitter(key) + \
            self.downlink_bytes() / self.downlink_bw

    def round_trip(self, n_draft_tokens: int, q="modelled") -> float:
        return self.uplink_time(n_draft_tokens, q) + self.downlink_time()
