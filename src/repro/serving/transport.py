"""Edge<->server transport with a simulated network (gRPC stand-in).

The real deployment uses gRPC (paper §4); in this container both ends run
in-process and the transport contributes *modelled* latency:

    t = base_rtt/2 + payload_bytes / bandwidth

Payload accounting matches the wire protocol: uplink carries draft token ids
plus the q-statistics needed by the acceptance rule (top-k sparsified logits,
k=32 by default — the residual-distribution tail mass is renormalized, a
standard lossless-in-practice compression the paper's SLED baseline also
uses); downlink carries (accept_len, token).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetworkModel:
    base_rtt: float = 0.010        # 10 ms edge<->cloud
    uplink_bw: float = 12.5e6      # 100 Mbit/s in bytes/s
    downlink_bw: float = 25e6      # 200 Mbit/s
    q_topk: int = 32               # sparsified draft distribution entries

    def uplink_bytes(self, n_draft_tokens: int) -> int:
        # token ids (4B) + topk (id 4B + logit 2B) per drafted token + header
        return 64 + n_draft_tokens * (4 + self.q_topk * 6)

    def downlink_bytes(self) -> int:
        return 64 + 8

    def uplink_time(self, n_draft_tokens: int) -> float:
        return self.base_rtt / 2 + self.uplink_bytes(n_draft_tokens) / self.uplink_bw

    def downlink_time(self) -> float:
        return self.base_rtt / 2 + self.downlink_bytes() / self.downlink_bw

    def round_trip(self, n_draft_tokens: int) -> float:
        return self.uplink_time(n_draft_tokens) + self.downlink_time()
