"""Typed server events + session handles (docs/API.md).

Every outcome a `WISPServer` produces — admission, first tokens, verify
verdicts, preemptions, TTFT records, closes — flows through ONE ordered,
drainable channel, ``server.pop_events()``, as a typed `ServerEvent`.
This replaces the three legacy ad-hoc channels (``pop_admissions()``
polling, the ``step()`` verdict return list, the ``prefill_log``
side-car), which remain as thin deprecation shims for one release.

Ordering guarantees, per session (tests/test_policies.py):

  * ``ADMITTED`` precedes every other event of the session;
  * exactly one ``FIRST_TOKEN`` is emitted, before any ``VERDICT``;
  * ``CLOSED`` is final (nothing follows it);
  * a ``PREEMPTED`` session re-enters the admission queue and emits a
    fresh ``ADMITTED`` when capacity frees (still before its single
    ``FIRST_TOKEN`` — preemption only happens mid-prefill).

`SessionHandle` is the client-facing half: ``open_session`` returns one,
and its ``state`` property walks the lifecycle state machine

    queued -> prefilling -> active -> closed
      ^            |  (chunked mode; monolithic skips to active)
      └─ PREEMPTED ┘
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServerEvent:
    """Base event: ``session_id`` + the server-clock ``time`` it fired."""

    session_id: int
    time: float

    kind = "EVENT"               # class tag, overridden per event type


@dataclasses.dataclass(frozen=True)
class Admitted(ServerEvent):
    """The session holds an engine slot (and, paged, its block table):
    monolithic mode right at ``open_session``/queue retry; chunked mode
    when prefill *begins* (the first token comes later)."""

    kind = "ADMITTED"


@dataclasses.dataclass(frozen=True)
class FirstToken(ServerEvent):
    """The session's first committed response token exists.  Emitted
    exactly once per session: at admission for monolithic prefill, when
    the final chunk's epoch lands for chunked prefill."""

    token: int

    kind = "FIRST_TOKEN"


@dataclasses.dataclass(frozen=True)
class VerdictEvent(ServerEvent):
    """One verification verdict (``verdict`` is the `Verdict` dataclass:
    accept_len, correction/bonus token, deadline accounting)."""

    verdict: object

    kind = "VERDICT"


@dataclasses.dataclass(frozen=True)
class Preempted(ServerEvent):
    """A mutually-blocked prefilling session was evicted back to the
    admission queue (liveness preemption; its pages were released and it
    retries FIFO with its original TTFT clock)."""

    kind = "PREEMPTED"


@dataclasses.dataclass(frozen=True)
class TTFTRecord(ServerEvent):
    """A chunked prefill completed; ``record`` is the `PrefillRecord`
    (prompt length, chunk count, TTFT vs deadline)."""

    record: object

    kind = "TTFT_RECORD"


@dataclasses.dataclass(frozen=True)
class Closed(ServerEvent):
    """The session is gone: slot/pages released, pending work purged,
    or a queued/prefilling session cancelled."""

    kind = "CLOSED"


@dataclasses.dataclass(frozen=True)
class Migrated(ServerEvent):
    """Fleet tier (repro.fleet): the session moved verifiers — its
    committed prefix was replayed as a chunked prefill on ``dst`` after
    ``src`` died (heartbeat sweep) or straggled past the hedge guard.
    ``replayed_tokens`` is the prompt work actually recomputed (prefix-
    cache hits on the destination make a warm migration nearly free)."""

    src: str
    dst: str
    replayed_tokens: int = 0

    kind = "MIGRATED"


@dataclasses.dataclass(frozen=True)
class VerifierDown(ServerEvent):
    """Fleet tier: a verifier replica was declared dead by the heartbeat
    sweep.  Fleet-scoped, not session-scoped: ``session_id`` is -1."""

    verifier: str = ""

    kind = "VERIFIER_DOWN"


@dataclasses.dataclass(frozen=True)
class Throttled(ServerEvent):
    """Tenancy tier (DESIGN.md §13): the tenant's rate limiter held this
    work.  ``stage`` names the throttle rung (``"deprioritize"`` — it ran
    at reduced WFQ weight; ``"queue"`` — held in the tenant's throttle
    buffer until the bucket recovers) and ``scope`` what was priced
    (``"open"`` | ``"submit"``).  May precede a session's ``ADMITTED``
    (a held open throttles before it admits)."""

    tenant: str = "default"
    stage: str = "queue"
    scope: str = "open"

    kind = "THROTTLED"


@dataclasses.dataclass(frozen=True)
class Rejected(ServerEvent):
    """Tenancy tier: an ``open_session`` was shed outright — the tenant's
    throttle backlog already exceeded its ``max_queued`` budget.  Final
    for the session (no ``ADMITTED``/``CLOSED`` follows); applies only to
    opens, never to a streaming session's submitted block."""

    tenant: str = "default"

    kind = "REJECTED"


@dataclasses.dataclass(frozen=True)
class RetryEvent(ServerEvent):
    """Edge-link fault domain (DESIGN.md §14): the device's per-round
    timeout expired and it re-submitted the round (idempotent under the
    ``(session_id, round_index)`` key).  ``attempt`` is the re-send's
    attempt index (1 = first retry); ``backoff`` the exponential+jitter
    delay armed for the NEXT timeout."""

    round_index: int = -1
    attempt: int = 0
    backoff: float = 0.0

    kind = "RETRY"


@dataclasses.dataclass(frozen=True)
class LinkDown(ServerEvent):
    """Edge-link fault domain: ``link_down_after`` consecutive round
    timeouts on ``device``'s link — the device enters degraded mode
    (K=1 server-side decode when ``link_degrade`` is on) until the
    health EWMA recovers with hysteresis."""

    device: int = -1

    kind = "LINK_DOWN"


@dataclasses.dataclass(frozen=True)
class LinkUp(ServerEvent):
    """Edge-link fault domain: ``device``'s link recovered (health EWMA
    back above the hysteresis threshold after an ok-streak).  ``outage``
    is the LINK_DOWN -> LINK_UP span in virtual seconds."""

    device: int = -1
    outage: float = 0.0

    kind = "LINK_UP"


#: event-kind tags in lifecycle order (documentation + test helper);
#: MIGRATED / VERIFIER_DOWN are fleet-tier events and can interleave
#: anywhere between a session's FIRST_TOKEN and CLOSED, as can the
#: edge-link chaos events RETRY / LINK_DOWN / LINK_UP (runtime-emitted,
#: collected in ``ClusterRuntime.chaos_log``); THROTTLED may precede
#: ADMITTED (a throttle-held open) and REJECTED replaces the whole
#: lifecycle for a shed open
EVENT_KINDS = ("THROTTLED", "REJECTED", "ADMITTED", "FIRST_TOKEN",
               "VERDICT", "PREEMPTED", "TTFT_RECORD", "MIGRATED",
               "VERIFIER_DOWN", "RETRY", "LINK_DOWN", "LINK_UP",
               "CLOSED")


class SessionHandle:
    """Client-facing handle for one server session.

    Returned by ``WISPServer.open_session``; all *outcomes* flow through
    the server's event stream (``pop_events()``) — the handle is the
    cheap synchronous view: lifecycle ``state``, the ``first_token``
    once known, and ``close()``."""

    __slots__ = ("session_id", "_server")

    def __init__(self, session_id: int, server):
        self.session_id = session_id
        self._server = server

    @property
    def state(self) -> str:
        """``"queued"`` (admission or throttle queue) | ``"prefilling"``
        (chunked prefill in flight) | ``"active"`` (streaming) |
        ``"rejected"`` (shed by the tenant rate limiter, terminal) |
        ``"closed"``."""
        return self._server.session_state(self.session_id)

    @property
    def first_token(self) -> int | None:
        """The session's first committed token, or ``None`` until it is
        admitted (queued) / finishes prefilling (chunked)."""
        return self._server.first_tokens.get(self.session_id)

    @property
    def active(self) -> bool:
        return self.state == "active"

    def close(self) -> None:
        self._server.close_session(self.session_id)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"SessionHandle(session_id={self.session_id}, "
                f"state={self.state!r}, first_token={self.first_token!r})")
