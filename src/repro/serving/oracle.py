"""Committed-prefix oracle: solo lock-step replay of a per-round K schedule.

Under adaptive speculation (DESIGN.md §11) a session's block boundaries
depend on *timing signals* (RTT, verifier load), and block boundaries
feed the verification rng keys ``(session_id, committed_len)`` — so an
adaptive run's streams lawfully differ from a static-K run's and are not
invariant to fleet composition.  What the determinism model DOES
guarantee, and what this module checks, is sharper:

    Given the per-round draft-length schedule a session actually ran
    (``IterationLog.k_used`` — equal to ``n_drafted`` when no predictor
    rides), replaying that session ALONE, lock-step, against a
    fresh same-seed engine commits the byte-identical token stream.

i.e. the committed stream is a pure function of (engine seed, device
seed, params, prompt, K schedule) — batching, queueing, speculation
overlap, scheduling policy and fleet interference contribute exactly
nothing.  `benchmarks/adaptive_k.py` gates the adaptive controller on
this oracle: goodput may move, bytes may not.
"""
from __future__ import annotations

from repro.core.estimator import EstimatorCoeffs
from repro.serving.client import EdgeDevice
from repro.serving.engine import VerificationEngine
from repro.serving.server import WISPServer
from repro.serving.transport import NetworkModel


def replay_session(
    target_cfg,
    target_params,
    draft_cfg,
    draft_params,
    *,
    prompt,
    k_schedule,
    session_id: int = 0,
    device_seed: int = 0,
    engine_seed: int = 0,
    draft_speed: float = 50.0,
    slo_class: int = 3,
    k_max: int | None = None,
    greedy: bool = False,
    q_mode: str = "dense",
    q_top_c: int = 64,
    method: str = "residual",
    max_len: int = 512,
    coeffs: EstimatorCoeffs | None = None,
    predictor=None,
) -> list[int]:
    """Replay ONE session solo under a scripted per-block K schedule;
    returns its committed response tokens.

    ``session_id`` and the seeds must match the original run: draft
    sampling keys are position-folded off ``PRNGKey(device_seed)`` and
    verification draws are keyed ``(session_id, committed_len)`` against
    the engine's seed — same keys, same draws, same stream."""
    k_schedule = [int(k) for k in k_schedule]
    if not k_schedule:
        return []
    engine = VerificationEngine(
        target_cfg, target_params, max_slots=1, max_len=max_len,
        method=method, seed=engine_seed,
    )
    server = WISPServer(
        engine,
        coeffs or EstimatorCoeffs(a=1e-4, b_compute=1e-8, b_read=1e-6, c=1e-3),
        policy="fcfs", network=NetworkModel(),
    )
    dev = EdgeDevice(
        draft_cfg, draft_params, predictor=predictor,
        k_max=k_max or max(k_schedule), max_len=max_len, seed=device_seed,
        draft_speed=draft_speed, greedy=greedy, q_mode=q_mode,
        q_top_c=q_top_c, spec_policy="scripted",
        spec_cfg={"schedule": k_schedule},
    )
    handle = server.open_session(session_id, prompt, slo_class=slo_class,
                                 queue_on_full=False)
    dev.start_session(session_id, prompt, handle.first_token)
    now = 0.0
    for _ in k_schedule:
        res = dev.draft_round()
        server.submit(session_id, res.tokens, res.q_logits,
                      q_compact=res.q_compact, now=now,
                      t_draft=res.draft_time, t_network=0.0)
        while server.queue_depth:
            for v in server.step(now):
                dev.apply_verdict(v.accept_len, v.token, res.tokens)
            now += 0.005
        server.pop_events()
    return [int(t) for t in dev.response_tokens]
