"""Edge device client: draft model + intelligent drafting controller +
session bookkeeping (mirrors the server's committed-prefix invariant).

Invariant shared with the server: ``fed`` = number of tokens whose state is
in the local draft cache = len(committed) - 1.  The last committed token is
the first input of the next draft round; rejected draft tokens are rolled
back by the position pointer (attention caches are length-capped).

Two drive modes share that invariant:

  * **lock-step** (``draft_round`` / ``apply_verdict``) — draft a block,
    wait for the verdict, commit, repeat.  The device idles while its
    request queues and verifies on the server: that idle window is exactly
    where the paper's Wasted Drafting Time and interference hide.
  * **pipelined** (``begin_round`` / ``finish_round`` /
    ``begin_speculation`` / ``resolve_verdict``) — the event-driven cluster
    runtime steps block drafting token-by-token on a virtual clock and,
    once a block is in flight, keeps drafting *speculatively*: it samples a
    guess for the server's bonus token and starts the next block after it.
    When the verdict lands, the guess either **commits** (full accept and
    the bonus token matches — the overlap-drafted tokens become the head of
    the next block, no time wasted) or **rolls back** (the cache position
    pointer snaps to the committed prefix, the same stale-but-masked
    rollback `apply_verdict` performs; the overlapped tokens are measured
    waste).  Both modes produce byte-identical committed streams because
    drafting keys are position-folded (`core/controller.py`) and stale
    cache entries past ``fed`` are never attended to.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import BlockDrafter, DraftingController, DraftResult
from repro.core.speculation import make_spec_controller
from repro.models import build


@dataclasses.dataclass
class EdgeSession:
    session_id: int
    committed: list            # committed token ids (full response prefix)
    prompt_len: int
    fed: int                   # draft-cache valid length
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    #: verdicts applied so far — the device half of the idempotency key
    #: (session_id, round_index): a duplicated/reordered verdict whose
    #: round does not equal ``resolved`` must never touch the stream
    resolved: int = 0


class EdgeDevice:
    """One edge device running a draft model for a single session stream."""

    def __init__(
        self,
        draft_cfg,
        draft_params,
        *,
        predictor=None,
        k_max: int = 8,
        draft_speed: float = 50.0,
        greedy: bool = False,
        max_len: int = 4096,
        seed: int = 0,
        q_mode: str = "dense",
        q_top_c: int = 64,
        spec_policy="static",
        spec_cfg: dict | None = None,
    ):
        self.cfg = draft_cfg
        self.bundle = build(draft_cfg)
        self.params = draft_params
        self.controller = DraftingController(
            self.bundle,
            draft_params,
            predictor=predictor,
            k_max=k_max,
            greedy=greedy,
            draft_speed=draft_speed,
            q_mode=q_mode,
            q_top_c=q_top_c,
        )
        #: per-session draft-length control (core/speculation.py): chooses
        #: each block's K cap from predicted acceptance, measured RTT and
        #: verifier load; "static" reproduces the fixed-K behavior exactly
        self.spec = make_spec_controller(
            spec_policy, k_max=k_max, draft_speed=draft_speed,
            predictor=predictor, **(spec_cfg or {}),
        )
        self.max_len = max_len
        self.cache = None
        self.session: EdgeSession | None = None
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(self.bundle.prefill)
        self._decode = jax.jit(self.bundle.decode)

    def start_session(self, session_id: int, prompt_tokens, first_token: int):
        """Prefill the local draft cache with the prompt; the server supplies
        the first committed token (sampled from the target at prefill)."""
        toks = np.asarray(prompt_tokens, np.int32)
        self.cache = self.bundle.init_cache(1, self.max_len, dtype=jnp.float32) \
            if self.cfg.family != "ssm" else self.bundle.init_cache(1, self.max_len)
        _, self.cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks[None])}, self.cache
        )
        self.session = EdgeSession(
            session_id=session_id,
            committed=list(toks) + [int(first_token)],
            prompt_len=len(toks),
            fed=len(toks),
        )
        self.spec.start_session()

    def begin_round(self) -> BlockDrafter:
        """Catch the local cache up to the committed stream and return a
        token-granular drafter for the next submission block.  The cluster
        runtime steps it between virtual-clock events; ``draft_round`` is
        the run-to-completion wrapper."""
        s = self.session
        catch = s.committed[s.fed :]
        assert catch, "invariant: committed always leads fed by >= 1"
        if len(catch) > 1:
            pre = jnp.asarray(np.asarray(catch[:-1], np.int32)[None])
            _, self.cache = self._decode(
                self.params, pre, self.cache, jnp.int32(s.fed)
            )
            s.fed += len(catch) - 1
        return self.controller.begin_block(
            self.rng, int(catch[-1]), self.cache, s.fed,
            k=self.spec.choose_k(),
        )

    def finish_round(self, drafter: BlockDrafter) -> DraftResult:
        """Absorb a completed drafter: sync the cache, update session
        counters, and return the block to submit."""
        res = drafter.result()
        self.cache = drafter.cache
        self._last_n_drafted = res.n_drafted
        s = self.session
        s.rounds += 1
        s.drafted += res.n_drafted
        return res

    def draft_round(self):
        """Draft a block; returns DraftResult.  Feeds any committed tokens
        the local cache is missing first (catch-up: after a fully-accepted
        block the last draft token was produced but never fed)."""
        drafter = self.begin_round()
        while drafter.step():
            pass
        return self.finish_round(drafter)

    def apply_verdict(self, accept_len: int, token: int, draft_tokens):
        """Commit the accepted prefix + correction token; roll the cache
        position back over rejected drafts (pointer-only for attention:
        entries past ``fed`` are stale-but-masked)."""
        s = self.session
        s.committed.extend(int(t) for t in draft_tokens[:accept_len])
        s.committed.append(int(token))
        s.accepted += accept_len
        s.resolved += 1
        # the draft loop fed [x_last, y_1 .. y_{n_drafted-1}]: the cache is
        # valid exactly up to the accepted prefix (or all fed tokens if the
        # whole block was accepted — the final draft token is caught up at
        # the next round).
        s.fed = s.fed + min(accept_len + 1, self._last_n_drafted)
        # Recurrent drafts cannot roll back by pointer; the serving stack
        # uses attention-family drafts (paper: Qwen3 ladder).  Guarded:
        if self.cfg.family in ("ssm", "hybrid") and accept_len < len(draft_tokens):
            raise NotImplementedError(
                "recurrent draft models need snapshot re-sync on rollback"
            )

    # -- speculative continuation (event-driven cluster runtime) -----------
    def begin_speculation(self, res) -> tuple[int, BlockDrafter, int]:
        """Start drafting the NEXT block while ``res`` is in flight, under
        the optimistic assumption that the whole block is accepted and the
        server's bonus token equals the draft model's own next sample (the
        *guess*).

        On a predictor-stopped block the guess is free — the flagged token
        the controller withheld already sits at the bonus position.  On a
        max-stopped block the guess costs one extra draft-model step.
        Returns ``(guess, drafter, guess_cost_tokens)``; the drafter's
        tokens become the next submission block if the verdict confirms the
        guess (``resolve_verdict``)."""
        if self.cfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                "speculative continuation needs pointer-rollback draft caches"
            )
        s = self.session
        valid = s.fed + res.n_drafted        # cache-valid tokens after block
        if res.n_drafted > res.n_sent:       # predictor-stop: flagged = guess
            guess, cost = int(res.last_drafted), 0
        else:                                # max-stop: sample the guess
            guess, _, self.cache = self.controller.sample_next(
                self.rng, int(res.last_drafted), self.cache, valid
            )
            valid += 1
            cost = 1
        s.drafted += cost
        drafter = self.controller.begin_block(self.rng, guess, self.cache,
                                              valid, k=self.spec.choose_k())
        return guess, drafter, cost

    def resolve_verdict(self, accept_len: int, token: int, res,
                        guess: int | None = None,
                        speculated: bool = False,
                        round_index: int | None = None) -> bool:
        """Apply a verdict to a round that may have speculation in flight.

        Commit path (returns True): the block was fully accepted AND the
        bonus token matches the guess — every token drafted during the
        overlap stands, and the speculation drafter simply continues as the
        live drafter of the next round (``fed`` realigns to the invariant
        ``len(committed) - 1``: the guess is committed and already fed).

        Rollback path (returns False): plain ``apply_verdict`` — the cache
        position pointer snaps back over rejected drafts and every
        speculative entry past it becomes stale-but-masked.

        ``round_index`` is the verdict's half of the idempotency key
        (DESIGN.md §14): callers that can see duplicated/reordered
        verdicts (the chaos runtime) pass it, and a mismatch against the
        session's ``resolved`` counter raises — the committed prefix only
        ever advances by exactly-once verdict application.  Drivers on a
        reliable channel may omit it."""
        s = self.session
        if round_index is not None and int(round_index) != s.resolved:
            raise ValueError(
                f"session {s.session_id}: verdict for round {round_index} "
                f"applied out of order (device at round {s.resolved})"
            )
        if speculated and accept_len == res.n_sent and int(token) == int(guess):
            s.committed.extend(int(t) for t in res.tokens)
            s.committed.append(int(token))
            s.accepted += accept_len
            s.fed = len(s.committed) - 1
            s.resolved += 1
            return True
        self.apply_verdict(accept_len, token, res.tokens)
        return False

    # -- link-health feedback (edge-link fault domain, DESIGN.md §14) ------
    def observe_link(self, ok: bool, *, down: bool = False) -> None:
        """One link observation for the speculation controller's health
        EWMA: ``ok`` on an applied verdict, not-ok on a round timeout
        (``down=True`` latches the LINK_DOWN state)."""
        self.spec.observe_link(ok, down=down)

    # -- adaptive-speculation feedback (core/speculation.py) ---------------
    def observe_verdict(self, accept_len: int, k_used: int, *,
                        rtt: float | None = None,
                        queue_depth: float | None = None,
                        features=None) -> None:
        """Feed one verified round back into the speculation controller:
        measured acceptance (or the predictor's calibrated probability
        over the block's logit features, when both ride along), the
        round's network RTT, and the verifier's queue depth piggybacked
        on the verdict."""
        p = None
        if self.controller.predictor is not None and features is not None:
            feats = np.asarray(features, np.float32)
            if feats.size:
                p = float(np.mean(np.asarray(
                    self.controller.predictor.proba(feats))))
        self.spec.observe(accept_len=int(accept_len), k_used=int(k_used),
                          p_accept=p, rtt=rtt, queue_depth=queue_depth)

    @property
    def response_tokens(self):
        s = self.session
        return s.committed[s.prompt_len:]
