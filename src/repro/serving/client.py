"""Edge device client: draft model + intelligent drafting controller +
session bookkeeping (mirrors the server's committed-prefix invariant).

Invariant shared with the server: ``fed`` = number of tokens whose state is
in the local draft cache = len(committed) - 1.  The last committed token is
the first input of the next draft round; rejected draft tokens are rolled
back by the position pointer (attention caches are length-capped).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import DraftingController
from repro.models import build


@dataclasses.dataclass
class EdgeSession:
    session_id: int
    committed: list            # committed token ids (full response prefix)
    prompt_len: int
    fed: int                   # draft-cache valid length
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0


class EdgeDevice:
    """One edge device running a draft model for a single session stream."""

    def __init__(
        self,
        draft_cfg,
        draft_params,
        *,
        predictor=None,
        k_max: int = 8,
        draft_speed: float = 50.0,
        greedy: bool = False,
        max_len: int = 4096,
        seed: int = 0,
    ):
        self.cfg = draft_cfg
        self.bundle = build(draft_cfg)
        self.params = draft_params
        self.controller = DraftingController(
            self.bundle,
            draft_params,
            predictor=predictor,
            k_max=k_max,
            greedy=greedy,
            draft_speed=draft_speed,
        )
        self.max_len = max_len
        self.cache = None
        self.session: EdgeSession | None = None
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(self.bundle.prefill)
        self._decode = jax.jit(self.bundle.decode)

    def start_session(self, session_id: int, prompt_tokens, first_token: int):
        """Prefill the local draft cache with the prompt; the server supplies
        the first committed token (sampled from the target at prefill)."""
        toks = np.asarray(prompt_tokens, np.int32)
        self.cache = self.bundle.init_cache(1, self.max_len, dtype=jnp.float32) \
            if self.cfg.family != "ssm" else self.bundle.init_cache(1, self.max_len)
        _, self.cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks[None])}, self.cache
        )
        self.session = EdgeSession(
            session_id=session_id,
            committed=list(toks) + [int(first_token)],
            prompt_len=len(toks),
            fed=len(toks),
        )

    def draft_round(self):
        """Draft a block; returns DraftResult.  Feeds any committed tokens
        the local cache is missing first (catch-up: after a fully-accepted
        block the last draft token was produced but never fed)."""
        s = self.session
        catch = s.committed[s.fed :]
        assert catch, "invariant: committed always leads fed by >= 1"
        if len(catch) > 1:
            pre = jnp.asarray(np.asarray(catch[:-1], np.int32)[None])
            _, self.cache = self._decode(
                self.params, pre, self.cache, jnp.int32(s.fed)
            )
            s.fed += len(catch) - 1
        last = np.asarray([catch[-1]], np.int32)
        res, self.cache, self.rng = self.controller.draft(
            self.rng, last, self.cache, s.fed
        )
        self._last_n_drafted = res.n_drafted
        s.rounds += 1
        s.drafted += res.n_drafted
        return res

    def apply_verdict(self, accept_len: int, token: int, draft_tokens):
        """Commit the accepted prefix + correction token; roll the cache
        position back over rejected drafts (pointer-only for attention:
        entries past ``fed`` are stale-but-masked)."""
        s = self.session
        s.committed.extend(int(t) for t in draft_tokens[:accept_len])
        s.committed.append(int(token))
        s.accepted += accept_len
        # the draft loop fed [x_last, y_1 .. y_{n_drafted-1}]: the cache is
        # valid exactly up to the accepted prefix (or all fed tokens if the
        # whole block was accepted — the final draft token is caught up at
        # the next round).
        s.fed = s.fed + min(accept_len + 1, self._last_n_drafted)
        # Recurrent drafts cannot roll back by pointer; the serving stack
        # uses attention-family drafts (paper: Qwen3 ladder).  Guarded:
        if self.cfg.family in ("ssm", "hybrid") and accept_len < len(draft_tokens):
            raise NotImplementedError(
                "recurrent draft models need snapshot re-sync on rollback"
            )

    @property
    def response_tokens(self):
        s = self.session
        return s.committed[s.prompt_len:]
