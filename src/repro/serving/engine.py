"""Verification engine (paper §4.5): batched one-step verification.

Two cache backends, auto-selected per family (DESIGN.md §4):

  * paged (attention families: dense/moe/vlm/audio, full attention) —
    sessions allocate fixed-size pages from a shared `PagedKV` pool via a
    block table; prompt prefill fills pages and registers full pages in the
    content-addressed prefix index so concurrent sessions with a common
    prompt prefix share pages; batched verification runs the target once
    over ``[x_last, y_1..y_K]`` through the paged attention kernel with
    per-row block tables and length pointers.  Accepted-length rollback is
    the length pointer plus releasing now-unreachable tail pages.
    Cross-attention K/V (vlm images, audio encoder memory) is bounded and
    stays in a small dense per-slot side cache; prefix sharing is disabled
    for those families (their self-attn KV is not a pure function of the
    token ids).

  * dense slots (recurrent families: ssm/hybrid, plus windowed-attention
    configs) — the engine owns a fixed-capacity cache with ``max_slots``
    rows; sessions map to slots.  Recurrent targets verify through a
    ``lax.scan`` over the K+1 fed tokens that computes the accept rule
    *incrementally* (the accept test at draft position t only needs the
    logits step t produced) and keeps exactly one live "selected state"
    per row — the state at the accepted length — inside the scan carry
    (recurrent state cannot be truncated; DESIGN.md §5).

Hot path (DESIGN.md §9): each ``verify`` batch executes as ONE fused jit
program per (backend, bucket) — cache gather, target forward, the
accept/reject + correction rule, and cache scatter-back all inside the
same dispatch — so only two small ``(B,)`` arrays (``accept_len``,
``token``) return to the host and the ``(B, K+1, V)`` target logits never
leave the device.  Host-side staging uses pooled, bucket-keyed buffers
(no per-call ``np.zeros``/``np.full``); pad rows simply keep the pooled
buffers' reset state — slot index ``max_slots`` is an out-of-bounds
sentinel that gathers clamped (read-only) and whose scatter updates XLA
drops.  ``fed``/``last_token`` commit from one device->host transfer.
The engine counts compiled-program launches (``dispatch_counts``) and
staged bytes (``stats``) so benchmarks/hotpath.py and CI can hold the
dispatch/byte budgets.

Batch shapes are padded to fixed buckets (draft length to k_max, batch to
powers of two) so jit compiles a bounded set of programs.

Prompt prefill is **incremental** (DESIGN.md §8): ``begin_prefill`` opens a
session without running the model, ``prefill_chunk`` advances it by a
bounded number of prompt tokens, and the prompt's first response token is
produced by whichever chunk consumes the final prompt position.
``new_session`` is the run-to-completion wrapper (one whole-prompt chunk —
the legacy monolithic path, bit-for-bit).  ``step`` executes a mixed batch
of verification items and prefill chunks in one engine dispatch, which is
what lets the SLO scheduler interleave cold-prompt prefill with
deadline-critical verification instead of stalling behind it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import (
    CompactQ,
    accept_draws,
    correction_token,
    residual_qhat_compact,
    residual_qhat_dense,
    verify_epoch_rule,
)
from repro.models import build, encdec, transformer
from repro.serving.kv_cache import (
    PAGE_SIZE,
    OutOfPages,
    PagedKV,
    TierConfig,
)

#: families whose self-attn KV can be paged; recurrent state cannot.
ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")


class NoFreeSlots(RuntimeError):
    """All ``max_slots`` session rows are occupied (admission-control
    signal, like ``OutOfPages`` for page capacity)."""


def supports_paged(cfg) -> bool:
    """Paged verification needs full (non-windowed) softmax attention —
    the paged kernel addresses history purely through block table +
    length pointer; a sliding-window mask would need per-page offsets."""
    return cfg.family in ATTENTION_FAMILIES and not cfg.sliding_window


def _batch_axis_tree(cache_axes_tree):
    """Map each cache leaf's logical axes -> index of 'act_batch'."""
    return jax.tree.map(
        lambda axes: axes.index("act_batch"),
        cache_axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def _bucket(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _log_softmax1(x):
    """log_softmax at temperature 1.0, matching `speculative._log_softmax`
    bit-for-bit (x / max(1.0, eps) == x exactly)."""
    return jax.nn.log_softmax(x, axis=-1)


@dataclasses.dataclass
class VerifyItem:
    slot: int
    draft_tokens: np.ndarray     # (k,) int32
    #: dense (k, V) float32 draft logits — the exact-residual wire format.
    #: Ignored entirely in greedy mode (nothing is staged).
    q_logits: np.ndarray | None = None
    #: compact draft statistics (`CompactQ`, O(k·C)) — exact accept test,
    #: residual correction within the documented bound (DESIGN.md §9).
    #: A batch must be uniformly dense or uniformly compact.
    q_compact: CompactQ | None = None
    #: optional (a, b) int pair keying this row's accept/correction draws
    #: (serving passes (session_id, committed_len)).  When every item in a
    #: batch carries a tag, verification outcomes become a pure function of
    #: (engine seed, tag, tokens, logits) — independent of batch composition
    #: and dispatch order, so differently-batched drivers commit identical
    #: streams.  Untagged batches keep the legacy split-per-call stream.
    rng_tag: tuple | None = None


@dataclasses.dataclass
class VerifyOutcome:
    slot: int
    accept_len: int
    token: int                   # correction / bonus token
    emitted: int                 # accept_len + 1
    t_verify: float              # engine wall time attributed to the batch


@dataclasses.dataclass
class PrefillState:
    """Resumable prompt-prefill progress for one session slot.

    Created by ``begin_prefill`` (which allocates the slot and, on the
    paged backend, reuses any cached prompt prefix); advanced by
    ``prefill_chunk``.  ``done`` counts prompt tokens whose KV/state is
    valid (including the prefix-cache hit), so ``done`` is the request's
    ``cached_len`` when a chunk is priced by the estimator.  A chunk that
    consumes the final prompt position sets ``first_token`` (the response's
    token 0, sampled greedily from the target's own logits)."""

    slot: int
    tokens: np.ndarray           # full prompt, int32
    done: int                    # prompt tokens with valid KV/state
    extras: dict | None = None   # vlm/audio conditioning (first chunk only)
    first_token: int | None = None
    chunks: int = 0              # chunks executed (observability)
    n_cached: int = 0            # prompt tokens served by the prefix cache

    @property
    def total(self) -> int:
        return len(self.tokens)

    @property
    def remaining(self) -> int:
        return self.total - self.done

    @property
    def finished(self) -> bool:
        return self.first_token is not None


@dataclasses.dataclass
class PrefillChunkItem:
    """One schedulable unit of prompt prefill for ``step``: advance
    ``state`` by up to ``n_tokens`` prompt tokens."""

    state: PrefillState
    n_tokens: int


@dataclasses.dataclass
class PrefillOutcome:
    slot: int
    processed: int               # prompt tokens consumed by this chunk
    done: int                    # total valid prompt tokens after the chunk
    total: int                   # prompt length
    first_token: int | None      # set when the prompt completed this chunk
    t_chunk: float               # engine wall time attributed to the chunk
    oom: bool = False            # chunk deferred: page pool cannot cover it


class VerificationEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_len: int,
        method: str = "residual",
        seed: int = 0,
        cache_dtype=jnp.float32,
        paged: bool | None = None,
        page_size: int = PAGE_SIZE,
        n_pages: int | None = None,
        kv_tier_pages: int = 0,
        spill_quantize: bool = False,
        spill_idle_epochs: int = 2,
    ):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.method = method
        self.recurrent = cfg.family in ("ssm", "hybrid")
        self.paged = supports_paged(cfg) if paged is None else bool(paged)
        if self.paged and not supports_paged(cfg):
            raise ValueError(
                f"paged verification unsupported for {cfg.name!r} "
                f"(family={cfg.family}, window={cfg.sliding_window})"
            )
        self.fed = np.zeros(max_slots, np.int64)        # KV-valid tokens/slot
        self.last_token = np.zeros(max_slots, np.int64) # committed[-1]/slot
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.rng = jax.random.PRNGKey(seed)
        #: never advanced: base for rng_tag-keyed (deterministic) verification
        self._rng_base = jax.random.PRNGKey(seed)
        #: pooled, bucket-keyed host staging buffers (DESIGN.md §9): one
        #: allocation per (shape bucket, q representation), reused across
        #: calls.  Rows written by the previous call are reset to their pad
        #: value on reuse; pad rows beyond the live batch simply keep that
        #: reset state (slot sentinel ``max_slots``: clamped gather,
        #: dropped scatter — no per-pad-row Python work).
        self._pools: dict[tuple, dict] = {}
        #: compiled-program launches by name ("verify" is the fused
        #: per-epoch program — exactly one per verify() call, any backend)
        self.dispatch_counts: Counter = Counter()
        #: ``prefix_cached_tokens`` counts prompt tokens satisfied by the
        #: content-addressed prefix cache.  That cache exists only on the
        #: paged backend — on the dense backend the field is structurally
        #: zero (no prefix cache, nothing to hit), not "zero hits observed";
        #: check ``stats["backend"]`` / ``prefix_cache_stats()["backend"]``
        #: before reading it as a hit rate (DESIGN.md §3).
        self.stats = {
            "backend": "paged" if self.paged else "dense",
            "batches": 0,
            "tokens_verified": 0,
            "tokens_committed": 0,
            "prefix_cached_tokens": 0,
            "prefill_chunks": 0,
            "dispatches": 0,          # compiled-program launches
            "h2d_bytes": 0,           # host->device staged bytes (verify)
            "h2d_q_bytes": 0,         # ...of which draft-q payload
            "d2h_bytes": 0,           # device->host result bytes (verify)
            #: batches whose rows carried heterogeneous draft lengths
            #: (adaptive per-session K, DESIGN.md §11): ragged rows ride
            #: the existing bucket/pad machinery — per-row ``dlen`` masks
            #: the pad tail, so mixed-K costs no extra dispatch
            "mixed_k_batches": 0,
            #: host spill tier (DESIGN.md §12): bytes moved across the
            #: device<->host boundary by spill / page-in, plus format
            #: counters — structurally zero when no tier is configured
            "spill_bytes": 0,
            "pagein_bytes": 0,
            "pages_spilled": 0,
            "pages_paged_in": 0,
            "spills_quantized": 0,
            "spills_raw": 0,
            "host_evictions": 0,
        }
        if kv_tier_pages > 0 and not self.paged:
            raise ValueError(
                "kv_tier_pages requires the paged backend "
                f"(family={cfg.family}, window={cfg.sliding_window})"
            )
        self._tier_cfg = (
            TierConfig(host_pages=int(kv_tier_pages),
                       quantize=bool(spill_quantize),
                       idle_epochs=int(spill_idle_epochs))
            if kv_tier_pages > 0 else None
        )

        if self.paged:
            self._init_paged(cache_dtype, page_size, n_pages)
        else:
            self.cache = self.bundle.init_cache(max_slots, max_len, dtype=cache_dtype) \
                if cfg.family != "ssm" else self.bundle.init_cache(max_slots, max_len)
            self._bax = _batch_axis_tree(self.bundle.cache_axes())
            self._decode = jax.jit(self.bundle.decode)
            self._prefill = jax.jit(self.bundle.prefill)
            self._fused_verify = (
                self._build_fused_recurrent()
                if self.recurrent
                else self._build_fused_attention()
            )

    # -- paged backend setup --------------------------------------------------
    def _init_paged(self, cache_dtype, page_size, n_pages):
        cfg = self.cfg
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        if n_pages is None:
            # every slot must be able to reach max_len even with per-slot
            # page rounding, + the reserved scratch page
            n_pages = self.max_slots * -(-self.max_len // page_size) + 1
        self.page_size = page_size
        self.kv = PagedKV(
            cfg.n_layers, n_pages, hkv, hd,
            page_size=page_size, dtype=cache_dtype,
            tier=self._tier_cfg, counters=self.stats,
        )
        #: prefix sharing is sound only when KV is a pure function of the
        #: token ids — cross-attention families condition on extras.
        self.share_prefix = cfg.family in ("dense", "moe")
        self.tokens: dict[int, list] = {}   # slot -> tokens with KV in pages
        self.extras_cache = None
        # donate the page pool (args 2/3 after params, tokens) so XLA
        # updates pages in place instead of copying the whole pool (and
        # transiently doubling KV memory) every call; CPU ignores it
        _jit = partial(jax.jit, donate_argnums=(2, 3))
        if cfg.family == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            z = lambda: jnp.zeros(
                (n_groups, self.max_slots, cfg.num_image_tokens, hkv, hd),
                cache_dtype,
            )
            self.extras_cache = {"k_img": z(), "v_img": z()}
            self._extras_key = "image_embeds"
            self._extras_builder = jax.jit(partial(transformer.vlm_cross_kv, cfg))
            decode_raw = partial(transformer.decode_paged, cfg)
            self._prefill_paged = _jit(
                partial(transformer.decode_paged, cfg, dropless=False)
            )
        elif cfg.family == "audio":
            z = lambda: jnp.zeros(
                (cfg.n_layers, self.max_slots, cfg.encoder_frames, hkv, hd),
                cache_dtype,
            )
            self.extras_cache = {"k_mem": z(), "v_mem": z()}
            self._extras_key = "frames"
            self._extras_builder = jax.jit(partial(encdec.encdec_cross_kv, cfg))
            decode_raw = partial(encdec.encdec_decode_paged, cfg)
            self._prefill_paged = _jit(decode_raw)       # no MoE routing
        else:
            decode_raw = partial(transformer.decode_paged, cfg)
            # prompt prefill keeps GShard capacity MoE routing, matching
            # the dense `prefill` path (verify stays dropless)
            self._prefill_paged = _jit(
                partial(transformer.decode_paged, cfg, dropless=False)
            )
        self._fused_verify = self._build_fused_paged(decode_raw)

    # -- fused per-epoch verify programs (DESIGN.md §9) -----------------------
    # Each program is ONE jit dispatch: target forward + the accept/reject
    # + correction rule, returning just (accept_len, token) plus the
    # updated device-resident cache state.  ``qargs`` is a (possibly empty)
    # dict of staged draft-q arrays whose structure selects the dense /
    # compact / greedy variant at trace time.

    def _build_fused_attention(self):
        decode = self.bundle.decode
        bax = self._bax

        def fused(params, cache, slot_idx, feed, pos, draft, dlen, rng,
                  tags, qargs, *, method, tagged):
            sub = jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, slot_idx, axis=ax,
                                          mode="clip"),
                cache, bax,
            )
            p_logits, sub = decode(params, feed, sub, pos)
            out = verify_epoch_rule(
                rng, draft, dlen, p_logits, method=method,
                rng_tags=tags if tagged else None, **qargs,
            )

            def put(leaf, new, ax):
                sl = (slice(None),) * ax
                # pad rows carry the OOB slot sentinel: XLA drops their
                # updates, so no masking / per-row host logic is needed
                return leaf.at[sl + (slot_idx,)].set(new.astype(leaf.dtype))

            cache = jax.tree.map(put, cache, sub, bax)
            return out["accept_len"], out["token"], cache

        return jax.jit(fused, static_argnames=("method", "tagged"),
                       donate_argnums=(1,))

    def _build_fused_recurrent(self):
        decode = self.bundle.decode
        bax = self._bax
        V = self.cfg.vocab

        def tree_where(cond, new, old):
            def w(nl, ol, ax):
                shape = [1] * nl.ndim
                shape[ax] = cond.shape[0]
                return jnp.where(cond.reshape(shape), nl, ol)

            return jax.tree.map(w, new, old, bax)

        def fused(params, cache, slot_idx, feed, pos, draft, dlen, rng,
                  tags, qargs, *, method, tagged):
            B, T = feed.shape
            K = T - 1
            sub = jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, slot_idx, axis=ax,
                                          mode="clip"),
                cache, bax,
            )
            rng_tags = tags if tagged else None
            u, row_keys, rng = accept_draws(rng, B, K, method, rng_tags)
            logq_full = None
            if "q_logits" in qargs:
                logq_full = _log_softmax1(qargs["q_logits"])
                lqt = jnp.take_along_axis(
                    logq_full, draft[..., None], axis=-1
                )[..., 0]
            elif "logq_tok" in qargs:
                lqt = qargs["logq_tok"]
            else:
                lqt = jnp.zeros((B, K), jnp.float32)     # greedy: unused

            # Per-step inputs, padded to T steps.  Step t feeds feed[:, t]
            # and its output logits are the target distribution for draft
            # position t — so the accept test runs INSIDE the scan, the
            # carry tracks the still-accepting prefix, and the state at the
            # accepted length is selected as it streams past (one live
            # state copy instead of T+1 stacked caches).  Step K is the
            # bonus slot (its logits are the bonus distribution).
            kpos = jnp.arange(K, dtype=jnp.int32)
            xs = dict(
                tok=feed.T,
                t=jnp.arange(T, dtype=jnp.int32),
                d=jnp.pad(draft, ((0, 0), (0, 1))).T,
                val=jnp.pad(kpos[None, :] < dlen[:, None],
                            ((0, 0), (0, 1))).T,
                bon=(jnp.arange(T, dtype=jnp.int32)[None, :]
                     == dlen[:, None]).T,
                u=jnp.pad(jnp.ones((B, K)) if u is None else u,
                          ((0, 0), (0, 1)), constant_values=1.0).T,
                lq=jnp.pad(lqt, ((0, 0), (0, 1))).T,
            )

            def body(carry, x):
                state, kept, still, corr, L = carry
                lg, state = decode(params, x["tok"][:, None], state,
                                   pos + x["t"])
                row = lg[:, 0]
                # rows whose accepted prefix is still growing (still was
                # True *entering* this step) advance their selected state;
                # the step after a row's stop (rejection or bonus) — and
                # every later one — leaves it frozen at length L+1
                kept = tree_where(still, state, kept)
                if method == "greedy":
                    acc_raw = x["d"] == jnp.argmax(row, axis=-1).astype(
                        x["d"].dtype
                    )
                else:
                    lpt = jnp.take_along_axis(
                        _log_softmax1(row), x["d"][:, None], axis=-1
                    )[:, 0]
                    acc_raw = jnp.log(x["u"]) <= (lpt - x["lq"])
                stop = jnp.logical_and(
                    still,
                    jnp.logical_or(
                        x["bon"],
                        jnp.logical_and(x["val"], jnp.logical_not(acc_raw)),
                    ),
                )
                corr = jnp.where(stop[:, None], row.astype(corr.dtype), corr)
                L = L + jnp.logical_and(
                    still, jnp.logical_and(x["val"], acc_raw)
                ).astype(jnp.int32)
                still = jnp.logical_and(still, jnp.logical_not(stop))
                return (state, kept, still, corr, L), None

            # the scan carry must be type-stable: decode may return state
            # in a wider dtype than the stored cache (e.g. bf16 conv
            # buffers stepping in f32) — initialize the carry in decode's
            # OUTPUT dtypes (exact upcast) and cast back at scatter
            out_aval = jax.eval_shape(
                lambda s: decode(params, feed[:, :1], s, pos)[1], sub
            )
            sub0 = jax.tree.map(
                lambda leaf, a: leaf.astype(a.dtype), sub, out_aval
            )
            init = (
                sub0, sub0, jnp.ones((B,), bool),
                jnp.zeros((B, V), jnp.float32),
                jnp.zeros((B,), jnp.int32),
            )
            (_, kept, _, corr, L), _ = jax.lax.scan(body, init, xs)

            qhat = None
            if method == "residual":
                if logq_full is not None:
                    qhat = residual_qhat_dense(logq_full, L)
                else:
                    qhat = residual_qhat_compact(
                        qargs["top_idx"], qargs["top_logq"], qargs["tail"],
                        L, V,
                    )
            token, rng = correction_token(
                rng, row_keys, corr, qhat, method=method, temperature=1.0
            )

            def put(leaf, new, ax):
                sl = (slice(None),) * ax
                return leaf.at[sl + (slot_idx,)].set(new.astype(leaf.dtype))

            cache = jax.tree.map(put, cache, kept, bax)
            return L, token.astype(jnp.int32), cache

        return jax.jit(fused, static_argnames=("method", "tagged"),
                       donate_argnums=(1,))

    def _build_fused_paged(self, decode_raw):
        def fused(params, feed, kp, vp, bt, base, tl, cross, draft, dlen,
                  rng, tags, qargs, *, method, tagged):
            logits, (kp, vp) = decode_raw(params, feed, kp, vp, bt, base,
                                          tl, cross)
            out = verify_epoch_rule(
                rng, draft, dlen, logits, method=method,
                rng_tags=tags if tagged else None, **qargs,
            )
            return out["accept_len"], out["token"], (kp, vp)

        return jax.jit(fused, static_argnames=("method", "tagged"),
                       donate_argnums=(2, 3))

    # -- slot/cache plumbing (dense backend) ----------------------------------
    def _gather(self, slots):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda leaf, ax: jnp.take(leaf, idx, axis=ax, mode="clip"),
            self.cache, self._bax,
        )

    def _scatter(self, slots, sub, valid_n):
        idx = np.asarray(slots[:valid_n], np.int32)

        def put(leaf, new, ax):
            sl = (slice(None),) * ax
            return leaf.at[sl + (idx,)].set(
                jax.lax.slice_in_dim(new, 0, valid_n, axis=ax).astype(leaf.dtype)
            )

        self.cache = jax.tree.map(put, self.cache, sub, self._bax)

    # -- extras side cache (paged vlm/audio: batch axis is 1) -----------------
    def _extras_gather(self, slots):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda leaf: jnp.take(leaf, idx, axis=1, mode="clip"),
            self.extras_cache,
        )

    def _extras_put(self, slot, sub):
        self.extras_cache = jax.tree.map(
            lambda leaf, new: leaf.at[:, slot].set(new[:, 0].astype(leaf.dtype)),
            self.extras_cache, sub,
        )

    # -- staging pools + dispatch accounting ----------------------------------
    def _count_dispatch(self, name: str):
        self.dispatch_counts[name] += 1
        self.stats["dispatches"] += 1

    def _pool(self, key: tuple, spec: dict) -> dict:
        """Fetch (or build) the pooled buffer set for ``key``.  On reuse,
        rows the previous call wrote (tracked by the ``_hw`` high-water
        mark) are reset to their pad value — pad rows of the next batch
        need no per-row Python work, they just keep this state."""
        p = self._pools.get(key)
        if p is None:
            p = {"_hw": 0}
            for name2, (shape, dtype, fill) in spec.items():
                p[name2] = np.full(shape, fill, dtype) if fill else \
                    np.zeros(shape, dtype)
            self._pools[key] = p
            return p
        hw = p["_hw"]
        if hw:
            for name2, (shape, dtype, fill) in spec.items():
                p[name2][:hw] = fill if fill else 0
        return p

    def _stage_verify(self, nb: int, K: int, q_kind: str, C: int) -> dict:
        sent = self.max_slots                  # OOB slot sentinel (pad rows)
        spec = {
            "draft": ((nb, K), np.int32, 0),
            "dlen": ((nb,), np.int32, 0),
            "feed": ((nb, K + 1), np.int32, 0),
            "pos": ((nb,), np.int32, 0),
            "slots": ((nb,), np.int32, sent),
            "tags": ((nb, 2), np.int32, 0),
            "tl": ((nb,), np.int32, 0),
        }
        if q_kind == "dense":
            spec["qlog"] = ((nb, K, self.cfg.vocab), np.float32, -30.0)
        elif q_kind == "compact":
            spec["logq_tok"] = ((nb, K), np.float32, 0)
            # unused table cells carry an out-of-vocab id: their scatter
            # updates are dropped during q̂ reconstruction — an in-bounds
            # pad (e.g. 0) would collide with token 0's real top entry
            # when blocks of different C share a batch bucket
            spec["top_idx"] = ((nb, K, C), np.int32, 1 << 30)
            spec["top_logq"] = ((nb, K, C), np.float32, -30.0)
            spec["tail"] = ((nb, K), np.float32, 0)
        return self._pool(("verify", nb, K, q_kind, C), spec)

    # -- memory accounting ----------------------------------------------------
    def memory_budget_tokens(self) -> int:
        """KV-token capacity the scheduler may admit against this epoch.

        A scheduled request accounts ``cached_len + new_tokens``; its
        cached tokens are covered by its session's resident pages and its
        new tokens must fit in its own tail-page slack or in pages the
        allocator can still hand out (free + evictable prefix-cached).  So
        the live budget is ``resident_capacity + free`` — counting the
        slack inside sequences' tail pages matters: with large pages and
        short sessions most capacity *is* tail slack, and a budget of only
        committed+free livelocks a full pool even though every request
        fits (single-slot engines hit this immediately).  The budget
        tightens as rejected-draft garbage accumulates and widens when
        sessions close or tail pages are trimmed.  The dense backend's
        capacity is static.

        With a spill tier (DESIGN.md §12) the budget additionally counts
        tokens the tier could move to host DRAM on demand (cold private
        pages of idle sessions, capped by host headroom) — admission sees
        through the tier, which is what multiplies resident-session
        capacity past the device pool."""
        if self.paged:
            return (self.kv.free_tokens + self.kv.resident_tokens()
                    + self.kv.spillable_tokens())
        return self.max_slots * self.max_len

    # -- spill tier (DESIGN.md §12) -------------------------------------------
    @property
    def tiered(self) -> bool:
        return self.paged and self.kv.tiered

    def spill_session(self, slot: int) -> int:
        """Force-spill a session's private pages to the host tier (tests,
        golden-stream battery, and explicit cold-session demotion).
        Returns device pages freed; 0 without a tier."""
        if not self.tiered:
            return 0
        return self.kv.spill_seq(slot)

    def prefetch_session(self, slot: int) -> int:
        """Best-effort page-in of a session's spilled pages ahead of its
        next verify epoch (the server calls this at submit time so the
        fused hot path never blocks on a fault).  Returns pages loaded; a
        device pool too full to cover the prefetch leaves the session
        spilled — verify's own ``ensure_resident`` retries under the
        OutOfPages degradation path."""
        if not self.tiered or slot not in self.kv.tables:
            return 0
        try:
            return self.kv.ensure_resident(slot)
        except OutOfPages:
            return 0

    def spilled_tokens(self, slot: int) -> int:
        """Token capacity of ``slot``'s host-resident pages — the page-in
        debt a verify of this session must pay (the scheduler prices it
        via ``WorkItem.pagein_tokens``)."""
        if not self.tiered or slot not in self.kv.tables:
            return 0
        return self.kv.spilled_tokens(slot)

    def prefix_cache_stats(self) -> dict:
        """Prefix-cache / page-pool counters, tagged with the backend that
        produced them.  The prefix cache is a paged-backend structure; the
        dense backend reports ``backend="dense"`` with zero counters —
        structurally zero (the cache does not exist), not a measured 0%
        hit rate.  Callers comparing backends must branch on ``backend``
        instead of treating the zeros as data (DESIGN.md §3)."""
        if self.paged:
            a = self.kv.allocator
            return {"backend": "paged", "hits": a.hits, "misses": a.misses,
                    "pages_in_use": a.in_use, "pages_free": len(a.free)}
        return {"backend": "dense", "hits": 0, "misses": 0,
                "pages_in_use": 0, "pages_free": 0}

    # -- session lifecycle -----------------------------------------------------
    def new_session(self, prompt_tokens, extras=None) -> tuple[int, int]:
        """Prefill a prompt into a fresh slot.  Returns (slot, first_token).

        Monolithic wrapper over the incremental path: one whole-prompt
        chunk, so behavior (including jit bucketing) is identical to the
        legacy blocking prefill.  The first committed token is sampled from
        the target's own prefill logits (the response's token 0 always
        comes from the target).  Paged backend: raises ``OutOfPages`` (with
        the slot and any partial pages returned) when the pool cannot cover
        the prompt."""
        st = self.begin_prefill(prompt_tokens, extras=extras)
        try:
            while not st.finished:
                self.prefill_chunk(st, st.remaining)
        except OutOfPages:
            self.abort_prefill(st)
            raise
        return st.slot, st.first_token

    def begin_prefill(self, prompt_tokens, extras=None) -> PrefillState:
        """Open a session slot for incremental prompt prefill WITHOUT
        running the model.  Paged backend: allocates the block table and
        reuses any content-addressed cached prefix (``state.done`` starts
        at the prefix hit) and builds the bounded cross-attention side
        cache for vlm/audio extras.  Raises ``NoFreeSlots`` /
        ``OutOfPages`` with nothing leaked (admission-control signals)."""
        if not self.free_slots:
            raise NoFreeSlots("no free verification slots")
        toks = np.asarray(prompt_tokens, np.int32)
        slot = self.free_slots.pop()
        if not self.paged:
            return PrefillState(slot=slot, tokens=toks, done=0, extras=extras)
        try:
            n_cached = self.kv.open_seq(slot, toks, share=self.share_prefix)
        except OutOfPages:
            if slot in self.kv.tables:
                self.kv.close_seq(slot)
            self.free_slots.append(slot)
            raise
        if self.extras_cache is not None:
            self._count_dispatch("extras")
            k_x, v_x = self._extras_builder(
                self.params, jnp.asarray(extras[self._extras_key])
            )
            keys = sorted(self.extras_cache)          # (k_img, v_img) / (k_mem, v_mem)
            self._extras_put(slot, {keys[0]: k_x, keys[1]: v_x})
        return PrefillState(slot=slot, tokens=toks, done=n_cached,
                            extras=extras, n_cached=n_cached)

    def prefill_chunk(self, st: PrefillState, n_tokens: int) -> int:
        """Advance ``st`` by up to ``n_tokens`` prompt tokens in one forward
        pass; returns the tokens consumed.  The chunk that consumes the
        final prompt position samples the first response token and (paged,
        sharing families) publishes the prompt's full pages to the prefix
        index.  Paged backend: raises ``OutOfPages`` with ``st`` intact and
        resumable when the pool cannot cover the chunk — retry after pages
        free, or ``abort_prefill``."""
        if st.finished:
            return 0
        n = min(int(n_tokens), st.remaining)
        if n <= 0:
            return 0
        if self.paged:
            self._prefill_chunks_paged([PrefillChunkItem(st, n)],
                                       raise_oom=True)
        else:
            self._prefill_chunk_dense(st, n)
        return n

    def abort_prefill(self, st: PrefillState):
        """Release a partially-prefilled session (slot, pages, block
        table).  Safe at any progress point: the prefix index only ever
        sees *completed* prompts, so nothing published needs retraction."""
        self.close_session(st.slot)

    def _finish_prefill(self, st: PrefillState, first: int):
        slot = st.slot
        st.first_token = first
        self.fed[slot] = st.total
        self.last_token[slot] = first
        if self.paged:
            if self.share_prefix:
                # register NOW (not at close) so concurrent same-prompt
                # sessions share pages
                self.kv.publish_seq_prefix(slot, st.tokens)
            self.tokens[slot] = [int(t) for t in st.tokens]
            self.stats["prefix_cached_tokens"] += int(st.n_cached)

    def _prefill_chunks_paged(self, chunks, *, raise_oom: bool = False):
        """Execute prefill chunks as rows of ONE ragged ``decode_paged``
        call (the flattened multi-token paged path verification uses — each
        prompt token is its own kernel row with length ``done + t + 1``, so
        chunked and monolithic prefill run the identical per-token
        computation).  Returns per-chunk ``oom`` flags; with ``raise_oom``
        an uncoverable chunk raises instead.  Either way the affected
        state is untouched and resumable."""
        live: list = []
        oom = [False] * len(chunks)
        if self.tiered:
            # co-scheduled chunks must not spill each other mid-staging
            self.kv.tick()
            for c in chunks:
                if c.state.slot in self.kv.tables:
                    self.kv.touch_seq(c.state.slot)
        for i, c in enumerate(chunks):
            st = c.state
            n = min(int(c.n_tokens), st.remaining)
            if n <= 0:
                continue
            try:
                if self.tiered:
                    # a partially-prefilled session parked behind the
                    # admission queue may have been spilled by reclaim;
                    # restore before reserving the chunk's pages
                    self.kv.ensure_resident(st.slot)
                self.kv.ensure_capacity(st.slot, st.done + n)
            except OutOfPages:
                if raise_oom:
                    raise
                oom[i] = True
                continue
            live.append((st, n))
        if not live:
            return oom
        T = _bucket(max(n for _, n in live), 16)
        nb = _bucket(len(live), 1)
        n_max = _bucket(max(self.kv.seq_pages(st.slot) for st, _ in live), 1)
        bufs = self._pool(("prefill", nb, T, n_max), {
            "feed": ((nb, T), np.int32, 0),
            "base": ((nb,), np.int32, 0),
            "tl": ((nb,), np.int32, 0),
            "slots": ((nb,), np.int32, self.max_slots),
            "bt": ((nb, n_max), np.int32, 0),
        })
        # pad rows: zero block table + zero valid length -> their K/V writes
        # land on the scratch page and their logits are discarded (slot
        # sentinel: extras gather clamps, read-only)
        for i, (st, n) in enumerate(live):
            bufs["feed"][i, :n] = st.tokens[st.done : st.done + n]
            bufs["base"][i] = st.done
            bufs["tl"][i] = n
            bufs["slots"][i] = st.slot
        bufs["bt"][: len(live)] = self.kv.block_table(
            [st.slot for st, _ in live], n_max
        )
        bufs["_hw"] = len(live)
        cross = (
            self._extras_gather(bufs["slots"])
            if self.extras_cache is not None else None
        )
        self._count_dispatch("prefill")
        logits, (kp, vp) = self._prefill_paged(
            self.params,
            jnp.asarray(bufs["feed"]),
            self.kv.k_pages,
            self.kv.v_pages,
            jnp.asarray(bufs["bt"]),
            jnp.asarray(bufs["base"]),
            jnp.asarray(bufs["tl"]),
            cross,
        )
        self.kv.k_pages, self.kv.v_pages = kp, vp
        finished: list = []
        for i, (st, n) in enumerate(live):
            st.done += n
            st.chunks += 1
            self.kv.set_len(st.slot, st.done)
            self.stats["prefill_chunks"] += 1
            if st.remaining == 0:
                finished.append((i, n, st))
        if finished:
            # one device-side argmax + ONE transfer for every chunk that
            # completed its prompt this call (was: a blocking
            # int(jnp.argmax(...)) sync per finished row)
            ridx = jnp.asarray([i for i, _, _ in finished], jnp.int32)
            cpos = jnp.asarray([n - 1 for _, n, _ in finished], jnp.int32)
            firsts = np.asarray(
                jax.device_get(jnp.argmax(logits[ridx, cpos], axis=-1))
            )
            for (_, _, st), first in zip(finished, firsts):
                self._finish_prefill(st, int(first))
        return oom

    def _prefill_chunk_dense(self, st: PrefillState, n: int):
        """One dense-backend prefill chunk.  The first chunk goes through
        the bundle's ``prefill`` entry point (builds vlm/audio cross-KV;
        keeps the legacy monolithic path bit-identical when the chunk
        covers the whole prompt); resumed chunks feed the cache at position
        ``done`` through ``decode`` — the same cached-attention path
        verification uses.  Attention targets: bucket the chunk so jit
        compiles a bounded set of programs — padded positions are
        stale-but-masked by the length pointer (and overwritten by the next
        chunk).  Recurrent targets: padding would ADVANCE the stored state
        through garbage tokens; run the exact length."""
        if n <= 0:
            return
        s0 = st.done
        chunk = st.tokens[s0 : s0 + n]
        Tb = n if self.recurrent else _bucket(n, 16)
        padded = np.zeros((1, Tb), np.int32)
        padded[0, :n] = chunk
        sub = self._gather([st.slot])
        if s0 == 0:
            batch = {"tokens": jnp.asarray(padded)}
            if st.extras:
                batch.update(st.extras)
            self._count_dispatch("prefill")
            logits, sub = self._prefill(self.params, batch, sub)
        else:
            self._count_dispatch("prefill")
            logits, sub = self._decode(
                self.params, jnp.asarray(padded), sub, jnp.int32(s0)
            )
        self._scatter([st.slot], sub, 1)
        st.done += n
        st.chunks += 1
        self.stats["prefill_chunks"] += 1
        if st.remaining == 0:
            first = int(jax.device_get(jnp.argmax(logits[0, n - 1])))
            self._finish_prefill(st, first)

    def close_session(self, slot: int):
        if self.paged:
            committed = self.tokens.pop(slot, [])
            n_kv = int(self.fed[slot])
            self.kv.close_seq(
                slot, committed[:n_kv] if self.share_prefix else None
            )
        self.fed[slot] = 0
        self.free_slots.append(slot)

    # -- unified dispatch (mixed verify + prefill) ------------------------------
    def step(self, items: list) -> list:
        """Execute one mixed engine dispatch: the batch the SLO scheduler
        admitted for this epoch, containing any mix of ``VerifyItem`` and
        ``PrefillChunkItem``.

        Contract (docs/ARCHITECTURE.md §2):

          * all verification items run as ONE batched ``verify`` call;
          * all prefill chunks run as rows of ONE ragged paged prefill call
            (dense backend: per-slot passes — no shared pool to batch over);
          * outcomes are returned aligned with ``items``
            (``VerifyOutcome`` / ``PrefillOutcome``);
          * ``OutOfPages`` raised by the *verify* portion propagates before
            any device state is touched (the server degrades to per-item
            steps, DESIGN.md §6);
          * a prefill chunk the pool cannot cover does NOT raise: it comes
            back as ``PrefillOutcome(oom=True, processed=0)`` with its
            state intact — requeue it and retry once pages free.
        """
        vidx = [i for i, it in enumerate(items) if isinstance(it, VerifyItem)]
        cidx = [i for i, it in enumerate(items)
                if isinstance(it, PrefillChunkItem)]
        if len(vidx) + len(cidx) != len(items):
            raise TypeError("step items must be VerifyItem or PrefillChunkItem")
        out: list = [None] * len(items)
        for i, o in zip(vidx, self.verify([items[i] for i in vidx])):
            out[i] = o
        t0 = time.perf_counter()        # the verify wall time is not the chunks'
        if cidx:
            chunks = [items[i] for i in cidx]
            before = [c.state.done for c in chunks]
            if self.paged:
                oom = self._prefill_chunks_paged(chunks)
            else:
                oom = [False] * len(chunks)
                for c in chunks:
                    self._prefill_chunk_dense(
                        c.state, min(int(c.n_tokens), c.state.remaining)
                    )
            dt = time.perf_counter() - t0
            for i, c, was, o in zip(cidx, chunks, before, oom):
                st = c.state
                out[i] = PrefillOutcome(
                    slot=st.slot,
                    processed=st.done - was,
                    done=st.done,
                    total=st.total,
                    first_token=st.first_token,
                    t_chunk=dt,
                    oom=o,
                )
        return out

    # -- batched verification ---------------------------------------------------
    def verify(self, items: list[VerifyItem]) -> list[VerifyOutcome]:
        """One fused dispatch per batch: stage into pooled buffers, run the
        (backend-specific) fused program, read back two (B,) arrays in one
        transfer, commit ``fed``/``last_token`` vectorized."""
        if not items:
            return []
        t0 = time.perf_counter()
        n = len(items)
        dlens = {len(it.draft_tokens) for it in items}
        if len(dlens) > 1:
            self.stats["mixed_k_batches"] += 1
        K = _bucket(max(max(dlens), 1), 2)
        nb = _bucket(n, 1)

        if self.method == "greedy":
            # greedy verification never reads q: nothing is staged at all
            q_kind, C = "none", 0
        elif all(it.q_compact is not None for it in items):
            q_kind = "compact"
            C = max(1, max(it.q_compact.C for it in items))
        else:
            if any(it.q_compact is not None for it in items):
                raise ValueError(
                    "a verify batch must be uniformly dense-q or "
                    "uniformly compact-q"
                )
            q_kind, C = "dense", 0

        bufs = self._stage_verify(nb, K, q_kind, C)
        for i, it in enumerate(items):
            k = len(it.draft_tokens)
            bufs["draft"][i, :k] = it.draft_tokens
            bufs["dlen"][i] = k
            bufs["tl"][i] = k + 1
            bufs["feed"][i, 0] = self.last_token[it.slot]
            bufs["feed"][i, 1 : 1 + k] = it.draft_tokens
            bufs["pos"][i] = self.fed[it.slot]
            bufs["slots"][i] = it.slot
            if q_kind == "dense":
                if it.q_logits is not None and it.q_logits.size:
                    bufs["qlog"][i, :k] = it.q_logits
            elif q_kind == "compact":
                q = it.q_compact
                c = q.C
                bufs["logq_tok"][i, :k] = q.logq_tok
                bufs["top_idx"][i, :k, :c] = q.top_idx
                bufs["top_logq"][i, :k, :c] = q.top_logq
                bufs["tail"][i, :k] = q.tail
        bufs["_hw"] = n

        if self.paged:
            # reserve pages FIRST: OutOfPages must propagate before any
            # engine side effect (rng split, byte counters) so an
            # OOM-requeued batch replays identically and is not
            # double-counted (staging pools alone are reset-on-reuse).
            # With a spill tier, first mark every batch row live (so one
            # row's page-in cannot spill a co-scheduled row), then page
            # spilled rows back in — page-ins that land stay resident
            # across an OOM requeue, so the replay is a no-op for them.
            if self.tiered:
                self.kv.tick()
                for it in items:
                    self.kv.touch_seq(it.slot)
                for it in items:
                    self.kv.ensure_resident(it.slot)
            for it in items:
                self.kv.ensure_capacity(
                    it.slot,
                    int(self.fed[it.slot]) + len(it.draft_tokens) + 1,
                )

        tagged = all(it.rng_tag is not None for it in items)
        if tagged:
            for i, it in enumerate(items):
                bufs["tags"][i] = it.rng_tag
            kv = self._rng_base
        else:
            self.rng, kv = jax.random.split(self.rng)

        qargs = {}
        q_bytes = 0
        if q_kind == "dense":
            qargs["q_logits"] = jnp.asarray(bufs["qlog"])
            q_bytes = bufs["qlog"].nbytes
        elif q_kind == "compact":
            for name in ("logq_tok", "top_idx", "top_logq", "tail"):
                qargs[name] = jnp.asarray(bufs[name])
                q_bytes += bufs[name].nbytes
        core_bytes = (bufs["draft"].nbytes + bufs["dlen"].nbytes
                      + bufs["feed"].nbytes + bufs["pos"].nbytes
                      + bufs["tags"].nbytes)
        self.stats["h2d_bytes"] += core_bytes + q_bytes
        self.stats["h2d_q_bytes"] += q_bytes

        draft_d = jnp.asarray(bufs["draft"])
        dlen_d = jnp.asarray(bufs["dlen"])
        feed_d = jnp.asarray(bufs["feed"])
        tags_d = jnp.asarray(bufs["tags"])

        if self.paged:
            acc_d, tok_d = self._dispatch_verify_paged(
                items, bufs, feed_d, draft_d, dlen_d, kv, tags_d, tagged,
                qargs, n, nb,
            )
        else:
            self._count_dispatch("verify")
            acc_d, tok_d, self.cache = self._fused_verify(
                self.params, self.cache, jnp.asarray(bufs["slots"]),
                feed_d, jnp.asarray(bufs["pos"]), draft_d, dlen_d,
                kv, tags_d, qargs, method=self.method, tagged=tagged,
            )
        # ONE device->host transfer carries the whole epoch's results
        acc, tok = jax.device_get((acc_d, tok_d))
        self.stats["d2h_bytes"] += acc.nbytes + tok.nbytes
        dt = time.perf_counter() - t0

        # batched commit: fed/last_token advance for all rows at once
        sl = bufs["slots"][:n].astype(np.int64)
        accs = np.asarray(acc[:n], np.int64)
        toks = np.asarray(tok[:n], np.int64)
        self.fed[sl] += accs + 1
        self.last_token[sl] = toks

        results = []
        for i, it in enumerate(items):
            L = int(accs[i])
            if self.paged:
                # the accepted prefix (+ re-fed last token) now has live KV;
                # rejected tail K/V is dead — roll back the length pointer
                # and release any now-unreachable tail pages
                self.tokens[it.slot].extend(
                    int(t) for t in bufs["feed"][i, : L + 1]
                )
                self.kv.set_len(it.slot, int(self.fed[it.slot]))
                self.kv.trim_seq(it.slot)
            results.append(
                VerifyOutcome(
                    slot=it.slot,
                    accept_len=L,
                    token=int(toks[i]),
                    emitted=L + 1,
                    t_verify=dt,
                )
            )
        self.stats["batches"] += 1
        self.stats["tokens_verified"] += int(bufs["dlen"][:n].sum())
        self.stats["tokens_committed"] += int(accs.sum()) + n
        return results

    # -- paged-target verification ---------------------------------------------
    def _dispatch_verify_paged(self, items, bufs, feed_d, draft_d, dlen_d,
                               kv, tags_d, tagged, qargs, n, nb):
        """Stage block tables and launch the fused paged program.  Page
        capacity was already reserved by ``verify`` (OutOfPages raises
        there, before any engine side effect)."""
        n_max = _bucket(max(self.kv.seq_pages(it.slot) for it in items), 1)
        btb = self._pool(("bt", nb, n_max), {
            "bt": ((nb, n_max), np.int32, 0),
        })
        btb["bt"][:n] = self.kv.block_table([it.slot for it in items], n_max)
        btb["_hw"] = n
        self.stats["h2d_bytes"] += btb["bt"].nbytes + bufs["tl"].nbytes
        cross = (
            self._extras_gather(bufs["slots"])
            if self.extras_cache is not None else None
        )
        self._count_dispatch("verify")
        acc_d, tok_d, (kp, vp) = self._fused_verify(
            self.params, feed_d, self.kv.k_pages, self.kv.v_pages,
            jnp.asarray(btb["bt"]), jnp.asarray(bufs["pos"]),
            jnp.asarray(bufs["tl"]), cross, draft_d, dlen_d,
            kv, tags_d, qargs, method=self.method, tagged=tagged,
        )
        self.kv.k_pages, self.kv.v_pages = kp, vp
        return acc_d, tok_d
