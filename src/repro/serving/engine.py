"""Verification engine (paper §4.5): batched one-step verification.

Slot model: the engine owns a fixed-capacity cache with ``max_slots`` rows;
sessions map to slots.  A verification batch gathers the selected slots'
cache rows, runs the target model once over ``[x_last, y_1..y_K]`` with
per-row positions (ragged), applies the lossless accept/reject rule, and
scatters the updated rows back.

Two advance strategies, auto-selected per family:
  * attention-family targets (dense/moe/vlm/audio): single ragged pass —
    KV entries past a row's committed length are stale-but-masked, so
    rollback is just the per-slot length pointer;
  * recurrent targets (ssm/hybrid): stepwise verify — per-step states are
    stacked and the state at the accepted length is selected per row
    (recurrent state cannot be truncated; DESIGN.md §5).

Batch shapes are padded to fixed buckets (draft length to k_max, batch to
powers of two) so jit compiles a bounded set of programs.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import speculative_verify
from repro.models import build


def _batch_axis_tree(cache_axes_tree):
    """Map each cache leaf's logical axes -> index of 'act_batch'."""
    return jax.tree.map(
        lambda axes: axes.index("act_batch"),
        cache_axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def _bucket(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class VerifyItem:
    slot: int
    draft_tokens: np.ndarray     # (k,) int32
    q_logits: np.ndarray         # (k, V) float32


@dataclasses.dataclass
class VerifyOutcome:
    slot: int
    accept_len: int
    token: int                   # correction / bonus token
    emitted: int                 # accept_len + 1
    t_verify: float              # engine wall time attributed to the batch


class VerificationEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_len: int,
        method: str = "residual",
        seed: int = 0,
        cache_dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.method = method
        self.cache = self.bundle.init_cache(max_slots, max_len, dtype=cache_dtype) \
            if cfg.family != "ssm" else self.bundle.init_cache(max_slots, max_len)
        self._bax = _batch_axis_tree(self.bundle.cache_axes())
        self.fed = np.zeros(max_slots, np.int64)        # KV-valid tokens/slot
        self.last_token = np.zeros(max_slots, np.int64) # committed[-1]/slot
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.rng = jax.random.PRNGKey(seed)
        self.recurrent = cfg.family in ("ssm", "hybrid")
        self._decode = jax.jit(self.bundle.decode)
        self._prefill = jax.jit(self.bundle.prefill)
        self.stats = {"batches": 0, "tokens_verified": 0, "tokens_committed": 0}

    # -- slot/cache plumbing -------------------------------------------------
    def _gather(self, slots):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda leaf, ax: jnp.take(leaf, idx, axis=ax), self.cache, self._bax
        )

    def _scatter(self, slots, sub, valid_n):
        idx = np.asarray(slots[:valid_n], np.int32)

        def put(leaf, new, ax):
            sl = (slice(None),) * ax
            return leaf.at[sl + (idx,)].set(
                jax.lax.slice_in_dim(new, 0, valid_n, axis=ax).astype(leaf.dtype)
            )

        self.cache = jax.tree.map(put, self.cache, sub, self._bax)

    # -- session lifecycle -----------------------------------------------------
    def new_session(self, prompt_tokens, extras=None) -> tuple[int, int]:
        """Prefill a prompt into a fresh slot.  Returns (slot, first_token).

        The first committed token is sampled from the target's own prefill
        logits (the response's token 0 always comes from the target)."""
        if not self.free_slots:
            raise RuntimeError("no free verification slots")
        slot = self.free_slots.pop()
        toks = np.asarray(prompt_tokens, np.int32)
        P = len(toks)
        # Attention targets: bucket the prompt so jit compiles a bounded
        # set of programs — padded positions are stale-but-masked by the
        # length pointer.  Recurrent targets: padding would ADVANCE the
        # stored state through garbage tokens; run the exact length.
        Pb = P if self.recurrent else _bucket(P, 16)
        padded = np.zeros((1, Pb), np.int32)
        padded[0, :P] = toks
        batch = {"tokens": jnp.asarray(padded)}
        if extras:
            batch.update(extras)
        sub = self._gather([slot])
        logits, sub = self._prefill(self.params, batch, sub)
        self._scatter([slot], sub, 1)
        lg = logits[0, P - 1]
        first = int(jnp.argmax(lg))
        self.fed[slot] = P
        self.last_token[slot] = first
        return slot, first

    def close_session(self, slot: int):
        self.fed[slot] = 0
        self.free_slots.append(slot)

    # -- batched verification ---------------------------------------------------
    def verify(self, items: list[VerifyItem]) -> list[VerifyOutcome]:
        if not items:
            return []
        t0 = time.perf_counter()
        n = len(items)
        K = max(len(it.draft_tokens) for it in items)
        K = _bucket(max(K, 1), 2)
        nb = _bucket(n, 1)
        V = self.cfg.vocab

        draft = np.zeros((nb, K), np.int32)
        qlog = np.full((nb, K, V), -30.0, np.float32)
        dlen = np.zeros(nb, np.int32)
        feed = np.zeros((nb, K + 1), np.int32)
        pos = np.zeros(nb, np.int32)
        slots = [0] * nb
        for i, it in enumerate(items):
            k = len(it.draft_tokens)
            draft[i, :k] = it.draft_tokens
            if it.q_logits.size:
                qlog[i, :k] = it.q_logits
            dlen[i] = k
            feed[i, 0] = self.last_token[it.slot]
            feed[i, 1 : 1 + k] = it.draft_tokens
            pos[i] = self.fed[it.slot]
            slots[i] = it.slot
        # pad rows reuse slot of item 0 read-only (their updates are dropped)
        for i in range(n, nb):
            slots[i] = items[0].slot
            pos[i] = self.fed[items[0].slot]

        sub = self._gather(slots)
        if self.recurrent:
            p_logits, sub = self._verify_stepwise(feed, sub, pos, dlen)
        else:
            p_logits, sub = self._decode(
                self.params, jnp.asarray(feed), sub, jnp.asarray(pos)
            )
        self.rng, kv = jax.random.split(self.rng)
        out = speculative_verify(
            kv,
            jnp.asarray(draft),
            jnp.asarray(dlen),
            jnp.asarray(qlog),
            p_logits,
            method=self.method,
        )
        acc = np.asarray(out["accept_len"])
        tok = np.asarray(out["token"])
        if self.recurrent:
            sub = self._select_states(sub, acc + 1)
        self._scatter(slots, sub, n)
        jax.block_until_ready(self.cache)
        dt = time.perf_counter() - t0

        results = []
        for i, it in enumerate(items):
            L = int(acc[i])
            self.fed[it.slot] += L + 1
            self.last_token[it.slot] = int(tok[i])
            results.append(
                VerifyOutcome(
                    slot=it.slot,
                    accept_len=L,
                    token=int(tok[i]),
                    emitted=L + 1,
                    t_verify=dt,
                )
            )
        self.stats["batches"] += 1
        self.stats["tokens_verified"] += int(dlen[:n].sum())
        self.stats["tokens_committed"] += int(acc[:n].sum()) + n
        return results

    # -- recurrent-target support -------------------------------------------------
    def _verify_stepwise(self, feed, sub, pos, dlen):
        """Step the target one token at a time, stacking per-step states."""
        T = feed.shape[1]
        logits_steps = []
        states = [sub]
        cur = sub
        for t in range(T):
            lg, cur = self._decode(
                self.params, jnp.asarray(feed[:, t : t + 1]), cur,
                jnp.asarray(pos + t),
            )
            logits_steps.append(lg[:, 0])
            states.append(cur)
        p_logits = jnp.stack(logits_steps, axis=1)          # (nb, T, V)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *states)
        return p_logits, stacked

    def _select_states(self, stacked, n_steps):
        """Pick state after step n_steps[b] per row (0 = before any step)."""
        sel = jnp.asarray(n_steps, jnp.int32)

        def pick(leaf, ax):
            # leaf: (T+1, ...) with batch at ax+1
            m = jnp.moveaxis(leaf, ax + 1, 0)               # (B, T+1, ...)
            picked = jnp.take_along_axis(
                m, sel.reshape(-1, *([1] * (m.ndim - 1))), axis=1
            )[:, 0]
            return picked if ax == 0 else jnp.moveaxis(picked, 0, ax)

        return jax.tree.map(pick, stacked, self._bax)
