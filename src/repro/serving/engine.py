"""Verification engine (paper §4.5): batched one-step verification.

Two cache backends, auto-selected per family (DESIGN.md §4):

  * paged (attention families: dense/moe/vlm/audio, full attention) —
    sessions allocate fixed-size pages from a shared `PagedKV` pool via a
    block table; prompt prefill fills pages and registers full pages in the
    content-addressed prefix index so concurrent sessions with a common
    prompt prefix share pages; batched verification runs the target once
    over ``[x_last, y_1..y_K]`` through the paged attention kernel with
    per-row block tables and length pointers.  Accepted-length rollback is
    the length pointer plus releasing now-unreachable tail pages.
    Cross-attention K/V (vlm images, audio encoder memory) is bounded and
    stays in a small dense per-slot side cache; prefix sharing is disabled
    for those families (their self-attn KV is not a pure function of the
    token ids).

  * dense slots (recurrent families: ssm/hybrid, plus windowed-attention
    configs) — the engine owns a fixed-capacity cache with ``max_slots``
    rows; sessions map to slots.  Recurrent targets verify stepwise —
    per-step states are stacked and the state at the accepted length is
    selected per row (recurrent state cannot be truncated; DESIGN.md §5).

Batch shapes are padded to fixed buckets (draft length to k_max, batch to
powers of two) so jit compiles a bounded set of programs.

Prompt prefill is **incremental** (DESIGN.md §8): ``begin_prefill`` opens a
session without running the model, ``prefill_chunk`` advances it by a
bounded number of prompt tokens, and the prompt's first response token is
produced by whichever chunk consumes the final prompt position.
``new_session`` is the run-to-completion wrapper (one whole-prompt chunk —
the legacy monolithic path, bit-for-bit).  ``step`` executes a mixed batch
of verification items and prefill chunks in one engine dispatch, which is
what lets the SLO scheduler interleave cold-prompt prefill with
deadline-critical verification instead of stalling behind it.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import speculative_verify
from repro.models import build, encdec, transformer
from repro.serving.kv_cache import PAGE_SIZE, OutOfPages, PagedKV

#: families whose self-attn KV can be paged; recurrent state cannot.
ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")


class NoFreeSlots(RuntimeError):
    """All ``max_slots`` session rows are occupied (admission-control
    signal, like ``OutOfPages`` for page capacity)."""


def supports_paged(cfg) -> bool:
    """Paged verification needs full (non-windowed) softmax attention —
    the paged kernel addresses history purely through block table +
    length pointer; a sliding-window mask would need per-page offsets."""
    return cfg.family in ATTENTION_FAMILIES and not cfg.sliding_window


def _batch_axis_tree(cache_axes_tree):
    """Map each cache leaf's logical axes -> index of 'act_batch'."""
    return jax.tree.map(
        lambda axes: axes.index("act_batch"),
        cache_axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def _bucket(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class VerifyItem:
    slot: int
    draft_tokens: np.ndarray     # (k,) int32
    q_logits: np.ndarray         # (k, V) float32
    #: optional (a, b) int pair keying this row's accept/correction draws
    #: (serving passes (session_id, committed_len)).  When every item in a
    #: batch carries a tag, verification outcomes become a pure function of
    #: (engine seed, tag, tokens, logits) — independent of batch composition
    #: and dispatch order, so differently-batched drivers commit identical
    #: streams.  Untagged batches keep the legacy split-per-call stream.
    rng_tag: tuple | None = None


@dataclasses.dataclass
class VerifyOutcome:
    slot: int
    accept_len: int
    token: int                   # correction / bonus token
    emitted: int                 # accept_len + 1
    t_verify: float              # engine wall time attributed to the batch


@dataclasses.dataclass
class PrefillState:
    """Resumable prompt-prefill progress for one session slot.

    Created by ``begin_prefill`` (which allocates the slot and, on the
    paged backend, reuses any cached prompt prefix); advanced by
    ``prefill_chunk``.  ``done`` counts prompt tokens whose KV/state is
    valid (including the prefix-cache hit), so ``done`` is the request's
    ``cached_len`` when a chunk is priced by the estimator.  A chunk that
    consumes the final prompt position sets ``first_token`` (the response's
    token 0, sampled greedily from the target's own logits)."""

    slot: int
    tokens: np.ndarray           # full prompt, int32
    done: int                    # prompt tokens with valid KV/state
    extras: dict | None = None   # vlm/audio conditioning (first chunk only)
    first_token: int | None = None
    chunks: int = 0              # chunks executed (observability)
    n_cached: int = 0            # prompt tokens served by the prefix cache

    @property
    def total(self) -> int:
        return len(self.tokens)

    @property
    def remaining(self) -> int:
        return self.total - self.done

    @property
    def finished(self) -> bool:
        return self.first_token is not None


@dataclasses.dataclass
class PrefillChunkItem:
    """One schedulable unit of prompt prefill for ``step``: advance
    ``state`` by up to ``n_tokens`` prompt tokens."""

    state: PrefillState
    n_tokens: int


@dataclasses.dataclass
class PrefillOutcome:
    slot: int
    processed: int               # prompt tokens consumed by this chunk
    done: int                    # total valid prompt tokens after the chunk
    total: int                   # prompt length
    first_token: int | None      # set when the prompt completed this chunk
    t_chunk: float               # engine wall time attributed to the chunk
    oom: bool = False            # chunk deferred: page pool cannot cover it


class VerificationEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_slots: int,
        max_len: int,
        method: str = "residual",
        seed: int = 0,
        cache_dtype=jnp.float32,
        paged: bool | None = None,
        page_size: int = PAGE_SIZE,
        n_pages: int | None = None,
    ):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.method = method
        self.recurrent = cfg.family in ("ssm", "hybrid")
        self.paged = supports_paged(cfg) if paged is None else bool(paged)
        if self.paged and not supports_paged(cfg):
            raise ValueError(
                f"paged verification unsupported for {cfg.name!r} "
                f"(family={cfg.family}, window={cfg.sliding_window})"
            )
        self.fed = np.zeros(max_slots, np.int64)        # KV-valid tokens/slot
        self.last_token = np.zeros(max_slots, np.int64) # committed[-1]/slot
        self.free_slots = list(range(max_slots - 1, -1, -1))
        self.rng = jax.random.PRNGKey(seed)
        #: never advanced: base for rng_tag-keyed (deterministic) verification
        self._rng_base = jax.random.PRNGKey(seed)
        #: ``prefix_cached_tokens`` counts prompt tokens satisfied by the
        #: content-addressed prefix cache.  That cache exists only on the
        #: paged backend — on the dense backend the field is structurally
        #: zero (no prefix cache, nothing to hit), not "zero hits observed";
        #: check ``stats["backend"]`` / ``prefix_cache_stats()["backend"]``
        #: before reading it as a hit rate (DESIGN.md §3).
        self.stats = {
            "backend": "paged" if self.paged else "dense",
            "batches": 0,
            "tokens_verified": 0,
            "tokens_committed": 0,
            "prefix_cached_tokens": 0,
            "prefill_chunks": 0,
        }

        if self.paged:
            self._init_paged(cache_dtype, page_size, n_pages)
        else:
            self.cache = self.bundle.init_cache(max_slots, max_len, dtype=cache_dtype) \
                if cfg.family != "ssm" else self.bundle.init_cache(max_slots, max_len)
            self._bax = _batch_axis_tree(self.bundle.cache_axes())
            self._decode = jax.jit(self.bundle.decode)
            self._prefill = jax.jit(self.bundle.prefill)

    # -- paged backend setup --------------------------------------------------
    def _init_paged(self, cache_dtype, page_size, n_pages):
        cfg = self.cfg
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        if n_pages is None:
            # every slot must be able to reach max_len even with per-slot
            # page rounding, + the reserved scratch page
            n_pages = self.max_slots * -(-self.max_len // page_size) + 1
        self.page_size = page_size
        self.kv = PagedKV(
            cfg.n_layers, n_pages, hkv, hd,
            page_size=page_size, dtype=cache_dtype,
        )
        #: prefix sharing is sound only when KV is a pure function of the
        #: token ids — cross-attention families condition on extras.
        self.share_prefix = cfg.family in ("dense", "moe")
        self.tokens: dict[int, list] = {}   # slot -> tokens with KV in pages
        self.extras_cache = None
        # donate the page pool (args 2/3 after params, tokens) so XLA
        # updates pages in place instead of copying the whole pool (and
        # transiently doubling KV memory) every call; CPU ignores it
        _jit = partial(jax.jit, donate_argnums=(2, 3))
        if cfg.family == "vlm":
            n_groups = cfg.n_layers // cfg.cross_attn_every
            z = lambda: jnp.zeros(
                (n_groups, self.max_slots, cfg.num_image_tokens, hkv, hd),
                cache_dtype,
            )
            self.extras_cache = {"k_img": z(), "v_img": z()}
            self._extras_key = "image_embeds"
            self._extras_builder = jax.jit(partial(transformer.vlm_cross_kv, cfg))
            self._decode_paged = _jit(partial(transformer.decode_paged, cfg))
            self._prefill_paged = _jit(
                partial(transformer.decode_paged, cfg, dropless=False)
            )
        elif cfg.family == "audio":
            z = lambda: jnp.zeros(
                (cfg.n_layers, self.max_slots, cfg.encoder_frames, hkv, hd),
                cache_dtype,
            )
            self.extras_cache = {"k_mem": z(), "v_mem": z()}
            self._extras_key = "frames"
            self._extras_builder = jax.jit(partial(encdec.encdec_cross_kv, cfg))
            self._decode_paged = _jit(partial(encdec.encdec_decode_paged, cfg))
            self._prefill_paged = self._decode_paged     # no MoE routing
        else:
            self._decode_paged = _jit(partial(transformer.decode_paged, cfg))
            # prompt prefill keeps GShard capacity MoE routing, matching
            # the dense `prefill` path (verify stays dropless)
            self._prefill_paged = _jit(
                partial(transformer.decode_paged, cfg, dropless=False)
            )

    # -- slot/cache plumbing (dense backend) ----------------------------------
    def _gather(self, slots):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda leaf, ax: jnp.take(leaf, idx, axis=ax), self.cache, self._bax
        )

    def _scatter(self, slots, sub, valid_n):
        idx = np.asarray(slots[:valid_n], np.int32)

        def put(leaf, new, ax):
            sl = (slice(None),) * ax
            return leaf.at[sl + (idx,)].set(
                jax.lax.slice_in_dim(new, 0, valid_n, axis=ax).astype(leaf.dtype)
            )

        self.cache = jax.tree.map(put, self.cache, sub, self._bax)

    # -- extras side cache (paged vlm/audio: batch axis is 1) -----------------
    def _extras_gather(self, slots):
        idx = jnp.asarray(slots, jnp.int32)
        return jax.tree.map(
            lambda leaf: jnp.take(leaf, idx, axis=1), self.extras_cache
        )

    def _extras_put(self, slot, sub):
        self.extras_cache = jax.tree.map(
            lambda leaf, new: leaf.at[:, slot].set(new[:, 0].astype(leaf.dtype)),
            self.extras_cache, sub,
        )

    # -- memory accounting ----------------------------------------------------
    def memory_budget_tokens(self) -> int:
        """KV-token capacity the scheduler may admit against this epoch.

        A scheduled request accounts ``cached_len + new_tokens``; its
        cached tokens are covered by its session's resident pages and its
        new tokens must fit in its own tail-page slack or in pages the
        allocator can still hand out (free + evictable prefix-cached).  So
        the live budget is ``resident_capacity + free`` — counting the
        slack inside sequences' tail pages matters: with large pages and
        short sessions most capacity *is* tail slack, and a budget of only
        committed+free livelocks a full pool even though every request
        fits (single-slot engines hit this immediately).  The budget
        tightens as rejected-draft garbage accumulates and widens when
        sessions close or tail pages are trimmed.  The dense backend's
        capacity is static."""
        if self.paged:
            return self.kv.free_tokens + self.kv.resident_tokens()
        return self.max_slots * self.max_len

    def prefix_cache_stats(self) -> dict:
        """Prefix-cache / page-pool counters, tagged with the backend that
        produced them.  The prefix cache is a paged-backend structure; the
        dense backend reports ``backend="dense"`` with zero counters —
        structurally zero (the cache does not exist), not a measured 0%
        hit rate.  Callers comparing backends must branch on ``backend``
        instead of treating the zeros as data (DESIGN.md §3)."""
        if self.paged:
            a = self.kv.allocator
            return {"backend": "paged", "hits": a.hits, "misses": a.misses,
                    "pages_in_use": a.in_use, "pages_free": len(a.free)}
        return {"backend": "dense", "hits": 0, "misses": 0,
                "pages_in_use": 0, "pages_free": 0}

    # -- session lifecycle -----------------------------------------------------
    def new_session(self, prompt_tokens, extras=None) -> tuple[int, int]:
        """Prefill a prompt into a fresh slot.  Returns (slot, first_token).

        Monolithic wrapper over the incremental path: one whole-prompt
        chunk, so behavior (including jit bucketing) is identical to the
        legacy blocking prefill.  The first committed token is sampled from
        the target's own prefill logits (the response's token 0 always
        comes from the target).  Paged backend: raises ``OutOfPages`` (with
        the slot and any partial pages returned) when the pool cannot cover
        the prompt."""
        st = self.begin_prefill(prompt_tokens, extras=extras)
        try:
            while not st.finished:
                self.prefill_chunk(st, st.remaining)
        except OutOfPages:
            self.abort_prefill(st)
            raise
        return st.slot, st.first_token

    def begin_prefill(self, prompt_tokens, extras=None) -> PrefillState:
        """Open a session slot for incremental prompt prefill WITHOUT
        running the model.  Paged backend: allocates the block table and
        reuses any content-addressed cached prefix (``state.done`` starts
        at the prefix hit) and builds the bounded cross-attention side
        cache for vlm/audio extras.  Raises ``NoFreeSlots`` /
        ``OutOfPages`` with nothing leaked (admission-control signals)."""
        if not self.free_slots:
            raise NoFreeSlots("no free verification slots")
        toks = np.asarray(prompt_tokens, np.int32)
        slot = self.free_slots.pop()
        if not self.paged:
            return PrefillState(slot=slot, tokens=toks, done=0, extras=extras)
        try:
            n_cached = self.kv.open_seq(slot, toks, share=self.share_prefix)
        except OutOfPages:
            if slot in self.kv.tables:
                self.kv.close_seq(slot)
            self.free_slots.append(slot)
            raise
        if self.extras_cache is not None:
            k_x, v_x = self._extras_builder(
                self.params, jnp.asarray(extras[self._extras_key])
            )
            keys = sorted(self.extras_cache)          # (k_img, v_img) / (k_mem, v_mem)
            self._extras_put(slot, {keys[0]: k_x, keys[1]: v_x})
        return PrefillState(slot=slot, tokens=toks, done=n_cached,
                            extras=extras, n_cached=n_cached)

    def prefill_chunk(self, st: PrefillState, n_tokens: int) -> int:
        """Advance ``st`` by up to ``n_tokens`` prompt tokens in one forward
        pass; returns the tokens consumed.  The chunk that consumes the
        final prompt position samples the first response token and (paged,
        sharing families) publishes the prompt's full pages to the prefix
        index.  Paged backend: raises ``OutOfPages`` with ``st`` intact and
        resumable when the pool cannot cover the chunk — retry after pages
        free, or ``abort_prefill``."""
        if st.finished:
            return 0
        n = min(int(n_tokens), st.remaining)
        if n <= 0:
            return 0
        if self.paged:
            self._prefill_chunks_paged([PrefillChunkItem(st, n)],
                                       raise_oom=True)
        else:
            self._prefill_chunk_dense(st, n)
        return n

    def abort_prefill(self, st: PrefillState):
        """Release a partially-prefilled session (slot, pages, block
        table).  Safe at any progress point: the prefix index only ever
        sees *completed* prompts, so nothing published needs retraction."""
        self.close_session(st.slot)

    def _finish_prefill(self, st: PrefillState, first: int):
        slot = st.slot
        st.first_token = first
        self.fed[slot] = st.total
        self.last_token[slot] = first
        if self.paged:
            if self.share_prefix:
                # register NOW (not at close) so concurrent same-prompt
                # sessions share pages
                self.kv.publish_seq_prefix(slot, st.tokens)
            self.tokens[slot] = [int(t) for t in st.tokens]
            self.stats["prefix_cached_tokens"] += int(st.n_cached)

    def _prefill_chunks_paged(self, chunks, *, raise_oom: bool = False):
        """Execute prefill chunks as rows of ONE ragged ``decode_paged``
        call (the flattened multi-token paged path verification uses — each
        prompt token is its own kernel row with length ``done + t + 1``, so
        chunked and monolithic prefill run the identical per-token
        computation).  Returns per-chunk ``oom`` flags; with ``raise_oom``
        an uncoverable chunk raises instead.  Either way the affected
        state is untouched and resumable."""
        live: list = []
        oom = [False] * len(chunks)
        for i, c in enumerate(chunks):
            st = c.state
            n = min(int(c.n_tokens), st.remaining)
            if n <= 0:
                continue
            try:
                self.kv.ensure_capacity(st.slot, st.done + n)
            except OutOfPages:
                if raise_oom:
                    raise
                oom[i] = True
                continue
            live.append((st, n))
        if not live:
            return oom
        T = _bucket(max(n for _, n in live), 16)
        nb = _bucket(len(live), 1)
        feed = np.zeros((nb, T), np.int32)
        base = np.zeros(nb, np.int32)
        tl = np.zeros(nb, np.int32)
        # pad rows: zero block table + zero valid length -> their K/V writes
        # land on the scratch page and their logits are discarded
        slots = [live[0][0].slot] * nb
        for i, (st, n) in enumerate(live):
            feed[i, :n] = st.tokens[st.done : st.done + n]
            base[i] = st.done
            tl[i] = n
            slots[i] = st.slot
        n_max = _bucket(max(self.kv.seq_pages(st.slot) for st, _ in live), 1)
        bt = np.zeros((nb, n_max), np.int32)
        bt[: len(live)] = self.kv.block_table([st.slot for st, _ in live], n_max)
        cross = self._extras_gather(slots) if self.extras_cache is not None else None
        logits, (kp, vp) = self._prefill_paged(
            self.params,
            jnp.asarray(feed),
            self.kv.k_pages,
            self.kv.v_pages,
            jnp.asarray(bt),
            jnp.asarray(base),
            jnp.asarray(tl),
            cross,
        )
        self.kv.k_pages, self.kv.v_pages = kp, vp
        for i, (st, n) in enumerate(live):
            st.done += n
            st.chunks += 1
            self.kv.set_len(st.slot, st.done)
            self.stats["prefill_chunks"] += 1
            if st.remaining == 0:
                self._finish_prefill(st, int(jnp.argmax(logits[i, n - 1])))
        return oom

    def _prefill_chunk_dense(self, st: PrefillState, n: int):
        """One dense-backend prefill chunk.  The first chunk goes through
        the bundle's ``prefill`` entry point (builds vlm/audio cross-KV;
        keeps the legacy monolithic path bit-identical when the chunk
        covers the whole prompt); resumed chunks feed the cache at position
        ``done`` through ``decode`` — the same cached-attention path
        verification uses.  Attention targets: bucket the chunk so jit
        compiles a bounded set of programs — padded positions are
        stale-but-masked by the length pointer (and overwritten by the next
        chunk).  Recurrent targets: padding would ADVANCE the stored state
        through garbage tokens; run the exact length."""
        if n <= 0:
            return
        s0 = st.done
        chunk = st.tokens[s0 : s0 + n]
        Tb = n if self.recurrent else _bucket(n, 16)
        padded = np.zeros((1, Tb), np.int32)
        padded[0, :n] = chunk
        sub = self._gather([st.slot])
        if s0 == 0:
            batch = {"tokens": jnp.asarray(padded)}
            if st.extras:
                batch.update(st.extras)
            logits, sub = self._prefill(self.params, batch, sub)
        else:
            logits, sub = self._decode(
                self.params, jnp.asarray(padded), sub, jnp.int32(s0)
            )
        self._scatter([st.slot], sub, 1)
        st.done += n
        st.chunks += 1
        self.stats["prefill_chunks"] += 1
        if st.remaining == 0:
            self._finish_prefill(st, int(jnp.argmax(logits[0, n - 1])))

    def close_session(self, slot: int):
        if self.paged:
            committed = self.tokens.pop(slot, [])
            n_kv = int(self.fed[slot])
            self.kv.close_seq(
                slot, committed[:n_kv] if self.share_prefix else None
            )
        self.fed[slot] = 0
        self.free_slots.append(slot)

    # -- unified dispatch (mixed verify + prefill) ------------------------------
    def step(self, items: list) -> list:
        """Execute one mixed engine dispatch: the batch the SLO scheduler
        admitted for this epoch, containing any mix of ``VerifyItem`` and
        ``PrefillChunkItem``.

        Contract (docs/ARCHITECTURE.md §2):

          * all verification items run as ONE batched ``verify`` call;
          * all prefill chunks run as rows of ONE ragged paged prefill call
            (dense backend: per-slot passes — no shared pool to batch over);
          * outcomes are returned aligned with ``items``
            (``VerifyOutcome`` / ``PrefillOutcome``);
          * ``OutOfPages`` raised by the *verify* portion propagates before
            any device state is touched (the server degrades to per-item
            steps, DESIGN.md §6);
          * a prefill chunk the pool cannot cover does NOT raise: it comes
            back as ``PrefillOutcome(oom=True, processed=0)`` with its
            state intact — requeue it and retry once pages free.
        """
        vidx = [i for i, it in enumerate(items) if isinstance(it, VerifyItem)]
        cidx = [i for i, it in enumerate(items)
                if isinstance(it, PrefillChunkItem)]
        if len(vidx) + len(cidx) != len(items):
            raise TypeError("step items must be VerifyItem or PrefillChunkItem")
        out: list = [None] * len(items)
        for i, o in zip(vidx, self.verify([items[i] for i in vidx])):
            out[i] = o
        t0 = time.perf_counter()        # the verify wall time is not the chunks'
        if cidx:
            chunks = [items[i] for i in cidx]
            before = [c.state.done for c in chunks]
            if self.paged:
                oom = self._prefill_chunks_paged(chunks)
            else:
                oom = [False] * len(chunks)
                for c in chunks:
                    self._prefill_chunk_dense(
                        c.state, min(int(c.n_tokens), c.state.remaining)
                    )
            dt = time.perf_counter() - t0
            for i, c, was, o in zip(cidx, chunks, before, oom):
                st = c.state
                out[i] = PrefillOutcome(
                    slot=st.slot,
                    processed=st.done - was,
                    done=st.done,
                    total=st.total,
                    first_token=st.first_token,
                    t_chunk=dt,
                    oom=o,
                )
        return out

    # -- batched verification ---------------------------------------------------
    def verify(self, items: list[VerifyItem]) -> list[VerifyOutcome]:
        if not items:
            return []
        t0 = time.perf_counter()
        n = len(items)
        K = max(len(it.draft_tokens) for it in items)
        K = _bucket(max(K, 1), 2)
        nb = _bucket(n, 1)
        V = self.cfg.vocab

        draft = np.zeros((nb, K), np.int32)
        qlog = np.full((nb, K, V), -30.0, np.float32)
        dlen = np.zeros(nb, np.int32)
        feed = np.zeros((nb, K + 1), np.int32)
        pos = np.zeros(nb, np.int32)
        slots = [0] * nb
        for i, it in enumerate(items):
            k = len(it.draft_tokens)
            draft[i, :k] = it.draft_tokens
            if it.q_logits.size:
                qlog[i, :k] = it.q_logits
            dlen[i] = k
            feed[i, 0] = self.last_token[it.slot]
            feed[i, 1 : 1 + k] = it.draft_tokens
            pos[i] = self.fed[it.slot]
            slots[i] = it.slot
        # pad rows reuse slot of item 0 read-only (their updates are dropped;
        # the paged path additionally zeroes their block table + lengths so
        # their K/V writes land on the scratch page)
        for i in range(n, nb):
            slots[i] = items[0].slot
            pos[i] = self.fed[items[0].slot]

        if self.paged:
            p_logits = self._verify_paged(items, feed, slots, n, nb)
        else:
            sub = self._gather(slots)
            if self.recurrent:
                p_logits, sub = self._verify_stepwise(feed, sub, pos, dlen)
            else:
                p_logits, sub = self._decode(
                    self.params, jnp.asarray(feed), sub, jnp.asarray(pos)
                )
        tags = None
        if all(it.rng_tag is not None for it in items):
            tags = np.zeros((nb, 2), np.int32)   # pad rows: discarded anyway
            for i, it in enumerate(items):
                tags[i] = it.rng_tag
        if tags is None:
            self.rng, kv = jax.random.split(self.rng)
        else:
            kv = self._rng_base
        out = speculative_verify(
            kv,
            jnp.asarray(draft),
            jnp.asarray(dlen),
            jnp.asarray(qlog),
            p_logits,
            method=self.method,
            rng_tags=None if tags is None else jnp.asarray(tags),
        )
        acc = np.asarray(out["accept_len"])
        tok = np.asarray(out["token"])
        if self.paged:
            jax.block_until_ready(self.kv.k_pages)
        else:
            if self.recurrent:
                sub = self._select_states(sub, acc + 1)
            self._scatter(slots, sub, n)
            jax.block_until_ready(self.cache)
        dt = time.perf_counter() - t0

        results = []
        for i, it in enumerate(items):
            L = int(acc[i])
            self.fed[it.slot] += L + 1
            self.last_token[it.slot] = int(tok[i])
            if self.paged:
                # the accepted prefix (+ re-fed last token) now has live KV;
                # rejected tail K/V is dead — roll back the length pointer
                # and release any now-unreachable tail pages
                self.tokens[it.slot].extend(int(t) for t in feed[i, : L + 1])
                self.kv.set_len(it.slot, int(self.fed[it.slot]))
                self.kv.trim_seq(it.slot)
            results.append(
                VerifyOutcome(
                    slot=it.slot,
                    accept_len=L,
                    token=int(tok[i]),
                    emitted=L + 1,
                    t_verify=dt,
                )
            )
        self.stats["batches"] += 1
        self.stats["tokens_verified"] += int(dlen[:n].sum())
        self.stats["tokens_committed"] += int(acc[:n].sum()) + n
        return results

    # -- paged-target verification ---------------------------------------------
    def _verify_paged(self, items, feed, slots, n, nb):
        """One ragged pass over ``[x_last, y_1..y_K]`` per row through the
        paged attention kernel.  May raise ``OutOfPages`` before any device
        state is touched (the server requeues the batch)."""
        T = feed.shape[1]
        base = np.zeros(nb, np.int32)
        tl = np.zeros(nb, np.int32)
        for i, it in enumerate(items):
            k = len(it.draft_tokens)
            base[i] = self.fed[it.slot]
            tl[i] = k + 1
            self.kv.ensure_capacity(it.slot, int(self.fed[it.slot]) + k + 1)
        n_max = _bucket(max(self.kv.seq_pages(it.slot) for it in items), 1)
        bt = np.zeros((nb, n_max), np.int32)
        bt[:n] = self.kv.block_table([it.slot for it in items], n_max)
        cross = (
            self._extras_gather(slots) if self.extras_cache is not None else None
        )
        logits, (kp, vp) = self._decode_paged(
            self.params,
            jnp.asarray(feed),
            self.kv.k_pages,
            self.kv.v_pages,
            jnp.asarray(bt),
            jnp.asarray(base),
            jnp.asarray(tl),
            cross,
        )
        self.kv.k_pages, self.kv.v_pages = kp, vp
        return logits

    # -- recurrent-target support -------------------------------------------------
    def _verify_stepwise(self, feed, sub, pos, dlen):
        """Step the target one token at a time, stacking per-step states."""
        T = feed.shape[1]
        logits_steps = []
        states = [sub]
        cur = sub
        for t in range(T):
            lg, cur = self._decode(
                self.params, jnp.asarray(feed[:, t : t + 1]), cur,
                jnp.asarray(pos + t),
            )
            logits_steps.append(lg[:, 0])
            states.append(cur)
        p_logits = jnp.stack(logits_steps, axis=1)          # (nb, T, V)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *states)
        return p_logits, stacked

    def _select_states(self, stacked, n_steps):
        """Pick state after step n_steps[b] per row (0 = before any step)."""
        sel = jnp.asarray(n_steps, jnp.int32)

        def pick(leaf, ax):
            # leaf: (T+1, ...) with batch at ax+1
            m = jnp.moveaxis(leaf, ax + 1, 0)               # (B, T+1, ...)
            picked = jnp.take_along_axis(
                m, sel.reshape(-1, *([1] * (m.ndim - 1))), axis=1
            )[:, 0]
            return picked if ax == 0 else jnp.moveaxis(picked, 0, ax)

        return jax.tree.map(pick, stacked, self._bax)
