"""Serving runtime: verification engine, paged KV + prefix cache, server,
edge client, simulated transport."""
from repro.serving.engine import (
    NoFreeSlots,
    PrefillChunkItem,
    PrefillOutcome,
    PrefillState,
    VerificationEngine,
    VerifyItem,
    VerifyOutcome,
    supports_paged,
)
from repro.serving.kv_cache import PagedKV, PageAllocator, SeqPages, OutOfPages, PAGE_SIZE
from repro.serving.client import EdgeDevice, EdgeSession
from repro.serving.events import (
    Admitted,
    Closed,
    FirstToken,
    Preempted,
    ServerEvent,
    SessionHandle,
    TTFTRecord,
    VerdictEvent,
)
from repro.serving.server import (
    DEFAULT_SLO_CLASSES,
    DEFAULT_TTFT_SLO,
    AdmissionQueue,
    PrefillRecord,
    ServerSession,
    Verdict,
    WISPServer,
)
from repro.serving.transport import NetworkModel

__all__ = [
    "Admitted",
    "Closed",
    "FirstToken",
    "Preempted",
    "ServerEvent",
    "SessionHandle",
    "TTFTRecord",
    "VerdictEvent",
    "AdmissionQueue",
    "PrefillRecord",
    "DEFAULT_TTFT_SLO",
    "NoFreeSlots",
    "PrefillChunkItem",
    "PrefillOutcome",
    "PrefillState",
    "VerificationEngine",
    "VerifyItem",
    "VerifyOutcome",
    "supports_paged",
    "PagedKV",
    "PageAllocator",
    "SeqPages",
    "OutOfPages",
    "PAGE_SIZE",
    "EdgeDevice",
    "EdgeSession",
    "WISPServer",
    "Verdict",
    "ServerSession",
    "DEFAULT_SLO_CLASSES",
    "NetworkModel",
]
