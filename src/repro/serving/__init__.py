"""Serving runtime: verification engine, paged KV + prefix cache, server,
edge client, simulated transport."""
from repro.serving.engine import (
    NoFreeSlots,
    PrefillChunkItem,
    PrefillOutcome,
    PrefillState,
    VerificationEngine,
    VerifyItem,
    VerifyOutcome,
    supports_paged,
)
from repro.serving.kv_cache import PagedKV, PageAllocator, SeqPages, OutOfPages, PAGE_SIZE
from repro.serving.client import EdgeDevice, EdgeSession
from repro.serving.server import WISPServer, Verdict, ServerSession, DEFAULT_SLO_CLASSES
from repro.serving.transport import NetworkModel

__all__ = [
    "NoFreeSlots",
    "PrefillChunkItem",
    "PrefillOutcome",
    "PrefillState",
    "VerificationEngine",
    "VerifyItem",
    "VerifyOutcome",
    "supports_paged",
    "PagedKV",
    "PageAllocator",
    "SeqPages",
    "OutOfPages",
    "PAGE_SIZE",
    "EdgeDevice",
    "EdgeSession",
    "WISPServer",
    "Verdict",
    "ServerSession",
    "DEFAULT_SLO_CLASSES",
    "NetworkModel",
]
