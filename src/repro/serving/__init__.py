"""Serving runtime: verification engine, paged KV + prefix cache, server,
edge client, simulated transport."""
from repro.serving.engine import VerificationEngine, VerifyItem, VerifyOutcome
from repro.serving.kv_cache import PagedKV, PageAllocator, SeqPages, OutOfPages, PAGE_SIZE
from repro.serving.client import EdgeDevice, EdgeSession
from repro.serving.server import WISPServer, Verdict, ServerSession, DEFAULT_SLO_CLASSES
from repro.serving.transport import NetworkModel

__all__ = [
    "VerificationEngine",
    "VerifyItem",
    "VerifyOutcome",
    "PagedKV",
    "PageAllocator",
    "SeqPages",
    "OutOfPages",
    "PAGE_SIZE",
    "EdgeDevice",
    "EdgeSession",
    "WISPServer",
    "Verdict",
    "ServerSession",
    "DEFAULT_SLO_CLASSES",
    "NetworkModel",
]
