"""WISP verification server: queues + pluggable scheduling policy + engine.

The coordinator keeps per-session state (slot, committed tokens, EWMA
acceptance estimate), maintains the pending-work pool, and at each
dispatch epoch runs the selected `SchedulingPolicy` (``"wisp"`` =
Algorithm 1; ``"fcfs"`` / ``"edf"`` / ``"priority"`` baselines — see
`repro.core.scheduler`) to build a batch, executes it on the
verification engine, and publishes the outcomes.

**Every outcome flows through one ordered event stream** (docs/API.md):
``open_session`` returns a `SessionHandle`; admissions, first tokens,
verify verdicts, preemptions, TTFT records and closes surface as typed
`ServerEvent`s drained with ``pop_events()``.  The legacy channels —
``pop_admissions()`` polling, the ``step()`` verdict return list, the
``prefill_log`` side-car — still work as thin deprecation shims and
carry byte-identical results (tests/test_policies.py).

Prompt prefill runs in one of two modes (DESIGN.md §8):

  * ``prefill="monolithic"`` (default) — ``open_session`` runs the whole
    prompt as one blocking engine call; the handle is ``active`` with its
    ``first_token`` set on return (the legacy path; simple drivers and
    the lock-step reference need it);
  * ``prefill="chunked"`` — ``open_session`` only *admits* the session
    (allocating its slot/pages) and returns a ``prefilling`` handle; the
    prompt is split into fixed-budget chunks that enter the pending pool
    as `PrefillChunkWork` items with the session's TTFT deadline and
    compete with verification under the scheduling policy.  The first
    token surfaces as a ``FIRST_TOKEN`` event when the final chunk lands.

This is the *functional* server used by examples and integration tests
(driven synchronously, CPU).  Paper-scale capacity/goodput numbers come
from `repro.sim`, which replays the same policies against the analytic
latency model at thousands of devices.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque

import numpy as np

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    PrefillChunkWork,
    SchedulerConfig,
    VerifyWork,
    make_policy,
)
from repro.serving.engine import NoFreeSlots, VerificationEngine
from repro.serving.events import (
    Admitted,
    Closed,
    FirstToken,
    Preempted,
    Rejected,
    ServerEvent,
    SessionHandle,
    Throttled,
    TTFTRecord,
    VerdictEvent,
)
from repro.serving.kv_cache import OutOfPages
from repro.serving.transport import NetworkModel
from repro.tenancy import DEFAULT_TENANT, Stage, TenantRegistry

#: paper §5.1: four token-speed SLO classes (tokens/s)
DEFAULT_SLO_CLASSES = {1: 8.0, 2: 6.0, 3: 4.0, 4: 2.0}

#: TTFT (time-to-first-token) budgets per SLO class, seconds — the
#: deadline chunked prefill schedules against (DESIGN.md §8).  Scaled like
#: the token-speed classes: a class promising 8 tok/s streaming also
#: promises a snappier first token than the 2 tok/s tier.
DEFAULT_TTFT_SLO = {1: 0.75, 2: 1.5, 3: 3.0, 4: 6.0}


@dataclasses.dataclass
class ServerSession:
    session_id: int
    slot: int
    slo_class: int
    committed_len: int
    alpha: float = 0.6           # EWMA acceptance-rate estimate
    rounds: int = 0
    draft_speed: float = 50.0
    t_draft_last: float = 0.0
    t_net_last: float = 0.0
    #: the edge speculation controller's last-submitted draft-length cap
    #: (DESIGN.md §11) — server-side observability, and carried through
    #: fleet migration so a restored session's adaptive-K context (like
    #: its ``alpha``) survives verifier death
    spec_k: int = 0
    #: owning tenant (DESIGN.md §13) — stamped onto every work item the
    #: session submits so the ``"wfq"`` policy can bucket virtual time
    tenant: str = DEFAULT_TENANT


@dataclasses.dataclass
class PrefillingSession:
    """A session whose prompt is still being chunk-prefilled: admitted to
    the engine (slot + pages held, ``state`` resumable) but not yet
    streaming.  Exactly one chunk of it is in the pending pool at a time —
    chunk *i+1* depends on chunk *i*'s KV."""

    session_id: int
    state: object                # engine PrefillState
    slo_class: int
    draft_speed: float
    t_request: float             # when the client asked (TTFT clock start)
    deadline: float              # TTFT deadline = t_request + ttft_slo[class]
    tenant: str = DEFAULT_TENANT
    #: the tenant's rate limiter borrowed from the debt band for this
    #: open — the session's prefill chunks run at reduced WFQ weight
    deprioritized: bool = False


@dataclasses.dataclass
class PrefillRecord:
    """One completed chunked prefill (the TTFT observability unit)."""

    session_id: int
    prompt_len: int
    chunks: int
    t_request: float
    t_first: float               # when the final chunk's epoch completed
    deadline: float
    violated: bool

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_request


@dataclasses.dataclass
class Verdict:
    session_id: int
    accept_len: int
    token: int
    emitted: int
    t_queue: float
    t_verify: float
    deadline: float
    violated: bool
    #: which verify round of the session this verdict resolves — the
    #: second half of the fleet's idempotency key (session_id, round_index)
    #: for hedged re-dispatch (repro.fleet); -1 on legacy paths
    round_index: int = -1
    #: the verifier's pending-pool depth when this verdict committed —
    #: piggybacked load feedback the edge speculation controller's
    #: congestion brake consumes (DESIGN.md §11); no extra round trip
    queue_depth: int = 0


class AdmissionQueue:
    """FIFO admission-retry queue with O(1) pops and O(1) cancellation.

    This queue is on the per-epoch hot path under churn (``_try_admit``
    runs every dispatch epoch and every close): a plain list cost O(n)
    per admission (``pop(0)``) and a full rebuild per cancellation.  Here
    admissions pop from a `deque` and cancellation just tombstones the
    entry — dead entries are skipped (and dropped) when the FIFO scan
    reaches them.  ``len`` / iteration / membership see only live
    entries.  Entries are tuples whose first element is the session id.

    Tombstones are keyed by a per-push unique token, NOT the session id:
    session ids may be reused (close a queued session, open a new one
    under the same id), and an id-keyed tombstone for the old entry
    would otherwise cancel — or, absorbed into a set, fail to cancel —
    the new one (ghost admission of a closed session)."""

    def __init__(self):
        self._q: deque = deque()            # (token, entry)
        self._dead: set[int] = set()        # cancelled tokens
        self._live: dict[int, int] = {}     # session id -> token
        self._next_token = 0

    def push(self, entry: tuple) -> None:
        sid = entry[0]
        old = self._live.pop(sid, None)
        if old is not None:                 # re-queue supersedes the old entry
            self._dead.add(old)
        self._next_token += 1
        self._q.append((self._next_token, entry))
        self._live[sid] = self._next_token

    def _drop_dead_prefix(self) -> None:
        while self._q and self._q[0][0] in self._dead:
            self._dead.discard(self._q.popleft()[0])

    def peek(self) -> tuple | None:
        """The oldest live entry (or None) — does not remove it."""
        self._drop_dead_prefix()
        return self._q[0][1] if self._q else None

    def popleft(self) -> tuple:
        self._drop_dead_prefix()
        token, entry = self._q.popleft()
        self._live.pop(entry[0], None)
        return entry

    def cancel(self, session_id: int) -> bool:
        """Tombstone a queued session; False when it is not queued."""
        token = self._live.pop(session_id, None)
        if token is None:
            return False
        self._dead.add(token)
        return True

    def resort(self, key) -> None:
        """Re-establish FIFO order after an out-of-order push (preemption
        re-queues a session with its *original* request time).  Rare path:
        O(n log n) is fine here; the hot path stays O(1)."""
        self._q = deque(sorted(
            ((t, e) for t, e in self._q if t not in self._dead),
            key=lambda te: key(te[1]),
        ))
        self._dead.clear()

    def __len__(self) -> int:
        return len(self._live)

    def __bool__(self) -> bool:
        return bool(self._live)

    def __contains__(self, session_id: int) -> bool:
        return session_id in self._live

    def __iter__(self):
        return (e for t, e in self._q if t not in self._dead)


class WISPServer:
    def __init__(
        self,
        engine: VerificationEngine,
        coeffs: EstimatorCoeffs,
        *,
        policy="wisp",                  # registry name | class | instance
        scheduler: str | None = None,   # DEPRECATED alias of ``policy``
        sched_cfg: SchedulerConfig | None = None,
        slo_classes: dict | None = None,
        network: NetworkModel | None = None,
        dynamic_memory_budget: bool = True,
        deterministic_verify: bool = True,
        prefill: str = "monolithic",    # "monolithic" | "chunked"
        prefill_chunk_tokens: int = 256,
        ttft_slo: dict | None = None,
        tenants=None,   # TenantRegistry | iterable of TenantSpec / spec str
    ):
        self.engine = engine
        self.coeffs = coeffs
        self.sched_cfg = sched_cfg or SchedulerConfig()
        if scheduler is not None:
            warnings.warn(
                "WISPServer(scheduler=...) is deprecated; use policy=... "
                "(registry names: repro.core.scheduler.available_policies())",
                DeprecationWarning, stacklevel=2,
            )
            policy = scheduler
        self.scheduler = make_policy(policy, self.sched_cfg, coeffs)
        #: canonical registry name of the active policy
        self.policy = self.scheduler.name
        self.slo_classes = slo_classes or dict(DEFAULT_SLO_CLASSES)
        self.network = network or NetworkModel()
        if prefill not in ("monolithic", "chunked"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        #: "monolithic": open_session blocks through the whole prompt.
        #: "chunked": prompts prefill in ``prefill_chunk_tokens``-sized
        #: work items scheduled by the policy against a TTFT deadline.
        self.prefill_mode = prefill
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.ttft_slo = ttft_slo or dict(DEFAULT_TTFT_SLO)
        #: multi-tenant admission + fair-share source of truth (DESIGN.md
        #: §13).  The default registry is all-unlimited, so a server built
        #: without tenants behaves exactly as before (golden ``tenant/*``
        #: cells pin this).  One registry may be SHARED across a fleet's
        #: servers — budgets are then tenant-global.
        if tenants is None:
            tenants = TenantRegistry()
        elif not isinstance(tenants, TenantRegistry):
            tenants = TenantRegistry(tenants)   # specs / spec strings
        self.tenants = tenants
        #: per-tenant throttle buffers: FIFO of held work, released each
        #: dispatch epoch as the tenant's bucket recovers.  Entries:
        #: ("open", sid, prompt, slo_class, draft_speed, extras,
        #:  t_request, queue_on_full) | ("work", VerifyWork).  Per-tenant
        #: deques so a flooding tenant's backlog head-blocks only itself.
        self._throttled: dict[str, deque] = {}
        #: sid -> tenant for throttle-held opens (state/close lookups)
        self._throttle_held: dict[int, str] = {}
        #: sids shed by the rate limiter (terminal ``"rejected"`` state)
        self._rejected: set[int] = set()
        #: refresh the scheduler's memory budget from the engine's live
        #: free-page capacity every dispatch epoch (paper Eq. 13's M(t_k));
        #: passed to schedule() as an override — the caller's SchedulerConfig
        #: is never mutated
        self.dynamic_memory_budget = dynamic_memory_budget
        #: key each request's accept/correction draws by (session_id,
        #: committed_len) so verification outcomes do not depend on batch
        #: composition or dispatch order — the event-driven and lock-step
        #: drivers then commit identical streams (see VerifyItem.rng_tag)
        self.deterministic_verify = deterministic_verify
        #: the budget the most recent epoch was admitted against
        self.memory_budget_tokens = self.sched_cfg.memory_budget_tokens
        #: observability: the most recent epoch's ScheduleDecision and the
        #: verify time attributed to it (wall by default; virtual when the
        #: cluster runtime passes ``verify_time`` to ``step``)
        self.last_decision = None
        self.last_verify_time = 0.0
        self._dt_virtual = None
        #: server clock: the latest ``now`` any entry point saw (stamps
        #: events from calls that have no time argument of their own)
        self.now = 0.0
        self.sessions: dict[int, ServerSession] = {}
        #: chunked mode: sessions admitted to the engine but still
        #: prefilling (slot held, chunks in the pending pool)
        self.prefilling: dict[int, PrefillingSession] = {}
        #: DEPRECATED side-car of completed chunked prefills — the same
        #: records ride TTFT_RECORD events; kept one release for drivers
        #: reading TTFT logs directly
        self.prefill_log: list[PrefillRecord] = []
        #: times a mutually-blocked prefill was evicted back to the
        #: admission queue (liveness preemption, see ``step``)
        self.prefill_preemptions = 0
        self.pending: list = []          # WorkItem pool
        #: the work items actually executed by the most recent ``step`` —
        #: what the epoch's verify time covers
        self.last_served: list = []
        #: sessions the cache could not admit yet, FIFO-retried each
        #: dispatch epoch; entries: (session_id, prompt, slo_class,
        #: draft_speed, extras, t_request)
        self.admission_queue = AdmissionQueue()
        #: DEPRECATED (sid, first_token) mirror of queued-session /
        #: chunked-prefill FIRST_TOKEN events; drain with pop_admissions()
        self.admitted: list[tuple[int, int]] = []
        #: first committed token per session (feeds SessionHandle)
        self.first_tokens: dict[int, int] = {}
        #: the ordered typed event stream (drain with ``pop_events()``)
        self._events: list[ServerEvent] = []
        self._rid = 0
        self.log: list[Verdict] = []
        #: last committed verdict per live session — the replay cache the
        #: idempotent ``submit`` answers stale re-submissions from (a
        #: device can never be more than one round behind, so one verdict
        #: is all the history a replay ever needs; DESIGN.md §14)
        self._last_verdict: dict[int, Verdict] = {}
        #: idempotency counters (folded into ClusterMetrics.chaos)
        self.chaos_stats = {"dup_submits": 0, "verdict_replays": 0}

    # -- event stream -------------------------------------------------------
    def _emit(self, event: ServerEvent) -> None:
        self._events.append(event)

    def pop_events(self) -> list[ServerEvent]:
        """Drain the typed event stream, in emission order.  THE way to
        observe server outcomes; see docs/API.md for the event types and
        their per-session ordering guarantees.

        A long-running driver must drain this regularly (event-stream
        consumers do so by construction; a legacy-channel driver should
        drain-and-discard, as the lock-step reference does) — the buffer
        grows with every epoch otherwise.  The deprecated mirrors it
        supersedes (``admitted``, ``prefill_log``, ``log``) grow only
        per-session / per-verdict, like the metrics logs."""
        out, self._events = self._events, []
        return out

    def pop_admissions(self) -> list[tuple[int, int]]:
        """DEPRECATED shim: (session_id, first_token) of queued sessions
        admitted — and chunked prefills completed — since the last call.
        Use ``pop_events()`` and match ``FIRST_TOKEN`` events instead."""
        warnings.warn(
            "pop_admissions() is deprecated; drain pop_events() and match "
            "FIRST_TOKEN events",
            DeprecationWarning, stacklevel=2,
        )
        out, self.admitted = self.admitted, []
        return out

    def session_state(self, session_id: int) -> str:
        """Lifecycle state (see `SessionHandle.state`)."""
        if session_id in self.sessions:
            return "active"
        if session_id in self.prefilling:
            return "prefilling"
        if (session_id in self.admission_queue
                or session_id in self._throttle_held):
            return "queued"
        if session_id in self._rejected:
            return "rejected"
        return "closed"

    def throttled_session_ids(self) -> set[int]:
        """Sids of opens currently held by the tenant rate limiter."""
        return set(self._throttle_held)

    # -- sessions -----------------------------------------------------------
    def _register(self, session_id, slot, first, prompt_len, slo_class,
                  draft_speed, tenant=DEFAULT_TENANT) -> int:
        self.sessions[session_id] = ServerSession(
            session_id=session_id,
            slot=slot,
            slo_class=slo_class,
            committed_len=prompt_len + 1,
            draft_speed=draft_speed,
            tenant=tenant,
        )
        self.first_tokens[session_id] = first
        return first

    def _resolve_slo(self, slo_class, spec) -> int:
        """Resolve + validate a session's SLO class: an explicit argument
        wins, else the tenant's default, else class 3.  Unknown classes
        raise a `ValueError` listing the known ones (not a bare KeyError
        deep in ``submit``/``_begin_chunked``)."""
        if slo_class is None:
            slo_class = spec.slo_class if spec.slo_class is not None else 3
        if slo_class not in self.slo_classes:
            raise ValueError(
                f"unknown SLO class {slo_class!r}; known classes: "
                f"{sorted(self.slo_classes)}"
            )
        if self.prefill_mode == "chunked" and slo_class not in self.ttft_slo:
            raise ValueError(
                f"SLO class {slo_class!r} has no TTFT budget; known: "
                f"{sorted(self.ttft_slo)}"
            )
        return slo_class

    def open_session(
        self, session_id: int, prompt_tokens, slo_class: int | None = None,
        draft_speed: float = 50.0, extras=None, queue_on_full: bool = True,
        now: float = 0.0, tenant: str = DEFAULT_TENANT,
    ) -> SessionHandle:
        """Open a session; returns its `SessionHandle`.

        Monolithic prefill: on success the handle is ``active`` with
        ``first_token`` set (the prompt ran as one blocking engine call);
        when the engine is out of KV pages or slots the session is queued
        (``queued`` handle; retried each dispatch epoch, its
        ``FIRST_TOKEN`` event fires on admission) unless
        ``queue_on_full=False``, which re-raises instead.

        Chunked prefill: the handle is ``prefilling`` — admission only
        reserves the slot and enqueues the first prefill chunk (``now``
        starts the TTFT clock); the first token arrives as a
        ``FIRST_TOKEN`` event when the final chunk completes.

        Tenancy (DESIGN.md §13): the ``tenant``'s rate limiter prices the
        open at its prompt length.  A DEPRIORITIZE decision admits but
        serves the prefill at reduced WFQ weight; QUEUE holds the open in
        the tenant's throttle buffer (``queued`` handle; released as the
        bucket recovers); REJECT sheds it outright (``rejected`` handle,
        terminal).  Both emit typed ``THROTTLED``/``REJECTED`` events.
        ``slo_class=None`` resolves to the tenant's default class."""
        self.now = max(self.now, now)
        spec = self.tenants.get(tenant).spec
        slo_class = self._resolve_slo(slo_class, spec)
        self._rejected.discard(session_id)
        handle = SessionHandle(session_id, self)
        stage = self.tenants.admit_session(
            tenant, len(prompt_tokens), now,
            queued=len(self._throttled.get(tenant, ())),
        )
        if stage == Stage.REJECT:
            self._rejected.add(session_id)
            self._emit(Rejected(session_id, now, tenant))
            return handle
        if stage == Stage.QUEUE:
            self._emit(Throttled(session_id, now, tenant, "queue", "open"))
            self._throttled.setdefault(tenant, deque()).append(
                ("open", session_id, list(prompt_tokens), slo_class,
                 draft_speed, extras, now, queue_on_full)
            )
            self._throttle_held[session_id] = tenant
            return handle
        deprio = stage == Stage.DEPRIORITIZE
        if deprio:
            self._emit(Throttled(session_id, now, tenant,
                                 "deprioritize", "open"))
        self._admit_open(session_id, prompt_tokens, slo_class, draft_speed,
                         extras, now, queue_on_full, tenant, deprio)
        return handle

    def _admit_open(self, session_id, prompt_tokens, slo_class, draft_speed,
                    extras, now, queue_on_full, tenant, deprio):
        """The post-throttle half of ``open_session``: engine admission or
        the capacity queue.  Counts the session live for its tenant."""
        st = self.tenants.get(tenant)
        try:
            if self.prefill_mode == "chunked":
                self._begin_chunked(session_id, prompt_tokens, slo_class,
                                    draft_speed, extras, now, tenant, deprio)
                st.live_sessions += 1
                return
            slot, first = self.engine.new_session(prompt_tokens, extras=extras)
        except (OutOfPages, NoFreeSlots):
            if not queue_on_full:
                raise
            self.admission_queue.push(
                (session_id, list(prompt_tokens), slo_class, draft_speed,
                 extras, now, tenant)
            )
            st.live_sessions += 1
            return
        self._register(session_id, slot, first, len(prompt_tokens),
                       slo_class, draft_speed, tenant)
        st.live_sessions += 1
        self._emit(Admitted(session_id, now))
        self._emit(FirstToken(session_id, now, first))

    def _begin_chunked(self, sid, prompt_tokens, slo_class, draft_speed,
                       extras, t_request, tenant=DEFAULT_TENANT,
                       deprio=False):
        """Reserve engine state for a session and enqueue its first prefill
        chunk.  Raises OutOfPages/NoFreeSlots with nothing leaked."""
        state = self.engine.begin_prefill(prompt_tokens, extras=extras)
        ps = PrefillingSession(
            session_id=sid,
            state=state,
            slo_class=slo_class,
            draft_speed=draft_speed,
            t_request=t_request,
            deadline=t_request + self.ttft_slo[slo_class],
            tenant=tenant,
            deprioritized=deprio,
        )
        self.prefilling[sid] = ps
        self._emit(Admitted(sid, self.now))
        # arrival = the ORIGINAL request time, not the (possibly later)
        # admission-retry time: FCFS/utility ordering and queue-time
        # accounting must see the wait the client actually experienced
        self._enqueue_chunk(ps, ps.t_request)

    def _enqueue_chunk(self, ps: PrefillingSession, now: float):
        """Put the session's NEXT prefill chunk in the pending pool (one at
        a time: chunk i+1 attends to chunk i's KV)."""
        st = ps.state
        self._rid += 1
        self.pending.append(PrefillChunkWork(
            req_id=self._rid,
            session_id=ps.session_id,
            slo_class=ps.slo_class,
            arrival=now,
            deadline=ps.deadline,
            draft_len=0,
            cached_len=st.done,
            alpha=0.0,
            payload=ps,
            prefill_tokens=min(self.prefill_chunk_tokens, st.remaining),
            enqueued_at=now,
            tenant=ps.tenant,
            tenant_weight=self.tenants.weight(ps.tenant),
            deprioritized=ps.deprioritized,
        ))

    def _try_admit(self):
        """Retry queued sessions in arrival order; stop at the first one
        that still does not fit (FIFO fairness — no small-session bypass)."""
        while True:
            entry = self.admission_queue.peek()
            if entry is None:
                return
            (sid, prompt, slo_class, draft_speed, extras, t_request,
             tenant) = entry
            try:
                if self.prefill_mode == "chunked":
                    # TTFT clock started at the original request — a long
                    # wait in the admission queue is TTFT the client saw
                    self._begin_chunked(sid, prompt, slo_class, draft_speed,
                                        extras, t_request, tenant)
                    self.admission_queue.popleft()
                    continue
                slot, first = self.engine.new_session(prompt, extras=extras)
            except (OutOfPages, NoFreeSlots):
                return
            self.admission_queue.popleft()
            self._register(sid, slot, first, len(prompt), slo_class,
                           draft_speed, tenant)
            self.admitted.append((sid, first))
            self._emit(Admitted(sid, self.now))
            self._emit(FirstToken(sid, self.now, first))

    def _purge_session_work(self, session_id: int, tenant: str) -> None:
        """Drop a closing session's pending + throttle-held verify work and
        refund the tenant's tokens-in-flight accounting."""
        st = self.tenants.get(tenant)
        dropped = 0
        keep = []
        for r in self.pending:
            if r.session_id == session_id:
                if r.kind == "verify":
                    dropped += r.draft_len
            else:
                keep.append(r)
        self.pending = keep
        dq = self._throttled.get(tenant)
        if dq:
            # held blocks were never counted in flight — drop, no refund
            self._throttled[tenant] = deque(
                e for e in dq
                if not (e[0] == "work" and e[1].session_id == session_id)
            )
        st.tokens_in_flight = max(0, st.tokens_in_flight - dropped)

    def close_session(self, session_id: int, now: float | None = None):
        t = self.now if now is None else now
        self.now = max(self.now, t)
        if session_id in self._rejected:
            # shed open: nothing was ever admitted or counted
            self._rejected.discard(session_id)
            self._emit(Closed(session_id, t))
            return
        held = self._throttle_held.pop(session_id, None)
        if held is not None:
            # open still in the tenant's throttle buffer: drop it there
            # (it was never counted live — no decrement)
            self._throttled[held] = deque(
                e for e in self._throttled.get(held, ())
                if not (e[0] == "open" and e[1] == session_id)
            )
            self._emit(Closed(session_id, t))
            return
        s = self.sessions.pop(session_id, None)
        if s is None:
            ps = self.prefilling.pop(session_id, None)
            if ps is not None:
                # cancel mid-prefill: drop the session's queued chunk and
                # release its slot/pages (nothing was published)
                self.pending = [
                    r for r in self.pending if r.session_id != session_id
                ]
                self.engine.abort_prefill(ps.state)
                self._tenant_session_closed(ps.tenant)
                self._emit(Closed(session_id, t))
                self._try_admit()
                return
            # session may still be waiting in the admission queue: cancel it
            tenant = next(
                (e[6] for e in self.admission_queue if e[0] == session_id),
                None,
            )
            if not self.admission_queue.cancel(session_id):
                raise KeyError(session_id)
            if tenant is not None:
                self._tenant_session_closed(tenant)
            self._emit(Closed(session_id, t))
            return
        # Lifecycle rule (docs/ARCHITECTURE.md §"Session lifecycle"): close
        # drops the session's still-pending verification requests.  Leaving
        # them behind would make a later step() dispatch a request whose
        # session — and engine slot — no longer exist (KeyError at best,
        # verification against a recycled slot at worst).
        self._purge_session_work(session_id, s.tenant)
        self.engine.close_session(s.slot)
        self.first_tokens.pop(session_id, None)
        self._last_verdict.pop(session_id, None)
        self._tenant_session_closed(s.tenant)
        self._emit(Closed(session_id, t))
        self._try_admit()

    def _tenant_session_closed(self, tenant: str) -> None:
        st = self.tenants.get(tenant)
        st.live_sessions = max(0, st.live_sessions - 1)

    def restore_session(
        self,
        session_id: int,
        committed_tokens,
        *,
        slo_class: int = 3,
        draft_speed: float = 50.0,
        rounds: int = 0,
        alpha: float = 0.6,
        spec_k: int = 0,
        first_token: int | None = None,
        extras=None,
        now: float = 0.0,
        tenant: str = DEFAULT_TENANT,
    ) -> int:
        """Rebuild a migrated session from its committed token stream
        (the fleet failover path, docs/ARCHITECTURE.md §7).

        The committed stream is the device-side ground truth: everything
        before its last token is replayed as a (resumable, prefix-cache
        aware) prefill — exactly the state the engine invariant requires
        (``fed = committed_len - 1``, KV for ``committed[:-1]``) — and the
        replay's argmax-sampled first token is discarded in favor of the
        stream's actual last token.  With deterministic (rng-tagged)
        verification and same-seed engines the restored session then
        continues byte-identically to the dead verifier (DESIGN.md §10).

        ``rounds`` must be the session's delivered-verdict count so the
        fleet's ``(session_id, round_index)`` hedge keys stay collision
        free across the migration.  Emits NO ADMITTED/FIRST_TOKEN events —
        the client already holds those tokens.  Raises OutOfPages /
        NoFreeSlots (nothing leaked) when this verifier cannot take the
        session; returns the number of prompt tokens actually recomputed
        (prefix-cache hits make migration to a warm verifier nearly
        free)."""
        self.now = max(self.now, now)
        if (session_id in self.sessions or session_id in self.prefilling
                or session_id in self.admission_queue
                or session_id in self._throttle_held):
            raise ValueError(f"session {session_id} already live here")
        committed = [int(t) for t in committed_tokens]
        if len(committed) < 2:
            raise ValueError("restore needs a prompt plus a first token")
        st = self.engine.begin_prefill(committed[:-1], extras=extras)
        try:
            while not st.finished:
                self.engine.prefill_chunk(st, self.prefill_chunk_tokens)
        except OutOfPages:
            self.engine.abort_prefill(st)
            raise
        # the replay sampled a throwaway first token at committed[:-1]'s
        # final position; the stream already committed its successor
        self.engine.last_token[st.slot] = committed[-1]
        self.sessions[session_id] = ServerSession(
            session_id=session_id,
            slot=st.slot,
            slo_class=slo_class,
            committed_len=len(committed),
            alpha=alpha,
            rounds=rounds,
            draft_speed=draft_speed,
            spec_k=spec_k,
            tenant=tenant,
        )
        # migration preserves tenant accounting: the session is live here
        # now (the dead verifier's registry entry — when the registry is
        # fleet-shared, the scrub already decremented it)
        self.tenants.get(tenant).live_sessions += 1
        if first_token is not None:
            self.first_tokens[session_id] = int(first_token)
        return st.total - st.n_cached

    # -- request intake (paper Eq. 6/12: server-side budget -> deadline) ----
    def submit(
        self,
        session_id: int,
        draft_tokens,
        q_logits=None,
        *,
        q_compact=None,
        now: float,
        t_draft: float,
        t_network: float,
        round_index: int | None = None,
    ) -> int | None:
        """Queue a drafted block for verification.  The draft distribution
        arrives as dense ``q_logits`` (exact residual), a `CompactQ` via
        ``q_compact`` (O(K·C) wire payload, DESIGN.md §9), or neither
        (greedy verification reads no q).

        **Idempotent** under the ``(session_id, round_index)`` key
        (DESIGN.md §14): a re-submission of the round currently in flight
        is absorbed (``None``, counted), and a re-submission of an
        already-verified round replays the cached verdict as a fresh
        VERDICT event instead of verifying twice — the committed stream
        advances exactly once per round no matter how many request copies
        a flaky uplink delivers.  ``round_index=None`` (legacy callers on
        a reliable channel) trusts the session's own round counter.

        The session's tenant bucket prices the block at its draft length
        (DESIGN.md §13): DEPRIORITIZE queues it flagged for reduced WFQ
        weight; QUEUE holds it in the tenant's throttle buffer until the
        bucket recovers (released each dispatch epoch).  A streaming
        block is never rejected."""
        self.now = max(self.now, now)
        s = self.sessions[session_id]
        rnd = s.rounds if round_index is None else int(round_index)
        if rnd < s.rounds:
            # stale duplicate of a verified round: its verdict died on the
            # downlink — replay the cached one (new event, new delivery)
            self.chaos_stats["verdict_replays"] += 1
            last = self._last_verdict.get(session_id)
            if last is not None and last.round_index == rnd:
                self._emit(VerdictEvent(session_id, self.now, last))
            return None
        if rnd > s.rounds:
            raise ValueError(
                f"session {session_id}: submit for future round {rnd} "
                f"(server at round {s.rounds})"
            )
        if any(r.kind == "verify" and r.session_id == session_id
               and r.round_index == rnd for r in self.pending) or any(
                e[0] == "work" and e[1].session_id == session_id
                and e[1].round_index == rnd
                for e in self._throttled.get(s.tenant, ())):
            # duplicate of the in-flight round, still queued/held: absorb
            self.chaos_stats["dup_submits"] += 1
            return None
        s.t_draft_last = t_draft
        s.t_net_last = t_network
        target_speed = self.slo_classes[s.slo_class]
        nd = len(draft_tokens)
        s.spec_k = max(nd, 1)
        stage = self.tenants.admit_block(s.tenant, nd, now)
        tstate = self.tenants.get(s.tenant)
        tstate.submitted_tokens += nd
        if stage != Stage.QUEUE:
            # held blocks do not count in flight (else their own release
            # recheck against max_tokens_in_flight would self-block)
            tstate.tokens_in_flight += nd
        # spill tier (DESIGN.md §12): a draft block announces the session's
        # next verify epoch — page its spilled KV back in NOW (best effort)
        # so the fused verify dispatch never blocks on a fault; whatever
        # could not be prefetched is priced into the work item below
        self.engine.prefetch_session(s.slot)
        expected_tokens = s.alpha * nd + 1.0
        budget = expected_tokens / target_speed - t_draft - t_network
        budget = max(budget, 1e-3)
        self._rid += 1
        req = VerifyWork(
            req_id=self._rid,
            session_id=session_id,
            slo_class=s.slo_class,
            arrival=now,
            deadline=now + budget,
            draft_len=nd,
            cached_len=int(self.engine.fed[s.slot]),
            alpha=s.alpha,
            payload=(
                np.asarray(draft_tokens, np.int32),
                None if q_logits is None else np.asarray(q_logits),
                q_compact,
            ),
            enqueued_at=now,
            round_index=s.rounds,
            pagein_tokens=self.engine.spilled_tokens(s.slot),
            tenant=s.tenant,
            tenant_weight=self.tenants.weight(s.tenant),
            deprioritized=stage == Stage.DEPRIORITIZE,
        )
        if stage == Stage.QUEUE:
            # held until the bucket recovers; the prebuilt item keeps its
            # original arrival/enqueued_at so WFQ aging credits the hold
            self._emit(Throttled(session_id, now, s.tenant,
                                 "queue", "submit"))
            self._throttled.setdefault(s.tenant, deque()).append(
                ("work", req)
            )
            return self._rid
        if stage == Stage.DEPRIORITIZE:
            self._emit(Throttled(session_id, now, s.tenant,
                                 "deprioritize", "submit"))
        self.pending.append(req)
        return self._rid

    # -- throttle release ----------------------------------------------------
    def _release_throttled(self, now: float) -> None:
        """Re-price each tenant's throttle buffer head against its (lazily
        refilled) bucket and release what it now covers.  FIFO *within* a
        tenant only — one flooding tenant's backlog never head-blocks
        another's.  Held opens re-price with ``queued=0``: the backlog
        bound sheds new arrivals, not work already accepted for holding."""
        for tenant, dq in self._throttled.items():
            while dq:
                entry = dq[0]
                if entry[0] == "open":
                    (_, sid, prompt, slo_class, draft_speed, extras,
                     t_request, queue_on_full) = entry
                    stage = self.tenants.admit_session(
                        tenant, len(prompt), now, queued=0)
                    if stage == Stage.QUEUE:
                        break
                    dq.popleft()
                    self._throttle_held.pop(sid, None)
                    if stage == Stage.REJECT:    # max_queued == 0 edge
                        self._rejected.add(sid)
                        self._emit(Rejected(sid, now, tenant))
                        continue
                    deprio = stage == Stage.DEPRIORITIZE
                    if deprio:
                        self._emit(Throttled(sid, now, tenant,
                                             "deprioritize", "open"))
                    self._admit_open(sid, prompt, slo_class, draft_speed,
                                     extras, t_request, queue_on_full,
                                     tenant, deprio)
                else:
                    req = entry[1]
                    stage = self.tenants.admit_block(
                        tenant, req.draft_len, now)
                    if stage == Stage.QUEUE:
                        break
                    dq.popleft()
                    self.tenants.get(tenant).tokens_in_flight += req.draft_len
                    req.deprioritized = stage == Stage.DEPRIORITIZE
                    if req.deprioritized:
                        self._emit(Throttled(req.session_id, now, tenant,
                                             "deprioritize", "submit"))
                    self.pending.append(req)

    # -- dispatch epoch -------------------------------------------------------
    def step(self, now: float, *, verify_time=None) -> list[Verdict]:
        """One dispatch epoch at time ``now``.

        Outcomes surface on the event stream (``VERDICT`` /
        ``FIRST_TOKEN`` / ``TTFT_RECORD`` / ``PREEMPTED`` events); the
        byte-identical verdict list is also *returned* as the legacy shim
        channel.

        ``verify_time``: optional callable mapping the list of served
        work items to the verification duration (seconds) to attribute
        to this epoch.  The event-driven cluster runtime passes one driven
        by the estimator (+ optional noise) so queueing/violation accounting
        runs on the virtual clock; by default each verdict carries the
        engine's measured wall time (synchronous CPU drivers)."""
        self.now = max(self.now, now)
        self._release_throttled(now)
        self._try_admit()
        # M(t_k): live free-page capacity, not a static config number
        self.memory_budget_tokens = (
            self.engine.memory_budget_tokens()
            if self.dynamic_memory_budget
            else self.sched_cfg.memory_budget_tokens
        )
        self.last_served = []
        if not self.pending:
            return []
        decision = self.scheduler.schedule(
            self.pending, now, memory_budget_tokens=self.memory_budget_tokens
        )
        self.last_decision = decision
        if not decision.batch:
            return []
        chosen = {r.req_id for r in decision.batch}
        self.pending = [r for r in self.pending if r.req_id not in chosen]

        items = [r.make_engine_item(self) for r in decision.batch]
        try:
            served = list(decision.batch)
            outcomes = self.engine.step(items)
        except OutOfPages:
            # The token budget over-admitted (committed tokens of sessions
            # outside the batch are not page headroom).  Shrink to whatever
            # fits — per-request execution — so the epoch still makes
            # progress instead of requeue-livelocking; requests that cannot
            # fit even alone go back to pending (they need a close_session
            # to free pages).
            served, outcomes = [], []
            for r, it in zip(decision.batch, items):
                try:
                    outcomes.extend(self.engine.step([it]))
                    served.append(r)
                except OutOfPages:
                    self.pending.append(r)

        # work the engine deferred (e.g. prefill chunks the page pool could
        # not cover — state untouched) requeues like the OutOfPages verify
        # path above
        pairs, deferred = [], []
        for r, o in zip(served, outcomes):
            if r.deferred(o):
                deferred.append(r)
            else:
                pairs.append((r, o))
        if not pairs and deferred and len(self.prefilling) > 1:
            # Liveness: every chunk this epoch was uncoverable and nothing
            # else ran, so no future close/trim is coming from *this* pool
            # of work — partially-prefilled sessions are mutually blocking
            # (each holds pages the others need).  Preempt the
            # youngest-requested *prefilling session* (not merely the
            # youngest chunk scheduled this epoch — under memory pressure
            # the scheduler may have admitted only the oldest's chunk)
            # back to the admission queue: its pages are released, it
            # retries FIFO with its original TTFT clock, and the oldest
            # can finish.  Without this, N long prompts that each fit
            # alone but not together requeue forever.
            victim_sid = max(
                self.prefilling,
                key=lambda sid: (self.prefilling[sid].t_request, sid),
            )
            ps = self.prefilling.pop(victim_sid)
            deferred = [r for r in deferred if r.session_id != victim_sid]
            self.pending = [
                r for r in self.pending if r.session_id != victim_sid
            ]
            self.engine.abort_prefill(ps.state)
            self.admission_queue.push(
                (ps.session_id, [int(x) for x in ps.state.tokens],
                 ps.slo_class, ps.draft_speed, ps.state.extras,
                 ps.t_request, ps.tenant)
            )
            # keep the retry queue in request order (FIFO fairness)
            self.admission_queue.resort(key=lambda q: q[5])
            self.prefill_preemptions += 1
            self._emit(Preempted(victim_sid, now))
        self.pending.extend(deferred)
        self.last_served = [r for r, _ in pairs]

        dt_virtual = (
            None if verify_time is None else float(verify_time(self.last_served))
        )
        # epoch wall time: the verify batch and the ragged prefill pass run
        # back to back (all verify outcomes share one batch time, all chunk
        # outcomes share one pass time)
        wall = max((o.t_verify for _, o in pairs if hasattr(o, "t_verify")),
                   default=0.0) + \
            max((o.t_chunk for _, o in pairs if hasattr(o, "t_chunk")),
                default=0.0)
        self.last_verify_time = dt_virtual if dt_virtual is not None else wall
        #: verify hooks read this: None -> each verdict carries the engine's
        #: measured wall time; set -> the epoch's virtual duration
        self._dt_virtual = dt_virtual
        tv_epoch = self.last_verify_time

        verdicts = []
        for r, o in pairs:
            v = r.apply(self, o, now, tv_epoch)
            if v is not None:
                verdicts.append(v)
        return verdicts

    # -- work-item commit hooks (called via WorkItem.apply) -----------------
    def commit_verify(self, r, outcome, now: float, tv_epoch: float) -> Verdict:
        """Account one executed verification: EWMA acceptance update,
        committed-stream advance, deadline verdict (VERDICT event + the
        legacy return/log channels)."""
        s = self.sessions[r.session_id]
        if r.draft_len > 0:
            s.alpha = 0.8 * s.alpha + 0.2 * (outcome.accept_len / r.draft_len)
        s.rounds += 1
        s.committed_len += outcome.emitted
        tstate = self.tenants.get(s.tenant)
        tstate.tokens_in_flight = max(
            0, tstate.tokens_in_flight - r.draft_len)
        tstate.committed_tokens += outcome.emitted
        t_queue = max(0.0, now - r.enqueued_at)
        tv = outcome.t_verify if self._dt_virtual is None else self._dt_virtual
        complete = now + tv
        v = Verdict(
            session_id=r.session_id,
            accept_len=outcome.accept_len,
            token=outcome.token,
            emitted=outcome.emitted,
            t_queue=t_queue,
            t_verify=tv,
            deadline=r.deadline,
            violated=complete > r.deadline,
            round_index=r.round_index,
            queue_depth=len(self.pending),
        )
        self.log.append(v)
        self._last_verdict[r.session_id] = v
        self._emit(VerdictEvent(r.session_id, now, v))
        return v

    def apply_chunk(self, r, outcome, now: float, tv_epoch: float) -> None:
        """Account one executed prefill chunk: enqueue the successor chunk,
        or — on the final chunk — activate the session and surface its
        first token as a FIRST_TOKEN event (+ TTFT_RECORD)."""
        ps: PrefillingSession = r.payload
        st = ps.state
        if outcome.first_token is None:
            self._enqueue_chunk(ps, now)
            return
        del self.prefilling[ps.session_id]
        self._register(ps.session_id, st.slot, outcome.first_token,
                       st.total, ps.slo_class, ps.draft_speed,
                       tenant=ps.tenant)
        self.admitted.append((ps.session_id, outcome.first_token))
        self._emit(FirstToken(ps.session_id, now, outcome.first_token))
        t_first = now + tv_epoch
        rec = PrefillRecord(
            session_id=ps.session_id,
            prompt_len=st.total,
            chunks=st.chunks,
            t_request=ps.t_request,
            t_first=t_first,
            deadline=ps.deadline,
            violated=t_first > ps.deadline,
        )
        self.prefill_log.append(rec)
        self._emit(TTFTRecord(ps.session_id, now, rec))

    @property
    def queue_depth(self) -> int:
        return len(self.pending)

    @property
    def throttle_backlog(self) -> int:
        """Opens + verify blocks currently held by the tenant rate limiter.
        Dispatch gating must treat this as queued work: releases happen
        only inside ``step()``, so a throttled-only backlog still needs an
        epoch scheduled to drain."""
        return sum(len(dq) for dq in self._throttled.values())
