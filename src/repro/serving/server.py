"""WISP verification server: queues + SLO-aware scheduler + engine.

The coordinator keeps per-session state (slot, committed tokens, EWMA
acceptance estimate), maintains the pending-request pool, and at each
dispatch epoch runs Algorithm 1 to build a batch, executes it on the
verification engine, and returns verdicts.

Prompt prefill runs in one of two modes (DESIGN.md §8):

  * ``prefill="monolithic"`` (default) — ``open_session`` runs the whole
    prompt as one blocking engine call and returns the first token
    synchronously (the legacy path; simple drivers and the lock-step
    reference need it);
  * ``prefill="chunked"`` — ``open_session`` only *admits* the session
    (allocating its slot/pages) and returns ``None`` immediately; the
    prompt is split into fixed-budget chunks that enter the pending pool
    as ``kind="prefill"`` work items with the session's TTFT deadline and
    compete with verification under Algorithm 1.  The first token
    surfaces through ``pop_admissions()`` when the final chunk lands —
    the same channel capacity-queued admissions already use.

This is the *functional* server used by examples and integration tests
(driven synchronously, CPU).  Paper-scale capacity/goodput numbers come
from `repro.sim`, which replays the same scheduler against the analytic
latency model at thousands of devices.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    FCFSScheduler,
    SchedulerConfig,
    SLOScheduler,
    VerifyRequest,
)
from repro.serving.engine import (
    NoFreeSlots,
    PrefillChunkItem,
    VerificationEngine,
    VerifyItem,
)
from repro.serving.kv_cache import OutOfPages
from repro.serving.transport import NetworkModel

#: paper §5.1: four token-speed SLO classes (tokens/s)
DEFAULT_SLO_CLASSES = {1: 8.0, 2: 6.0, 3: 4.0, 4: 2.0}

#: TTFT (time-to-first-token) budgets per SLO class, seconds — the
#: deadline chunked prefill schedules against (DESIGN.md §8).  Scaled like
#: the token-speed classes: a class promising 8 tok/s streaming also
#: promises a snappier first token than the 2 tok/s tier.
DEFAULT_TTFT_SLO = {1: 0.75, 2: 1.5, 3: 3.0, 4: 6.0}


@dataclasses.dataclass
class ServerSession:
    session_id: int
    slot: int
    slo_class: int
    committed_len: int
    alpha: float = 0.6           # EWMA acceptance-rate estimate
    rounds: int = 0
    draft_speed: float = 50.0
    t_draft_last: float = 0.0
    t_net_last: float = 0.0


@dataclasses.dataclass
class PrefillingSession:
    """A session whose prompt is still being chunk-prefilled: admitted to
    the engine (slot + pages held, ``state`` resumable) but not yet
    streaming.  Exactly one chunk of it is in the pending pool at a time —
    chunk *i+1* depends on chunk *i*'s KV."""

    session_id: int
    state: object                # engine PrefillState
    slo_class: int
    draft_speed: float
    t_request: float             # when the client asked (TTFT clock start)
    deadline: float              # TTFT deadline = t_request + ttft_slo[class]


@dataclasses.dataclass
class PrefillRecord:
    """One completed chunked prefill (the TTFT observability unit)."""

    session_id: int
    prompt_len: int
    chunks: int
    t_request: float
    t_first: float               # when the final chunk's epoch completed
    deadline: float
    violated: bool

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_request


@dataclasses.dataclass
class Verdict:
    session_id: int
    accept_len: int
    token: int
    emitted: int
    t_queue: float
    t_verify: float
    deadline: float
    violated: bool


class WISPServer:
    def __init__(
        self,
        engine: VerificationEngine,
        coeffs: EstimatorCoeffs,
        *,
        scheduler: str = "slo",          # "slo" | "fcfs"
        sched_cfg: SchedulerConfig | None = None,
        slo_classes: dict | None = None,
        network: NetworkModel | None = None,
        dynamic_memory_budget: bool = True,
        deterministic_verify: bool = True,
        prefill: str = "monolithic",    # "monolithic" | "chunked"
        prefill_chunk_tokens: int = 256,
        ttft_slo: dict | None = None,
    ):
        self.engine = engine
        self.coeffs = coeffs
        self.sched_cfg = sched_cfg or SchedulerConfig()
        cls = SLOScheduler if scheduler == "slo" else FCFSScheduler
        self.scheduler = cls(self.sched_cfg, coeffs)
        self.slo_classes = slo_classes or dict(DEFAULT_SLO_CLASSES)
        self.network = network or NetworkModel()
        if prefill not in ("monolithic", "chunked"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        #: "monolithic": open_session blocks through the whole prompt.
        #: "chunked": prompts prefill in ``prefill_chunk_tokens``-sized
        #: work items scheduled by Algorithm 1 against a TTFT deadline.
        self.prefill_mode = prefill
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.ttft_slo = ttft_slo or dict(DEFAULT_TTFT_SLO)
        #: refresh the scheduler's memory budget from the engine's live
        #: free-page capacity every dispatch epoch (paper Eq. 13's M(t_k));
        #: passed to schedule() as an override — the caller's SchedulerConfig
        #: is never mutated
        self.dynamic_memory_budget = dynamic_memory_budget
        #: key each request's accept/correction draws by (session_id,
        #: committed_len) so verification outcomes do not depend on batch
        #: composition or dispatch order — the event-driven and lock-step
        #: drivers then commit identical streams (see VerifyItem.rng_tag)
        self.deterministic_verify = deterministic_verify
        #: the budget the most recent epoch was admitted against
        self.memory_budget_tokens = self.sched_cfg.memory_budget_tokens
        #: observability: the most recent epoch's ScheduleDecision and the
        #: verify time attributed to it (wall by default; virtual when the
        #: cluster runtime passes ``verify_time`` to ``step``)
        self.last_decision = None
        self.last_verify_time = 0.0
        self.sessions: dict[int, ServerSession] = {}
        #: chunked mode: sessions admitted to the engine but still
        #: prefilling (slot held, chunks in the pending pool)
        self.prefilling: dict[int, PrefillingSession] = {}
        #: completed chunked prefills (TTFT log)
        self.prefill_log: list[PrefillRecord] = []
        #: times a mutually-blocked prefill was evicted back to the
        #: admission queue (liveness preemption, see ``step``)
        self.prefill_preemptions = 0
        self.pending: list[VerifyRequest] = []
        #: the requests (verify + prefill chunks) actually executed by the
        #: most recent ``step`` — what the epoch's verify time covers
        self.last_served: list[VerifyRequest] = []
        #: sessions the cache could not admit yet: (session_id, prompt,
        #: slo_class, draft_speed, extras, t_request), retried each
        #: dispatch epoch
        self.admission_queue: list[tuple] = []
        #: (session_id, first_token) of queued sessions admitted since the
        #: last ``pop_admissions()``
        self.admitted: list[tuple[int, int]] = []
        self._rid = 0
        self.log: list[Verdict] = []

    # -- sessions -----------------------------------------------------------
    def _register(self, session_id, slot, first, prompt_len, slo_class,
                  draft_speed) -> int:
        self.sessions[session_id] = ServerSession(
            session_id=session_id,
            slot=slot,
            slo_class=slo_class,
            committed_len=prompt_len + 1,
            draft_speed=draft_speed,
        )
        return first

    def open_session(
        self, session_id: int, prompt_tokens, slo_class: int = 3,
        draft_speed: float = 50.0, extras=None, queue_on_full: bool = True,
        now: float = 0.0,
    ) -> int | None:
        """Admit a session, or queue it when the engine is out of KV pages
        or slots (returns ``None``; the session is retried each dispatch
        epoch — poll ``pop_admissions()`` for its first token).

        Chunked-prefill mode always returns ``None``: admission only
        reserves the slot and enqueues the first prefill chunk (``now``
        starts the TTFT clock); the first token arrives via
        ``pop_admissions()`` when the final chunk completes."""
        try:
            if self.prefill_mode == "chunked":
                self._begin_chunked(session_id, prompt_tokens, slo_class,
                                    draft_speed, extras, now)
                return None
            slot, first = self.engine.new_session(prompt_tokens, extras=extras)
        except (OutOfPages, NoFreeSlots):
            if not queue_on_full:
                raise
            self.admission_queue.append(
                (session_id, list(prompt_tokens), slo_class, draft_speed,
                 extras, now)
            )
            return None
        return self._register(session_id, slot, first, len(prompt_tokens),
                              slo_class, draft_speed)

    def _begin_chunked(self, sid, prompt_tokens, slo_class, draft_speed,
                       extras, t_request):
        """Reserve engine state for a session and enqueue its first prefill
        chunk.  Raises OutOfPages/NoFreeSlots with nothing leaked."""
        state = self.engine.begin_prefill(prompt_tokens, extras=extras)
        ps = PrefillingSession(
            session_id=sid,
            state=state,
            slo_class=slo_class,
            draft_speed=draft_speed,
            t_request=t_request,
            deadline=t_request + self.ttft_slo[slo_class],
        )
        self.prefilling[sid] = ps
        self._enqueue_chunk(ps, t_request)

    def _enqueue_chunk(self, ps: PrefillingSession, now: float):
        """Put the session's NEXT prefill chunk in the pending pool (one at
        a time: chunk i+1 attends to chunk i's KV)."""
        st = ps.state
        self._rid += 1
        self.pending.append(VerifyRequest(
            req_id=self._rid,
            session_id=ps.session_id,
            slo_class=ps.slo_class,
            arrival=now,
            deadline=ps.deadline,
            draft_len=0,
            cached_len=st.done,
            alpha=0.0,
            payload=ps,
            prefill_tokens=min(self.prefill_chunk_tokens, st.remaining),
            kind="prefill",
            enqueued_at=now,
        ))

    def _try_admit(self):
        """Retry queued sessions in arrival order; stop at the first one
        that still does not fit (FIFO fairness — no small-session bypass)."""
        while self.admission_queue:
            (sid, prompt, slo_class, draft_speed, extras,
             t_request) = self.admission_queue[0]
            try:
                if self.prefill_mode == "chunked":
                    # TTFT clock started at the original request — a long
                    # wait in the admission queue is TTFT the client saw
                    self._begin_chunked(sid, prompt, slo_class, draft_speed,
                                        extras, t_request)
                    self.admission_queue.pop(0)
                    continue
                slot, first = self.engine.new_session(prompt, extras=extras)
            except (OutOfPages, NoFreeSlots):
                return
            self.admission_queue.pop(0)
            self._register(sid, slot, first, len(prompt), slo_class,
                           draft_speed)
            self.admitted.append((sid, first))

    def pop_admissions(self) -> list[tuple[int, int]]:
        out, self.admitted = self.admitted, []
        return out

    def close_session(self, session_id: int):
        s = self.sessions.pop(session_id, None)
        if s is None:
            ps = self.prefilling.pop(session_id, None)
            if ps is not None:
                # cancel mid-prefill: drop the session's queued chunk and
                # release its slot/pages (nothing was published)
                self.pending = [
                    r for r in self.pending if r.session_id != session_id
                ]
                self.engine.abort_prefill(ps.state)
                self._try_admit()
                return
            # session may still be waiting in the admission queue: cancel it
            before = len(self.admission_queue)
            self.admission_queue = [
                q for q in self.admission_queue if q[0] != session_id
            ]
            if len(self.admission_queue) == before:
                raise KeyError(session_id)
            return
        # Lifecycle rule (docs/ARCHITECTURE.md §"Session lifecycle"): close
        # drops the session's still-pending verification requests.  Leaving
        # them behind would make a later step() dispatch a request whose
        # session — and engine slot — no longer exist (KeyError at best,
        # verification against a recycled slot at worst).
        self.pending = [r for r in self.pending if r.session_id != session_id]
        self.engine.close_session(s.slot)
        self._try_admit()

    # -- request intake (paper Eq. 6/12: server-side budget -> deadline) ----
    def submit(
        self,
        session_id: int,
        draft_tokens,
        q_logits,
        *,
        now: float,
        t_draft: float,
        t_network: float,
    ) -> int:
        s = self.sessions[session_id]
        s.t_draft_last = t_draft
        s.t_net_last = t_network
        target_speed = self.slo_classes[s.slo_class]
        nd = len(draft_tokens)
        expected_tokens = s.alpha * nd + 1.0
        budget = expected_tokens / target_speed - t_draft - t_network
        budget = max(budget, 1e-3)
        self._rid += 1
        req = VerifyRequest(
            req_id=self._rid,
            session_id=session_id,
            slo_class=s.slo_class,
            arrival=now,
            deadline=now + budget,
            draft_len=nd,
            cached_len=int(self.engine.fed[s.slot]),
            alpha=s.alpha,
            payload=(np.asarray(draft_tokens, np.int32), np.asarray(q_logits)),
            enqueued_at=now,
            round_index=s.rounds,
        )
        self.pending.append(req)
        return self._rid

    # -- dispatch epoch -------------------------------------------------------
    def step(self, now: float, *, verify_time=None) -> list[Verdict]:
        """One dispatch epoch at time ``now``; returns verdicts of the batch.

        ``verify_time``: optional callable mapping the list of served
        VerifyRequests to the verification duration (seconds) to attribute
        to this epoch.  The event-driven cluster runtime passes one driven
        by the estimator (+ optional noise) so queueing/violation accounting
        runs on the virtual clock; by default each verdict carries the
        engine's measured wall time (synchronous CPU drivers)."""
        self._try_admit()
        # M(t_k): live free-page capacity, not a static config number
        self.memory_budget_tokens = (
            self.engine.memory_budget_tokens()
            if self.dynamic_memory_budget
            else self.sched_cfg.memory_budget_tokens
        )
        self.last_served = []
        if not self.pending:
            return []
        decision = self.scheduler.schedule(
            self.pending, now, memory_budget_tokens=self.memory_budget_tokens
        )
        self.last_decision = decision
        if not decision.batch:
            return []
        chosen = {r.req_id for r in decision.batch}
        self.pending = [r for r in self.pending if r.req_id not in chosen]

        items = []
        for r in decision.batch:
            if r.kind == "prefill":
                ps = r.payload
                items.append(PrefillChunkItem(ps.state, r.prefill_tokens))
                continue
            s = self.sessions[r.session_id]
            toks, qlog = r.payload
            items.append(VerifyItem(
                slot=s.slot, draft_tokens=toks, q_logits=qlog,
                rng_tag=(r.session_id, r.cached_len)
                if self.deterministic_verify else None,
            ))
        try:
            served = list(decision.batch)
            outcomes = self.engine.step(items)
        except OutOfPages:
            # The token budget over-admitted (committed tokens of sessions
            # outside the batch are not page headroom).  Shrink to whatever
            # fits — per-request execution — so the epoch still makes
            # progress instead of requeue-livelocking; requests that cannot
            # fit even alone go back to pending (they need a close_session
            # to free pages).
            served, outcomes = [], []
            for r, it in zip(decision.batch, items):
                try:
                    outcomes.extend(self.engine.step([it]))
                    served.append(r)
                except OutOfPages:
                    self.pending.append(r)

        # prefill chunks the pool could not cover come back oom (state
        # untouched): requeue them like the OutOfPages verify path above
        pairs, oom_reqs = [], []
        for r, o in zip(served, outcomes):
            if r.kind == "prefill" and o.oom:
                oom_reqs.append(r)
                continue
            pairs.append((r, o))
        if not pairs and oom_reqs and len(self.prefilling) > 1:
            # Liveness: every chunk this epoch was uncoverable and nothing
            # else ran, so no future close/trim is coming from *this* pool
            # of work — partially-prefilled sessions are mutually blocking
            # (each holds pages the others need).  Preempt the
            # youngest-requested *prefilling session* (not merely the
            # youngest chunk scheduled this epoch — under memory pressure
            # the scheduler may have admitted only the oldest's chunk)
            # back to the admission queue: its pages are released, it
            # retries FIFO with its original TTFT clock, and the oldest
            # can finish.  Without this, N long prompts that each fit
            # alone but not together requeue forever.
            victim_sid = max(
                self.prefilling,
                key=lambda sid: (self.prefilling[sid].t_request, sid),
            )
            ps = self.prefilling.pop(victim_sid)
            oom_reqs = [r for r in oom_reqs if r.session_id != victim_sid]
            self.pending = [
                r for r in self.pending if r.session_id != victim_sid
            ]
            self.engine.abort_prefill(ps.state)
            self.admission_queue.append(
                (ps.session_id, [int(x) for x in ps.state.tokens],
                 ps.slo_class, ps.draft_speed, ps.state.extras,
                 ps.t_request)
            )
            # keep the retry queue in request order (FIFO fairness)
            self.admission_queue.sort(key=lambda q: q[5])
            self.prefill_preemptions += 1
        self.pending.extend(oom_reqs)
        self.last_served = [r for r, _ in pairs]

        dt_virtual = (
            None if verify_time is None else float(verify_time(self.last_served))
        )
        # epoch wall time: the verify batch and the ragged prefill pass run
        # back to back (all verify outcomes share one batch time, all chunk
        # outcomes share one pass time)
        wall = max((o.t_verify for r, o in pairs if r.kind != "prefill"),
                   default=0.0) + \
            max((o.t_chunk for r, o in pairs if r.kind == "prefill"),
                default=0.0)
        self.last_verify_time = dt_virtual if dt_virtual is not None else wall
        tv_epoch = self.last_verify_time

        verdicts = []
        for r, o in pairs:
            if r.kind == "prefill":
                self._apply_chunk(r, o, now, tv_epoch)
                continue
            s = self.sessions[r.session_id]
            # EWMA acceptance update
            if r.draft_len > 0:
                s.alpha = 0.8 * s.alpha + 0.2 * (o.accept_len / r.draft_len)
            s.rounds += 1
            s.committed_len += o.emitted
            t_queue = max(0.0, now - r.enqueued_at)
            tv = o.t_verify if dt_virtual is None else dt_virtual
            complete = now + tv
            v = Verdict(
                session_id=r.session_id,
                accept_len=o.accept_len,
                token=o.token,
                emitted=o.emitted,
                t_queue=t_queue,
                t_verify=tv,
                deadline=r.deadline,
                violated=complete > r.deadline,
            )
            self.log.append(v)
            verdicts.append(v)
        return verdicts

    def _apply_chunk(self, r: VerifyRequest, outcome, now: float,
                     tv_epoch: float):
        """Account one executed prefill chunk: enqueue the successor chunk,
        or — on the final chunk — activate the session and surface its
        first token through ``pop_admissions()``."""
        ps: PrefillingSession = r.payload
        st = ps.state
        if outcome.first_token is None:
            self._enqueue_chunk(ps, now)
            return
        del self.prefilling[ps.session_id]
        self._register(ps.session_id, st.slot, outcome.first_token,
                       st.total, ps.slo_class, ps.draft_speed)
        self.admitted.append((ps.session_id, outcome.first_token))
        t_first = now + tv_epoch
        self.prefill_log.append(PrefillRecord(
            session_id=ps.session_id,
            prompt_len=st.total,
            chunks=st.chunks,
            t_request=ps.t_request,
            t_first=t_first,
            deadline=ps.deadline,
            violated=t_first > ps.deadline,
        ))

    @property
    def queue_depth(self) -> int:
        return len(self.pending)
