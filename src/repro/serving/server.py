"""WISP verification server: queues + SLO-aware scheduler + engine.

The coordinator keeps per-session state (slot, committed tokens, EWMA
acceptance estimate), maintains the pending-request pool, and at each
dispatch epoch runs Algorithm 1 to build a batch, executes it on the
verification engine, and returns verdicts.

This is the *functional* server used by examples and integration tests
(driven synchronously, CPU).  Paper-scale capacity/goodput numbers come
from `repro.sim`, which replays the same scheduler against the analytic
latency model at thousands of devices.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.estimator import EstimatorCoeffs
from repro.core.scheduler import (
    FCFSScheduler,
    SchedulerConfig,
    SLOScheduler,
    VerifyRequest,
)
from repro.serving.engine import NoFreeSlots, VerificationEngine, VerifyItem
from repro.serving.kv_cache import OutOfPages
from repro.serving.transport import NetworkModel

#: paper §5.1: four token-speed SLO classes (tokens/s)
DEFAULT_SLO_CLASSES = {1: 8.0, 2: 6.0, 3: 4.0, 4: 2.0}


@dataclasses.dataclass
class ServerSession:
    session_id: int
    slot: int
    slo_class: int
    committed_len: int
    alpha: float = 0.6           # EWMA acceptance-rate estimate
    rounds: int = 0
    draft_speed: float = 50.0
    t_draft_last: float = 0.0
    t_net_last: float = 0.0


@dataclasses.dataclass
class Verdict:
    session_id: int
    accept_len: int
    token: int
    emitted: int
    t_queue: float
    t_verify: float
    deadline: float
    violated: bool


class WISPServer:
    def __init__(
        self,
        engine: VerificationEngine,
        coeffs: EstimatorCoeffs,
        *,
        scheduler: str = "slo",          # "slo" | "fcfs"
        sched_cfg: SchedulerConfig | None = None,
        slo_classes: dict | None = None,
        network: NetworkModel | None = None,
        dynamic_memory_budget: bool = True,
        deterministic_verify: bool = True,
    ):
        self.engine = engine
        self.coeffs = coeffs
        self.sched_cfg = sched_cfg or SchedulerConfig()
        cls = SLOScheduler if scheduler == "slo" else FCFSScheduler
        self.scheduler = cls(self.sched_cfg, coeffs)
        self.slo_classes = slo_classes or dict(DEFAULT_SLO_CLASSES)
        self.network = network or NetworkModel()
        #: refresh the scheduler's memory budget from the engine's live
        #: free-page capacity every dispatch epoch (paper Eq. 13's M(t_k));
        #: passed to schedule() as an override — the caller's SchedulerConfig
        #: is never mutated
        self.dynamic_memory_budget = dynamic_memory_budget
        #: key each request's accept/correction draws by (session_id,
        #: committed_len) so verification outcomes do not depend on batch
        #: composition or dispatch order — the event-driven and lock-step
        #: drivers then commit identical streams (see VerifyItem.rng_tag)
        self.deterministic_verify = deterministic_verify
        #: the budget the most recent epoch was admitted against
        self.memory_budget_tokens = self.sched_cfg.memory_budget_tokens
        #: observability: the most recent epoch's ScheduleDecision and the
        #: verify time attributed to it (wall by default; virtual when the
        #: cluster runtime passes ``verify_time`` to ``step``)
        self.last_decision = None
        self.last_verify_time = 0.0
        self.sessions: dict[int, ServerSession] = {}
        self.pending: list[VerifyRequest] = []
        #: sessions the cache could not admit yet: (session_id, prompt,
        #: slo_class, draft_speed, extras), retried each dispatch epoch
        self.admission_queue: list[tuple] = []
        #: (session_id, first_token) of queued sessions admitted since the
        #: last ``pop_admissions()``
        self.admitted: list[tuple[int, int]] = []
        self._rid = 0
        self.log: list[Verdict] = []

    # -- sessions -----------------------------------------------------------
    def _register(self, session_id, slot, first, prompt_len, slo_class,
                  draft_speed) -> int:
        self.sessions[session_id] = ServerSession(
            session_id=session_id,
            slot=slot,
            slo_class=slo_class,
            committed_len=prompt_len + 1,
            draft_speed=draft_speed,
        )
        return first

    def open_session(
        self, session_id: int, prompt_tokens, slo_class: int = 3,
        draft_speed: float = 50.0, extras=None, queue_on_full: bool = True,
    ) -> int | None:
        """Admit a session, or queue it when the engine is out of KV pages
        or slots (returns ``None``; the session is retried each dispatch
        epoch — poll ``pop_admissions()`` for its first token)."""
        try:
            slot, first = self.engine.new_session(prompt_tokens, extras=extras)
        except (OutOfPages, NoFreeSlots):
            if not queue_on_full:
                raise
            self.admission_queue.append(
                (session_id, list(prompt_tokens), slo_class, draft_speed,
                 extras)
            )
            return None
        return self._register(session_id, slot, first, len(prompt_tokens),
                              slo_class, draft_speed)

    def _try_admit(self):
        """Retry queued sessions in arrival order; stop at the first one
        that still does not fit (FIFO fairness — no small-session bypass)."""
        while self.admission_queue:
            sid, prompt, slo_class, draft_speed, extras = self.admission_queue[0]
            try:
                slot, first = self.engine.new_session(prompt, extras=extras)
            except (OutOfPages, NoFreeSlots):
                return
            self.admission_queue.pop(0)
            self._register(sid, slot, first, len(prompt), slo_class,
                           draft_speed)
            self.admitted.append((sid, first))

    def pop_admissions(self) -> list[tuple[int, int]]:
        out, self.admitted = self.admitted, []
        return out

    def close_session(self, session_id: int):
        s = self.sessions.pop(session_id, None)
        if s is None:
            # session may still be waiting in the admission queue: cancel it
            before = len(self.admission_queue)
            self.admission_queue = [
                q for q in self.admission_queue if q[0] != session_id
            ]
            if len(self.admission_queue) == before:
                raise KeyError(session_id)
            return
        # Lifecycle rule (docs/ARCHITECTURE.md §"Session lifecycle"): close
        # drops the session's still-pending verification requests.  Leaving
        # them behind would make a later step() dispatch a request whose
        # session — and engine slot — no longer exist (KeyError at best,
        # verification against a recycled slot at worst).
        self.pending = [r for r in self.pending if r.session_id != session_id]
        self.engine.close_session(s.slot)
        self._try_admit()

    # -- request intake (paper Eq. 6/12: server-side budget -> deadline) ----
    def submit(
        self,
        session_id: int,
        draft_tokens,
        q_logits,
        *,
        now: float,
        t_draft: float,
        t_network: float,
    ) -> int:
        s = self.sessions[session_id]
        s.t_draft_last = t_draft
        s.t_net_last = t_network
        target_speed = self.slo_classes[s.slo_class]
        nd = len(draft_tokens)
        expected_tokens = s.alpha * nd + 1.0
        budget = expected_tokens / target_speed - t_draft - t_network
        budget = max(budget, 1e-3)
        self._rid += 1
        req = VerifyRequest(
            req_id=self._rid,
            session_id=session_id,
            slo_class=s.slo_class,
            arrival=now,
            deadline=now + budget,
            draft_len=nd,
            cached_len=int(self.engine.fed[s.slot]),
            alpha=s.alpha,
            payload=(np.asarray(draft_tokens, np.int32), np.asarray(q_logits)),
            enqueued_at=now,
            round_index=s.rounds,
        )
        self.pending.append(req)
        return self._rid

    # -- dispatch epoch -------------------------------------------------------
    def step(self, now: float, *, verify_time=None) -> list[Verdict]:
        """One dispatch epoch at time ``now``; returns verdicts of the batch.

        ``verify_time``: optional callable mapping the list of served
        VerifyRequests to the verification duration (seconds) to attribute
        to this epoch.  The event-driven cluster runtime passes one driven
        by the estimator (+ optional noise) so queueing/violation accounting
        runs on the virtual clock; by default each verdict carries the
        engine's measured wall time (synchronous CPU drivers)."""
        self._try_admit()
        # M(t_k): live free-page capacity, not a static config number
        self.memory_budget_tokens = (
            self.engine.memory_budget_tokens()
            if self.dynamic_memory_budget
            else self.sched_cfg.memory_budget_tokens
        )
        if not self.pending:
            return []
        decision = self.scheduler.schedule(
            self.pending, now, memory_budget_tokens=self.memory_budget_tokens
        )
        self.last_decision = decision
        if not decision.batch:
            return []
        chosen = {r.req_id for r in decision.batch}
        self.pending = [r for r in self.pending if r.req_id not in chosen]

        items = []
        for r in decision.batch:
            s = self.sessions[r.session_id]
            toks, qlog = r.payload
            items.append(VerifyItem(
                slot=s.slot, draft_tokens=toks, q_logits=qlog,
                rng_tag=(r.session_id, r.cached_len)
                if self.deterministic_verify else None,
            ))
        try:
            served = decision.batch
            outcomes = self.engine.verify(items)
        except OutOfPages:
            # The token budget over-admitted (committed tokens of sessions
            # outside the batch are not page headroom).  Shrink to whatever
            # fits — per-request verification — so the epoch still makes
            # progress instead of requeue-livelocking; requests that cannot
            # fit even alone go back to pending (they need a close_session
            # to free pages).
            served, outcomes = [], []
            for r, it in zip(decision.batch, items):
                try:
                    outcomes.extend(self.engine.verify([it]))
                    served.append(r)
                except OutOfPages:
                    self.pending.append(r)

        dt_virtual = None if verify_time is None else float(verify_time(served))
        self.last_verify_time = (
            dt_virtual if dt_virtual is not None
            else (outcomes[0].t_verify if outcomes else 0.0)
        )
        verdicts = []
        for r, o in zip(served, outcomes):
            s = self.sessions[r.session_id]
            # EWMA acceptance update
            if r.draft_len > 0:
                s.alpha = 0.8 * s.alpha + 0.2 * (o.accept_len / r.draft_len)
            s.rounds += 1
            s.committed_len += o.emitted
            t_queue = max(0.0, now - r.enqueued_at)
            tv = o.t_verify if dt_virtual is None else dt_virtual
            complete = now + tv
            v = Verdict(
                session_id=r.session_id,
                accept_len=o.accept_len,
                token=o.token,
                emitted=o.emitted,
                t_queue=t_queue,
                t_verify=tv,
                deadline=r.deadline,
                violated=complete > r.deadline,
            )
            self.log.append(v)
            verdicts.append(v)
        return verdicts

    @property
    def queue_depth(self) -> int:
        return len(self.pending)
