"""Paged KV cache + prefix cache (paper §4.5, TPU adaptation).

TPU adaptation of PagedAttention (DESIGN.md §2): pages are 256 tokens (vs
vLLM's 16) so each page maps to one DMA-efficient VMEM tile; the paged
attention kernel consumes the block table as a scalar-prefetch operand.

Host-side allocator state (free list, block tables, refcounts, prefix hash
index) is plain Python — it runs on the serving coordinator.  Device arrays
hold the actual pages:

    k_pages, v_pages : (L, n_pages, page_size, Hkv, hd)

The prefix cache is content-addressed at page granularity: a full page of
committed tokens hashes (chained) to a page id; sessions sharing a prompt
prefix map their leading block-table entries to the same pages (copy-on-
write never needed — committed prefixes are immutable).
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np

PAGE_SIZE = 256


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class SeqPages:
    """Block table for one sequence: page ids covering positions
    [0, num_tokens)."""

    pages: list          # [page_id]
    num_tokens: int = 0  # valid tokens

    def capacity(self, page_size=PAGE_SIZE):
        return len(self.pages) * page_size


class PageAllocator:
    """Reference-counted page allocator with a content-addressed prefix
    index (chained page hashes)."""

    def __init__(self, n_pages: int, page_size: int = PAGE_SIZE):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = np.zeros(n_pages, np.int32)
        # prefix cache: chain_hash -> page_id ; page_id -> chain_hash
        self.prefix_index: dict[bytes, int] = {}
        self.page_hash: dict[int, bytes] = {}
        self.hits = 0
        self.misses = 0

    # -- raw alloc ---------------------------------------------------------
    def alloc(self) -> int:
        # evict unreferenced prefix-cached pages lazily when exhausted
        if not self.free:
            self._evict_unreferenced()
        if not self.free:
            raise OutOfPages(f"all {self.n_pages} pages referenced")
        pid = self.free.pop()
        self.refcount[pid] = 1
        return pid

    def retain(self, pid: int):
        self.refcount[pid] += 1

    def release(self, pid: int):
        self.refcount[pid] -= 1
        if self.refcount[pid] <= 0 and pid not in self.page_hash:
            self.refcount[pid] = 0
            self.free.append(pid)
        # hashed pages stay resident (refcount 0) until evicted

    def _evict_unreferenced(self):
        stale = [pid for pid, h in list(self.page_hash.items()) if self.refcount[pid] <= 0]
        for pid in stale:
            h = self.page_hash.pop(pid)
            self.prefix_index.pop(h, None)
            self.refcount[pid] = 0
            self.free.append(pid)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def available(self) -> int:
        """Pages obtainable by an ``alloc()`` right now: the free list plus
        prefix-cached pages no live sequence references (lazily evictable)."""
        evictable = sum(1 for pid in self.page_hash if self.refcount[pid] <= 0)
        return len(self.free) + evictable

    # -- prefix cache ------------------------------------------------------
    @staticmethod
    def chain_hash(prev: bytes, tokens) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(np.asarray(tokens, np.int32).tobytes())
        return h.digest()

    def lookup_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest page-aligned cached prefix of ``tokens``.
        Returns (page_ids, n_cached_tokens); retains the returned pages."""
        pages: list[int] = []
        h = b"root"
        n = 0
        for s in range(0, len(tokens) - self.page_size + 1, self.page_size):
            h = self.chain_hash(h, tokens[s : s + self.page_size])
            pid = self.prefix_index.get(h)
            if pid is None:
                break
            pages.append(pid)
            n += self.page_size
        for pid in pages:
            self.retain(pid)
        if pages:
            self.hits += 1
        else:
            self.misses += 1
        return pages, n

    def publish_prefix(self, tokens, page_ids):
        """Register fully-filled pages of a committed prefix in the index."""
        h = b"root"
        for i, pid in enumerate(page_ids):
            s = i * self.page_size
            if s + self.page_size > len(tokens):
                break
            h = self.chain_hash(h, tokens[s : s + self.page_size])
            if h not in self.prefix_index:
                self.prefix_index[h] = pid
                self.page_hash[pid] = h


class PagedKV:
    """Device-side paged KV arrays + per-sequence block tables."""

    def __init__(
        self,
        n_layers: int,
        n_pages: int,
        n_kv_heads: int,
        head_dim: int,
        *,
        page_size: int = PAGE_SIZE,
        dtype=jnp.bfloat16,
    ):
        self.page_size = page_size
        self.allocator = PageAllocator(n_pages, page_size)
        # Page 0 is reserved as the scratch/sink page: padded batch rows and
        # masked positions scatter their (garbage) K/V here, so real pages
        # are never clobbered by padding.  Block tables also pad with 0, so
        # reads of pad entries land on scratch and are masked by lengths.
        self.scratch_page = self.allocator.alloc()
        assert self.scratch_page == 0, "scratch must be page 0 (pad id)"
        shape = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self.tables: dict[int, SeqPages] = {}

    # -- sequence lifecycle -------------------------------------------------
    def open_seq(self, seq_id: int, prompt_tokens, *, share: bool = True) -> int:
        """Allocate a block table; reuse prefix pages.  Returns number of
        tokens already covered by the prefix cache.

        ``share=False`` skips the prefix lookup entirely — used when KV is
        not a pure function of the token ids (cross-attention families:
        the same prompt under different images/audio has different KV).

        Always leaves at least one prompt token uncovered: prefill logits
        for the final prompt position must be recomputed, and recomputed
        suffix K/V may only be written to pages this sequence owns — so a
        fully-cached, page-aligned prompt gives back its last cached page.
        """
        if not share:
            self.tables[seq_id] = SeqPages(pages=[], num_tokens=0)
            return 0
        pages, n_cached = self.allocator.lookup_prefix(prompt_tokens)
        if n_cached >= len(prompt_tokens) and pages:
            self.allocator.release(pages.pop())
            n_cached -= self.page_size
        self.tables[seq_id] = SeqPages(pages=pages, num_tokens=n_cached)
        return n_cached

    def ensure_capacity(self, seq_id: int, n_tokens: int):
        t = self.tables[seq_id]
        while t.capacity(self.page_size) < n_tokens:
            t.pages.append(self.allocator.alloc())

    def trim_seq(self, seq_id: int):
        """Release pages past the last valid token (speculative rollback:
        K/V written for rejected draft tokens can strand whole tail pages)."""
        t = self.tables[seq_id]
        keep = -(-t.num_tokens // self.page_size)          # ceil
        while len(t.pages) > keep:
            self.allocator.release(t.pages.pop())

    def close_seq(self, seq_id: int, committed_tokens=None):
        t = self.tables.pop(seq_id)
        if committed_tokens is not None:
            self.allocator.publish_prefix(committed_tokens, t.pages)
        for pid in t.pages:
            self.allocator.release(pid)

    def set_len(self, seq_id: int, n: int):
        self.tables[seq_id].num_tokens = n

    def seq_len(self, seq_id: int) -> int:
        return self.tables[seq_id].num_tokens

    def seq_pages(self, seq_id: int) -> int:
        return len(self.tables[seq_id].pages)

    def publish_seq_prefix(self, seq_id: int, tokens):
        """Register the sequence's full pages covering ``tokens`` in the
        prefix index (done right after prompt prefill so *concurrent*
        sessions with the same prompt share pages, not just later ones)."""
        self.allocator.publish_prefix(tokens, self.tables[seq_id].pages)

    # -- memory accounting ---------------------------------------------------
    @property
    def free_tokens(self) -> int:
        """Token capacity obtainable without evicting any live sequence."""
        return self.allocator.available * self.page_size

    def resident_tokens(self, seq_ids=None) -> int:
        """Token capacity already held by the given (default: all) open
        sequences' block tables.  Shared prefix pages count once per
        sharing sequence — that is the prefix cache's capacity gain."""
        tabs = (
            self.tables.values()
            if seq_ids is None
            else [self.tables[s] for s in seq_ids]
        )
        return sum(t.capacity(self.page_size) for t in tabs)

    def committed_tokens(self) -> int:
        """Valid (length-pointer-covered) tokens across open sequences."""
        return sum(t.num_tokens for t in self.tables.values())

    # -- device I/O ----------------------------------------------------------
    def block_table(self, seq_ids, max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 page ids, padded with 0 (masked by lengths)."""
        bt = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            pg = self.tables[sid].pages[:max_pages]
            bt[i, : len(pg)] = pg
        return bt

    def lengths(self, seq_ids) -> np.ndarray:
        return np.array([self.tables[s].num_tokens for s in seq_ids], np.int32)

    def write_tokens(self, seq_id: int, start: int, k_new, v_new):
        """Write K/V for [start, start+T) of one sequence.

        k_new/v_new: (L, T, Hkv, hd).  Functional-update of the page arrays
        (on TPU this is the fused scatter inside the verify kernel; the
        host path keeps semantics identical).
        """
        t = self.tables[seq_id]
        T = k_new.shape[1]
        self.ensure_capacity(seq_id, start + T)
        ps = self.page_size
        o = 0
        while o < T:
            pos = start + o
            pid = t.pages[pos // ps]
            off = pos % ps
            n = min(ps - off, T - o)
            self.k_pages = self.k_pages.at[:, pid, off : off + n].set(
                k_new[:, o : o + n].astype(self.k_pages.dtype)
            )
            self.v_pages = self.v_pages.at[:, pid, off : off + n].set(
                v_new[:, o : o + n].astype(self.v_pages.dtype)
            )
            o += n

    def gather_dense(self, seq_id: int, max_len: int):
        """Materialize (L, max_len, Hkv, hd) dense K/V for one sequence —
        reference/debug path."""
        t = self.tables[seq_id]
        ps = self.page_size
        n_pages_needed = (max_len + ps - 1) // ps
        pads = t.pages[:n_pages_needed] + [0] * (n_pages_needed - len(t.pages))
        idx = np.asarray(pads, np.int32)
        k = self.k_pages[:, idx].reshape(
            self.k_pages.shape[0], -1, *self.k_pages.shape[3:]
        )[:, :max_len]
        v = self.v_pages[:, idx].reshape(
            self.v_pages.shape[0], -1, *self.v_pages.shape[3:]
        )[:, :max_len]
        return k, v
