"""Paged KV cache + prefix cache (paper §4.5, TPU adaptation) with an
optional host-DRAM spill tier (DESIGN.md §12).

TPU adaptation of PagedAttention (DESIGN.md §2): pages are 256 tokens (vs
vLLM's 16) so each page maps to one DMA-efficient VMEM tile; the paged
attention kernel consumes the block table as a scalar-prefetch operand.

Host-side allocator state (free list, block tables, refcounts, prefix hash
index) is plain Python — it runs on the serving coordinator.  Device arrays
hold the actual pages:

    k_pages, v_pages : (L, n_pages, page_size, Hkv, hd)

The prefix cache is content-addressed at page granularity: a full page of
committed tokens hashes (chained) to a page id; sessions sharing a prompt
prefix map their leading block-table entries to the same pages (copy-on-
write never needed — committed prefixes are immutable).

Tiering (DESIGN.md §12): with a `TierConfig`, cold pages spill from the
device pool to a host-memory pool instead of walling admission at
``OutOfPages``.  A page reference is either a device page id (``>= 0``)
or a spilled host handle encoded as ``~handle`` (``< 0``) — the two
states are disjoint by construction, so no page is ever simultaneously
resident and spilled.  Spill victims are chosen prefix-refcount-aware:
unreferenced prefix-cache pages go first (LRU by last-touch epoch), then
private (refcount == 1) pages of sequences idle past
``TierConfig.idle_epochs``; pages reachable from the prefix index with
refcount > 1 (a hot shared system prompt) are pinned and never spill.
Page-in restores the page bytes exactly — the int8 spill format only
quantizes a page when its dequantization round-trips bit-for-bit (raw
fallback otherwise), so a spill/reload cycle can never perturb the
committed stream.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

PAGE_SIZE = 256


class OutOfPages(RuntimeError):
    pass


class PageFault(RuntimeError):
    """A device-side consumer (block table, kernel staging) touched a
    spilled page reference — the engine must ``ensure_resident`` first."""


def is_spilled(ref: int) -> bool:
    """Page references are device ids (``>= 0``) or spilled host handles
    encoded as ``~handle`` (``< 0``)."""
    return ref < 0


@dataclasses.dataclass
class SeqPages:
    """Block table for one sequence: page refs covering positions
    [0, num_tokens).  Entries are device page ids or (tiered pools only)
    spilled ``~handle`` references."""

    pages: list          # [page_ref]
    num_tokens: int = 0  # valid tokens

    def capacity(self, page_size=PAGE_SIZE):
        return len(self.pages) * page_size


# ---------------------------------------------------------------------------
# Host spill tier
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TierConfig:
    """Host-DRAM spill tier under the device page pool (DESIGN.md §12)."""

    #: host pool capacity in pages; 0 disables the tier
    host_pages: int = 0
    #: int8-quantize pages on spill (per-page, per-layer symmetric scales)
    #: — applied only when the dequantization round-trips bit-exactly,
    #: raw fallback otherwise, so reloads never perturb verification
    quantize: bool = False
    #: a sequence is a spill candidate once it has not been touched for
    #: this many allocator epochs (engine dispatches)
    idle_epochs: int = 2


@dataclasses.dataclass
class HostPage:
    """One spilled page: ``(2, L, page_size, Hkv, hd)`` K/V stacked."""

    fmt: str                      # "raw" | "int8"
    data: np.ndarray              # raw page bytes, or int8 codes
    scales: np.ndarray | None     # int8: (2, L) float32 per-(k/v, layer)
    dtype: np.dtype               # page dtype (reconstruction target)
    nbytes: int
    touched: int                  # allocator epoch at spill (host LRU key)
    owner: int | None             # owning seq_id; None = prefix-index only


class TieredPagePool:
    """Host-memory pool of spilled pages.

    ``put`` encodes (int8 when bit-exact and ``quantize`` is on, raw
    otherwise) and returns a monotonically-increasing handle; ``get``
    reconstructs the exact original page bytes.  Capacity is enforced by
    the caller (`PagedKV`) which evicts unreferenced (prefix-only)
    entries LRU before failing a spill.
    """

    def __init__(self, cfg: TierConfig, counters: dict | None = None):
        self.cfg = cfg
        self.entries: dict[int, HostPage] = {}
        self._next = 0
        self.counters = counters if counters is not None else {}
        for key in ("spill_bytes", "pagein_bytes", "pages_spilled",
                    "pages_paged_in", "spills_quantized", "spills_raw",
                    "host_evictions"):
            self.counters.setdefault(key, 0)

    @property
    def in_use(self) -> int:
        return len(self.entries)

    @property
    def free(self) -> int:
        return self.cfg.host_pages - len(self.entries)

    def _encode(self, page: np.ndarray):
        """int8 codes + per-(k/v, layer) scales when the dequantization is
        bit-exact; raw otherwise.  Lossy int8 would perturb target logits
        and flip accept decisions at the margin — incompatible with the
        byte-identity contract the golden battery enforces — so exactness
        is a structural property of the format, not a hope."""
        if self.cfg.quantize:
            amax = np.abs(page).reshape(page.shape[0], page.shape[1], -1) \
                .max(axis=-1)
            scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
            s = scales[:, :, None, None, None]
            codes = np.clip(np.rint(page.astype(np.float32) / s),
                            -127, 127).astype(np.int8)
            recon = (codes.astype(np.float32) * s).astype(page.dtype)
            if recon.tobytes() == page.tobytes():
                return "int8", codes, scales, codes.nbytes + scales.nbytes
        return "raw", page, None, page.nbytes

    def put(self, page: np.ndarray, *, epoch: int, owner: int | None) -> int:
        if self.free <= 0:
            raise OutOfPages(
                f"host spill pool full ({self.cfg.host_pages} pages)"
            )
        fmt, data, scales, nbytes = self._encode(page)
        self._next += 1
        self.entries[self._next] = HostPage(
            fmt=fmt, data=data, scales=scales, dtype=page.dtype,
            nbytes=nbytes, touched=epoch, owner=owner,
        )
        self.counters["pages_spilled"] += 1
        self.counters["spill_bytes"] += nbytes
        self.counters["spills_quantized" if fmt == "int8" else
                       "spills_raw"] += 1
        return self._next

    def get(self, handle: int) -> np.ndarray:
        """Exact reconstruction of the spilled page bytes."""
        e = self.entries[handle]
        self.counters["pages_paged_in"] += 1
        self.counters["pagein_bytes"] += e.nbytes
        if e.fmt == "raw":
            return e.data
        s = e.scales[:, :, None, None, None]
        return (e.data.astype(np.float32) * s).astype(e.dtype)

    def drop(self, handle: int) -> None:
        self.entries.pop(handle, None)


class PageAllocator:
    """Reference-counted page allocator with a content-addressed prefix
    index (chained page hashes) and LRU last-touch tracking.

    ``clock`` is the coarse allocation epoch (the engine ticks it once
    per dispatch); ``last_touch[pid]`` records the epoch a page was last
    allocated or used, which makes eviction (and tier-spill victim
    selection) explicitly LRU instead of dict-iteration order."""

    def __init__(self, n_pages: int, page_size: int = PAGE_SIZE):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = np.zeros(n_pages, np.int32)
        # prefix cache: chain_hash -> page_ref ; page_ref -> chain_hash
        # (refs are device page ids, or ~handle for tier-spilled pages)
        self.prefix_index: dict[bytes, int] = {}
        self.page_hash: dict[int, bytes] = {}
        self.hits = 0
        self.misses = 0
        # LRU bookkeeping
        self.clock = 0
        self.last_touch = np.zeros(n_pages, np.int64)
        #: optional tier hooks installed by a tiered `PagedKV`:
        #: spill_hook(pid) -> ~handle | None (spill an unreferenced
        #: prefix page to the host tier instead of dropping its content);
        #: reclaim_hook(need) -> int (spill cold sequence pages, returns
        #: pages freed)
        self.spill_hook = None
        self.reclaim_hook = None

    # -- LRU clock ---------------------------------------------------------
    def tick(self) -> int:
        self.clock += 1
        return self.clock

    def touch(self, pid: int):
        self.last_touch[pid] = self.clock

    # -- raw alloc ---------------------------------------------------------
    def alloc(self) -> int:
        # reclaim lazily when exhausted: evict/spill unreferenced
        # prefix-cached pages first (LRU), then let the tier spill cold
        # sequence pages — shared (refcount > 1) pages are never touched
        if not self.free:
            self._evict_unreferenced(need=1)
        if not self.free and self.reclaim_hook is not None:
            self.reclaim_hook(1)
        if not self.free:
            raise OutOfPages(f"all {self.n_pages} pages referenced")
        pid = self.free.pop()
        self.refcount[pid] = 1
        self.touch(pid)
        return pid

    def retain(self, pid: int):
        self.refcount[pid] += 1

    def release(self, pid: int):
        self.refcount[pid] -= 1
        if self.refcount[pid] <= 0 and pid not in self.page_hash:
            self.refcount[pid] = 0
            self.free.append(pid)
        # hashed pages stay resident (refcount 0) until evicted

    def _evict_unreferenced(self, need: int | None = None):
        """Evict unreferenced prefix-cached pages in explicit LRU order
        (last-touch epoch, page id as the tie-break) — dict-iteration
        order would make tier-spill ordering depend on insertion history.
        ``need`` bounds the eviction to the pages actually required, so a
        hot prefix entry survives pressure longer than a cold one.  With
        a tier attached the page content is spilled (index entry
        retargeted to the host handle) instead of destroyed."""
        stale = sorted(
            (pid for pid, h in self.page_hash.items()
             if pid >= 0 and self.refcount[pid] <= 0),
            key=lambda pid: (self.last_touch[pid], pid),
        )
        if need is not None:
            stale = stale[:need]
        for pid in stale:
            h = self.page_hash.pop(pid)
            ref = self.spill_hook(pid) if self.spill_hook is not None else None
            if ref is not None:
                self.prefix_index[h] = ref
                self.page_hash[ref] = h
            else:
                self.prefix_index.pop(h, None)
            self.refcount[pid] = 0
            self.free.append(pid)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    @property
    def available(self) -> int:
        """Pages obtainable by an ``alloc()`` right now: the free list plus
        prefix-cached pages no live sequence references (lazily evictable)."""
        evictable = sum(
            1 for pid in self.page_hash
            if pid >= 0 and self.refcount[pid] <= 0
        )
        return len(self.free) + evictable

    # -- prefix cache ------------------------------------------------------
    @staticmethod
    def chain_hash(prev: bytes, tokens) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(prev)
        h.update(np.asarray(tokens, np.int32).tobytes())
        return h.digest()

    def lookup_prefix(self, tokens, *, load_hook=None) -> tuple[list[int], int]:
        """Longest page-aligned cached prefix of ``tokens``.
        Returns (page_ids, n_cached_tokens); retains the returned pages.

        ``load_hook(ref) -> pid | None`` (installed by a tiered `PagedKV`)
        pages a spilled index entry back in; a load failure (device pool
        exhausted) truncates the cached prefix there instead of raising.
        Pages are retained as they are found so a page-in for entry k+1
        cannot evict the (still refcount-0) entry k mid-lookup."""
        pages: list[int] = []
        h = b"root"
        n = 0
        for s in range(0, len(tokens) - self.page_size + 1, self.page_size):
            h = self.chain_hash(h, tokens[s : s + self.page_size])
            pid = self.prefix_index.get(h)
            if pid is None:
                break
            if is_spilled(pid):
                pid = load_hook(pid) if load_hook is not None else None
                if pid is None:
                    break
            self.retain(pid)
            self.touch(pid)
            pages.append(pid)
            n += self.page_size
        if pages:
            self.hits += 1
        else:
            self.misses += 1
        return pages, n

    def publish_prefix(self, tokens, page_ids):
        """Register fully-filled pages of a committed prefix in the index."""
        h = b"root"
        for i, pid in enumerate(page_ids):
            s = i * self.page_size
            if s + self.page_size > len(tokens):
                break
            h = self.chain_hash(h, tokens[s : s + self.page_size])
            if h not in self.prefix_index:
                self.prefix_index[h] = pid
                self.page_hash[pid] = h


class PagedKV:
    """Device-side paged KV arrays + per-sequence block tables, with an
    optional host-DRAM spill tier (``tier=TierConfig(...)``)."""

    def __init__(
        self,
        n_layers: int,
        n_pages: int,
        n_kv_heads: int,
        head_dim: int,
        *,
        page_size: int = PAGE_SIZE,
        dtype=jnp.bfloat16,
        tier: TierConfig | None = None,
        counters: dict | None = None,
    ):
        self.page_size = page_size
        self.allocator = PageAllocator(n_pages, page_size)
        # Page 0 is reserved as the scratch/sink page: padded batch rows and
        # masked positions scatter their (garbage) K/V here, so real pages
        # are never clobbered by padding.  Block tables also pad with 0, so
        # reads of pad entries land on scratch and are masked by lengths.
        self.scratch_page = self.allocator.alloc()
        assert self.scratch_page == 0, "scratch must be page 0 (pad id)"
        shape = (n_layers, n_pages, page_size, n_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self.tables: dict[int, SeqPages] = {}
        # -- host spill tier (DESIGN.md §12) -------------------------------
        self.tier = None
        self.seq_last_used: dict[int, int] = {}
        if tier is not None and tier.host_pages > 0:
            self.tier = TieredPagePool(tier, counters)
            self.allocator.spill_hook = self._spill_index_page
            self.allocator.reclaim_hook = self._reclaim_cold

    @property
    def tiered(self) -> bool:
        return self.tier is not None

    # -- sequence lifecycle -------------------------------------------------
    def open_seq(self, seq_id: int, prompt_tokens, *, share: bool = True) -> int:
        """Allocate a block table; reuse prefix pages.  Returns number of
        tokens already covered by the prefix cache.

        ``share=False`` skips the prefix lookup entirely — used when KV is
        not a pure function of the token ids (cross-attention families:
        the same prompt under different images/audio has different KV).

        Always leaves at least one prompt token uncovered: prefill logits
        for the final prompt position must be recomputed, and recomputed
        suffix K/V may only be written to pages this sequence owns — so a
        fully-cached, page-aligned prompt gives back its last cached page.
        """
        self.seq_last_used[seq_id] = self.allocator.clock
        if not share:
            self.tables[seq_id] = SeqPages(pages=[], num_tokens=0)
            return 0
        pages, n_cached = self.allocator.lookup_prefix(
            prompt_tokens, load_hook=self._load_index_page,
        )
        if n_cached >= len(prompt_tokens) and pages:
            self.allocator.release(pages.pop())
            n_cached -= self.page_size
        self.tables[seq_id] = SeqPages(pages=pages, num_tokens=n_cached)
        return n_cached

    def ensure_capacity(self, seq_id: int, n_tokens: int):
        t = self.tables[seq_id]
        while t.capacity(self.page_size) < n_tokens:
            t.pages.append(self.allocator.alloc())

    def trim_seq(self, seq_id: int):
        """Release pages past the last valid token (speculative rollback:
        K/V written for rejected draft tokens can strand whole tail pages)."""
        t = self.tables[seq_id]
        keep = -(-t.num_tokens // self.page_size)          # ceil
        while len(t.pages) > keep:
            self._release_ref(t.pages.pop())

    def close_seq(self, seq_id: int, committed_tokens=None):
        t = self.tables.pop(seq_id)
        self.seq_last_used.pop(seq_id, None)
        if committed_tokens is not None:
            self.allocator.publish_prefix(committed_tokens, t.pages)
        for ref in t.pages:
            self._release_ref(ref)

    def _release_ref(self, ref: int):
        """Release one block-table entry: a device page drops a refcount;
        a spilled page keeps its host entry only if the prefix index still
        reaches it (orphaned to prefix-only ownership), else it is freed."""
        if not is_spilled(ref):
            self.allocator.release(ref)
            return
        if ref in self.allocator.page_hash:
            self.tier.entries[~ref].owner = None      # prefix-only now
        else:
            self.tier.drop(~ref)

    def set_len(self, seq_id: int, n: int):
        self.tables[seq_id].num_tokens = n

    def seq_len(self, seq_id: int) -> int:
        return self.tables[seq_id].num_tokens

    def seq_pages(self, seq_id: int) -> int:
        return len(self.tables[seq_id].pages)

    def publish_seq_prefix(self, seq_id: int, tokens):
        """Register the sequence's full pages covering ``tokens`` in the
        prefix index (done right after prompt prefill so *concurrent*
        sessions with the same prompt share pages, not just later ones)."""
        self.allocator.publish_prefix(tokens, self.tables[seq_id].pages)

    # -- spill tier (DESIGN.md §12) ------------------------------------------
    def tick(self) -> int:
        """Advance the allocator's LRU epoch (the engine calls this once
        per dispatch — verify batch or prefill pass)."""
        return self.allocator.tick()

    def touch_seq(self, seq_id: int):
        """Mark a sequence (and its resident pages) used this epoch —
        protects it from being chosen as a spill victim by a co-scheduled
        sequence's page-in."""
        self.seq_last_used[seq_id] = self.allocator.clock
        for ref in self.tables[seq_id].pages:
            if not is_spilled(ref):
                self.allocator.touch(ref)

    def _page_bytes(self, pid: int) -> np.ndarray:
        """(2, L, page_size, Hkv, hd) stacked K/V of one device page."""
        return np.asarray(jax.device_get(
            jnp.stack((self.k_pages[:, pid], self.v_pages[:, pid]))
        ))

    def _host_make_room(self) -> bool:
        """Free one host-pool slot by dropping the LRU prefix-only entry
        (entries owned by a live sequence hold unrecoverable state and are
        never dropped)."""
        if self.tier.free > 0:
            return True
        victims = sorted(
            (h for h, e in self.tier.entries.items() if e.owner is None),
            key=lambda h: (self.tier.entries[h].touched, h),
        )
        if not victims:
            return False
        h = victims[0]
        hsh = self.allocator.page_hash.pop(~h, None)
        if hsh is not None:
            self.allocator.prefix_index.pop(hsh, None)
        self.tier.drop(h)
        self.tier.counters["host_evictions"] += 1
        return True

    def _spill_index_page(self, pid: int) -> int | None:
        """Allocator eviction hook: move an unreferenced prefix-cache page
        to the host tier; returns the spilled ``~handle`` reference (or
        None when the host pool is full — the entry is then dropped, the
        untiered behavior)."""
        if not self._host_make_room():
            return None
        handle = self.tier.put(self._page_bytes(pid),
                               epoch=self.allocator.clock, owner=None)
        return ~handle

    def _reclaim_cold(self, need: int) -> int:
        """Allocator exhaustion hook: spill private (refcount == 1) pages
        of sequences idle past ``idle_epochs``, coldest sequence first.
        Shared prefix pages (refcount > 1) are pinned; sequences touched
        this epoch are protected."""
        idle_after = self.tier.cfg.idle_epochs
        clock = self.allocator.clock
        victims = sorted(
            (sid for sid, last in self.seq_last_used.items()
             if sid in self.tables and clock - last >= idle_after),
            key=lambda sid: (self.seq_last_used[sid], sid),
        )
        freed = 0
        for sid in victims:
            if freed >= need:
                break
            freed += self.spill_seq(sid, max_pages=need - freed)
        return freed

    def spill_seq(self, seq_id: int, *, max_pages: int | None = None) -> int:
        """Spill a sequence's private pages to the host tier; returns the
        number of device pages freed.  Pages shared through the prefix
        index (refcount > 1) stay resident — a hot shared system prompt
        never spills."""
        t = self.tables[seq_id]
        freed = 0
        for i, ref in enumerate(t.pages):
            if max_pages is not None and freed >= max_pages:
                break
            if is_spilled(ref) or self.allocator.refcount[ref] != 1:
                continue
            if not self._host_make_room():
                break
            handle = self.tier.put(self._page_bytes(ref),
                                   epoch=self.allocator.clock, owner=seq_id)
            h = self.allocator.page_hash.pop(ref, None)
            if h is not None:       # published page: retarget the index
                self.allocator.prefix_index[h] = ~handle
                self.allocator.page_hash[~handle] = h
            t.pages[i] = ~handle
            self.allocator.refcount[ref] = 0
            self.allocator.free.append(ref)
            freed += 1
        return freed

    def _restore_page(self, handle: int) -> int:
        """Allocate a device page and write the spilled bytes back into
        it, exactly.  Raises OutOfPages when the device pool cannot cover
        it even after reclaiming."""
        page = self.tier.get(handle)
        pid = self.allocator.alloc()
        self.k_pages = self.k_pages.at[:, pid].set(
            jnp.asarray(page[0], dtype=self.k_pages.dtype))
        self.v_pages = self.v_pages.at[:, pid].set(
            jnp.asarray(page[1], dtype=self.v_pages.dtype))
        return pid

    def _load_index_page(self, ref: int) -> int | None:
        """Prefix-lookup hook: page a spilled index entry back in.  The
        restored page re-enters the index as a resident refcount-0 page
        (the caller retains it).  Returns None when the device pool is
        exhausted — the lookup truncates the cached prefix there."""
        handle = ~ref
        try:
            pid = self._restore_page(handle)
        except OutOfPages:
            return None
        h = self.allocator.page_hash.pop(ref, None)
        if h is not None:
            self.allocator.prefix_index[h] = pid
            self.allocator.page_hash[pid] = h
        # swap any live table references (a closed-then-republished page
        # cannot have one, but a refcount-1 published page can)
        for t in self.tables.values():
            for i, r in enumerate(t.pages):
                if r == ref:
                    t.pages[i] = pid
                    self.allocator.retain(pid)
        self.allocator.refcount[pid] -= 1     # alloc's count; owner(s) added
        if self.allocator.refcount[pid] < 0:
            self.allocator.refcount[pid] = 0
        self.tier.drop(handle)
        return pid

    def ensure_resident(self, seq_id: int) -> int:
        """Page every spilled entry of ``seq_id`` back onto the device
        (the engine calls this for each scheduled row before staging the
        block table, so the fused hot path never sees a fault).  Returns
        the number of pages paged in; raises OutOfPages (sequence state
        consistent, resumable) when the device pool cannot cover it."""
        t = self.tables[seq_id]
        self.seq_last_used[seq_id] = self.allocator.clock
        loaded = 0
        for i, ref in enumerate(t.pages):
            if not is_spilled(ref):
                continue
            pid = self._restore_page(~ref)
            h = self.allocator.page_hash.pop(ref, None)
            if h is not None:
                self.allocator.prefix_index[h] = pid
                self.allocator.page_hash[pid] = h
            t.pages[i] = pid
            self.tier.drop(~ref)
            loaded += 1
        return loaded

    def spilled_pages(self, seq_id: int) -> int:
        return sum(1 for r in self.tables[seq_id].pages if is_spilled(r))

    def spilled_tokens(self, seq_id: int) -> int:
        """Token capacity of ``seq_id``'s spilled pages — what a verify
        of this sequence must page in (the scheduler prices this)."""
        return self.spilled_pages(seq_id) * self.page_size

    def spillable_tokens(self) -> int:
        """Token capacity the tier could still free from the device pool:
        unreferenced prefix pages plus private pages of idle sequences,
        capped by host-pool headroom.  Joins the scheduler's live memory
        budget — admission sees through the spill tier."""
        if not self.tiered:
            return 0
        clock = self.allocator.clock
        idle_after = self.tier.cfg.idle_epochs
        cold = 0
        for sid, t in self.tables.items():
            if clock - self.seq_last_used.get(sid, clock) < idle_after:
                continue
            cold += sum(
                1 for r in t.pages
                if not is_spilled(r) and self.allocator.refcount[r] == 1
            )
        # unreferenced prefix pages are already counted by `available`
        # (free_tokens); only cold sequence pages extend the budget here
        headroom = self.tier.free + sum(
            1 for e in self.tier.entries.values() if e.owner is None
        )
        return min(cold, max(headroom, 0)) * self.page_size

    # -- memory accounting ---------------------------------------------------
    @property
    def free_tokens(self) -> int:
        """Token capacity obtainable without evicting any live sequence."""
        return self.allocator.available * self.page_size

    def resident_tokens(self, seq_ids=None) -> int:
        """Token capacity held ON DEVICE by the given (default: all) open
        sequences' block tables.  Shared prefix pages count once per
        sharing sequence — that is the prefix cache's capacity gain.
        Spilled pages are excluded: reloading them consumes free pages,
        so counting them here would double-budget the pool."""
        tabs = (
            self.tables.values()
            if seq_ids is None
            else [self.tables[s] for s in seq_ids]
        )
        return sum(
            sum(1 for r in t.pages if not is_spilled(r)) for t in tabs
        ) * self.page_size

    def committed_tokens(self) -> int:
        """Valid (length-pointer-covered) tokens across open sequences."""
        return sum(t.num_tokens for t in self.tables.values())

    # -- device I/O ----------------------------------------------------------
    def block_table(self, seq_ids, max_pages: int) -> np.ndarray:
        """(B, max_pages) int32 page ids, padded with 0 (masked by lengths)."""
        bt = np.zeros((len(seq_ids), max_pages), np.int32)
        for i, sid in enumerate(seq_ids):
            pg = self.tables[sid].pages[:max_pages]
            if any(is_spilled(r) for r in pg):
                raise PageFault(
                    f"seq {sid} has spilled pages; ensure_resident first"
                )
            bt[i, : len(pg)] = pg
        return bt

    def lengths(self, seq_ids) -> np.ndarray:
        return np.array([self.tables[s].num_tokens for s in seq_ids], np.int32)

    def write_tokens(self, seq_id: int, start: int, k_new, v_new):
        """Write K/V for [start, start+T) of one sequence.

        k_new/v_new: (L, T, Hkv, hd).  Functional-update of the page arrays
        (on TPU this is the fused scatter inside the verify kernel; the
        host path keeps semantics identical).
        """
        if self.tiered:
            self.ensure_resident(seq_id)
        t = self.tables[seq_id]
        T = k_new.shape[1]
        self.ensure_capacity(seq_id, start + T)
        ps = self.page_size
        o = 0
        while o < T:
            pos = start + o
            pid = t.pages[pos // ps]
            off = pos % ps
            n = min(ps - off, T - o)
            self.k_pages = self.k_pages.at[:, pid, off : off + n].set(
                k_new[:, o : o + n].astype(self.k_pages.dtype)
            )
            self.v_pages = self.v_pages.at[:, pid, off : off + n].set(
                v_new[:, o : o + n].astype(self.v_pages.dtype)
            )
            o += n

    def gather_dense(self, seq_id: int, max_len: int):
        """Materialize (L, max_len, Hkv, hd) dense K/V for one sequence —
        reference/debug path."""
        if self.tiered:
            self.ensure_resident(seq_id)
        t = self.tables[seq_id]
        ps = self.page_size
        n_pages_needed = (max_len + ps - 1) // ps
        pads = t.pages[:n_pages_needed] + [0] * (n_pages_needed - len(t.pages))
        idx = np.asarray(pads, np.int32)
        k = self.k_pages[:, idx].reshape(
            self.k_pages.shape[0], -1, *self.k_pages.shape[3:]
        )[:, :max_len]
        v = self.v_pages[:, idx].reshape(
            self.v_pages.shape[0], -1, *self.v_pages.shape[3:]
        )[:, :max_len]
        return k, v
