"""Logical-axis sharding rules (T5X/MaxText style).

Every parameter and major activation in the model zoo is annotated with a
tuple of *logical* axis names (e.g. ``("embed", "mlp")``).  A rule table maps
logical names to mesh axis names.  ``logical_to_spec`` resolves a logical
annotation into a concrete ``PartitionSpec`` against a given mesh, with two
safety properties that make one rule table serve every architecture:

  * **divisibility guard** — a logical axis is only mapped onto a mesh axis
    if the dimension size divides evenly by the mesh axis size (e.g. grok's
    8 KV heads are replicated rather than 16-way sharded);
  * **uniqueness guard** — a mesh axis is consumed at most once per tensor
    (first logical axis in the annotation wins).

Rules may map one logical axis to a *tuple* of mesh axes (e.g. batch over
``("pod", "data")``).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Mapping[str, tuple[str, ...] | str | None]


def _as_tuple(v) -> tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


# ---------------------------------------------------------------------------
# Canonical rule tables.
#
# Parameter logical axes:
#   layers    scan-stacked layer dim                  -> never sharded
#   vocab     embedding / logits vocabulary           -> tensor parallel
#   embed     d_model                                 -> FSDP over data
#   heads     query heads                             -> tensor parallel
#   kv_heads  key/value heads                         -> tensor parallel
#   head_dim  per-head feature                        -> never sharded
#   mlp       FFN hidden                              -> tensor parallel
#   expert    MoE expert count                        -> expert parallel
#   state     SSM/xLSTM recurrent state feature       -> never sharded
#   conv      conv channel (frontends)                -> never sharded
#
# Activation logical axes:
#   act_batch   global batch
#   act_seq     sequence (sequence parallel in train)
#   act_embed   residual stream feature
#   act_heads   attention heads during attention
#   act_kv      kv heads in the cache
#   act_expert  expert dim of dispatched MoE buffers
# ---------------------------------------------------------------------------

TRAIN_RULES: AxisRules = {
    "layers": None,
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "expert": "model",
    "state": None,
    "conv": None,
    "act_batch": ("pod", "data"),
    "act_seq": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_kv": "model",
    "act_cache": None,           # no KV cache in training steps
    "act_expert": "model",
}

# Serving: params keep the same 2D layout (embed over data amortizes HBM for
# very large targets — the all-gather shows up in the roofline and is
# attacked in §Perf).  The KV cache length axis shards over `model`
# (flash-decoding style: partial softmax per shard + small all-reduce) —
# GQA targets have too few KV heads to shard, and the cache dominates HBM
# at decode_32k/long_500k batch sizes.  Prefill keeps sequence parallelism.
SERVE_RULES: AxisRules = {
    **TRAIN_RULES,
    "act_seq": "model",
    "act_cache": "model",
}

# §Perf variant (beyond-paper): parameters replicated across `data`, tensor
# parallel over `model` only.  The FSDP layout above re-all-gathers every
# parameter on EVERY serve step (decode reuses nothing across steps) — the
# dominant collective term in the serve baselines.  Replication trades
# params-HBM (x data) for zero parameter collectives; viable whenever
# params/model_parallel fits HBM (all assigned targets at 16-way TP).
SERVE_RULES_REPLICATED: AxisRules = {
    **SERVE_RULES,
    "embed": None,
}


def logical_to_spec(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
) -> P:
    """Resolve logical axis names into a PartitionSpec for ``shape``."""
    if len(logical_axes) != len(shape):
        raise ValueError(
            f"logical axes {logical_axes} rank != shape {tuple(shape)} rank"
        )
    used: set[str] = set()
    out = []
    for name, dim in zip(logical_axes, shape):
        assigned: tuple[str, ...] = ()
        if name is not None:
            candidates = _as_tuple(rules.get(name))
            picked = []
            prod = 1
            for ax in candidates:
                if ax in used or ax not in mesh.shape:
                    continue
                nxt = prod * mesh.shape[ax]
                if dim % nxt == 0:
                    picked.append(ax)
                    prod = nxt
            assigned = tuple(picked)
            used.update(assigned)
        if len(assigned) == 0:
            out.append(None)
        elif len(assigned) == 1:
            out.append(assigned[0])
        else:
            out.append(assigned)
    # Trim trailing Nones (cosmetic, matches PartitionSpec conventions).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


class ShardCtx:
    """Mesh + rule table threaded through model apply functions.

    ``ShardCtx(None)`` (the default everywhere) makes every constraint a
    no-op, so unit tests and single-device paths never touch mesh state.
    """

    def __init__(self, mesh: Mesh | None = None, rules: AxisRules = TRAIN_RULES):
        self.mesh = mesh
        self.rules = rules

    def cs(self, x, logical_axes):
        if self.mesh is None:
            return x
        spec = logical_to_spec(logical_axes, x.shape, self.mesh, self.rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


NULL_CTX = ShardCtx(None)


def logical_constraint(x, logical_axes, rules: AxisRules, mesh: Mesh | None = None):
    """``with_sharding_constraint`` via logical axes; no-op off-mesh."""
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Mesh | None:
    env_mesh = jax.sharding.get_abstract_mesh()
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    if env_mesh is not None and not env_mesh.empty:  # pragma: no cover
        return None
    return None


def make_param_shardings(param_axes, param_shapes, mesh: Mesh, rules: AxisRules):
    """Map pytrees of logical-axis tuples + shapes -> NamedShardings."""

    def one(axes, shape_like):
        shape = getattr(shape_like, "shape", shape_like)
        return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))

    return jax.tree.map(
        one, param_axes, param_shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
