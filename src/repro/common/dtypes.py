"""Dtype policy: parameter, compute, and accumulation dtypes.

Production TPU training keeps a bf16 copy of parameters for compute with an
f32 optimizer master (see `repro.train.optimizer`); serving is pure bf16.
The policy object is threaded through model code so tests can force f32 for
tight numerical comparisons against oracles.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    # Softmax / norm / router statistics always accumulate in f32.
    accum_dtype: jnp.dtype = jnp.float32

    def cast_compute(self, x):
        return x.astype(self.compute_dtype)

    def cast_accum(self, x):
        return x.astype(self.accum_dtype)


DEFAULT_POLICY = DTypePolicy()
F32_POLICY = DTypePolicy(
    param_dtype=jnp.float32, compute_dtype=jnp.float32, accum_dtype=jnp.float32
)
