"""Switchable scan: ``lax.scan`` in production, python-unrolled for the
dry-run's cost accounting.

XLA's ``HloCostAnalysis`` visits a while-loop body ONCE — it does not
multiply by the trip count — so FLOPs/bytes/collective-bytes of a scanned
layer stack are undercounted by ~n_layers.  The dry-run therefore lowers
with ``cost_unroll`` enabled: every layer/chunk scan becomes straight-line
HLO and the roofline terms are exact.  Production code paths keep
``lax.scan`` (O(1) HLO in depth, fast compiles).

Numerics are identical either way (same math, same order).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

_UNROLL = False

#: only loops with at most this many iterations unroll (layer stacks and
#: short chunk scans).  Longer loops — per-token recurrences (seq_len
#: trips) and the 128-trip SSD chunk scans of the 32k-prefill cells — stay
#: rolled: unrolling them is compile-intractable.  Their cost-analysis
#: shortfall is corrected analytically by the dry-run (see
#: uncounted_sequential_flops and run_cell's chunk-trip scaling).
UNROLL_LIMIT = 32


def cost_unroll_enabled() -> bool:
    return _UNROLL


def set_cost_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


@contextlib.contextmanager
def cost_unroll(value: bool = True):
    prev = _UNROLL
    set_cost_unroll(value)
    try:
        yield
    finally:
        set_cost_unroll(prev)


def _index(xs, i):
    return jax.tree.map(lambda a: a[i], xs, is_leaf=lambda x: x is None)


def scan(f, init, xs, length: int | None = None):
    """Drop-in for ``jax.lax.scan(f, init, xs)`` honoring the unroll flag."""
    if length is None and xs is not None:
        length = jax.tree.leaves(xs)[0].shape[0]
    if not _UNROLL or (length is not None and length > UNROLL_LIMIT):
        return jax.lax.scan(f, init, xs, length=length)
    carry = init
    ys = []
    for i in range(length):
        carry, y = f(carry, _index(xs, i) if xs is not None else None)
        ys.append(y)
    if not ys or all(y is None for y in jax.tree.leaves(ys[0], is_leaf=lambda x: x is None)) and ys[0] is None:
        return carry, None
    stacked = jax.tree.map(
        lambda *zs: None if zs[0] is None else jnp.stack(zs),
        *ys,
        is_leaf=lambda x: x is None,
    )
    return carry, stacked
