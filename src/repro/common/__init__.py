"""Common substrate: dtype policy, logical-axis sharding, pytree helpers."""
from repro.common.sharding import (
    AxisRules,
    TRAIN_RULES,
    SERVE_RULES,
    ShardCtx,
    NULL_CTX,
    logical_to_spec,
    logical_constraint,
    make_param_shardings,
)
from repro.common.dtypes import DTypePolicy, DEFAULT_POLICY

__all__ = [
    "AxisRules",
    "TRAIN_RULES",
    "SERVE_RULES",
    "ShardCtx",
    "NULL_CTX",
    "logical_to_spec",
    "logical_constraint",
    "make_param_shardings",
    "DTypePolicy",
    "DEFAULT_POLICY",
]
