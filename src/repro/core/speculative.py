"""Speculative decoding: the lossless accept/reject rule (paper Eq. 1-3).

Batched, ragged (per-row draft lengths), jit-friendly.

Two correction modes:
  * ``residual`` (default) — Leviathan et al.'s exact rule: on rejection at
    position R the replacement token is sampled from norm(max(p - q, 0)).
    This preserves the target distribution exactly (property-tested).
  * ``target`` — the paper's Eq. (3) as literally written (sample from p
    directly).  Kept for paper-faithful ablations; slightly over-weights
    high-q tokens.
  * ``greedy`` — deterministic: accept iff draft token == argmax(p);
    replacement = argmax.  Used by deterministic tests and greedy serving.

Convention: a verification forward feeds tokens ``[x_last, y_1 .. y_K]``
(K+1 tokens); its output ``p_logits[:, i]`` is the target distribution for
the token at draft index i (0-based), and ``p_logits[:, K]`` is the bonus
distribution after a fully accepted block.

Randomness: with the default ``rng`` alone, accept draws and correction
samples come from one batch-wide key — outcomes then depend on how requests
were batched together.  Passing per-row ``rng_tags`` (B, 2) int32 instead
derives every row's key as ``fold_in(fold_in(rng, tag0), tag1)`` with
per-position scalar draws, making each request's outcome a pure function of
(base seed, tag, tokens, logits) — independent of batch composition, draft-
length bucketing, and dispatch order.  The serving stack tags rows with
(session_id, committed_len) so the event-driven cluster runtime and the
lock-step driver commit identical streams.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _log_softmax(logits, temperature):
    return jax.nn.log_softmax(logits / jnp.maximum(temperature, 1e-6), axis=-1)


def _row_keys(rng, rng_tags):
    """(B,2) int32 tags -> per-row keys, batch-independent."""
    return jax.vmap(
        lambda t: jax.random.fold_in(jax.random.fold_in(rng, t[0]), t[1])
    )(rng_tags)


def _row_uniform(key, K):
    """K accept-draws for one row; draw i depends only on (key, i), never on
    K — so the same request gets the same draws in any draft-length bucket."""
    ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(2, 2 + K, dtype=jnp.int32)
    )
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(ks)


@partial(jax.jit, static_argnames=("method",))
def speculative_verify(
    rng,
    draft_tokens,        # (B, K) int32
    draft_len,           # (B,)   int32, number of valid draft tokens (<= K)
    q_logits,            # (B, K, V) draft-model logits at each draft position
    p_logits,            # (B, K+1, V) target logits (see module docstring)
    *,
    method: str = "residual",
    temperature: float = 1.0,
    rng_tags=None,       # (B, 2) int32 per-row key tags (see module docstring)
):
    """Returns dict with:
      accept_len   (B,)  L = number of accepted draft tokens
      token        (B,)  the correction/bonus token appended after y_{1:L}
      accept_mask  (B,K) which draft positions were accepted
      num_emitted  (B,)  L + 1 (tokens committed this round)
    """
    B, K = draft_tokens.shape
    logq = _log_softmax(q_logits, temperature)                   # (B,K,V)
    logp = _log_softmax(p_logits[:, :K], temperature)            # (B,K,V)
    idx = draft_tokens[..., None]
    logq_tok = jnp.take_along_axis(logq, idx, axis=-1)[..., 0]   # (B,K)
    logp_tok = jnp.take_along_axis(logp, idx, axis=-1)[..., 0]

    pos = jnp.arange(K)[None, :]
    valid = pos < draft_len[:, None]                             # (B,K)

    row_keys = None if rng_tags is None else _row_keys(rng, rng_tags)
    if method == "greedy":
        accept = draft_tokens == jnp.argmax(p_logits[:, :K], axis=-1)
    else:
        if row_keys is None:
            k_unif, rng = jax.random.split(rng)
            u = jax.random.uniform(k_unif, (B, K))
        else:
            u = jax.vmap(lambda k: _row_uniform(k, K))(row_keys)
        accept = jnp.log(u) <= (logp_tok - logq_tok)             # u <= p/q

    accept = jnp.logical_and(accept, valid)
    # first rejection among valid positions
    rejected = jnp.logical_and(jnp.logical_not(accept), valid)
    any_rej = rejected.any(axis=-1)
    first_rej = jnp.argmax(rejected, axis=-1)                    # (B,)
    L = jnp.where(any_rej, first_rej, draft_len)                 # accept len
    # mask acceptances after the first rejection (verification stops there)
    accept_mask = jnp.logical_and(accept, pos < L[:, None])

    # distribution for the correction token at position L (0..K)
    p_at = jnp.take_along_axis(
        p_logits, L[:, None, None], axis=1
    )[:, 0]                                                      # (B, V)
    logp_at = _log_softmax(p_at, temperature)

    def _sample_rows(logits_rows):
        """Correction-token sampling: one batch key, or per-row keys."""
        if row_keys is None:
            nonlocal rng
            k_s, rng = jax.random.split(rng)
            return jax.random.categorical(k_s, logits_rows).astype(jnp.int32)
        return jax.vmap(
            lambda k, lg: jax.random.categorical(jax.random.fold_in(k, 1), lg)
        )(row_keys, logits_rows).astype(jnp.int32)

    if method == "greedy":
        token = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    elif method == "target":
        token = _sample_rows(logp_at)
    else:  # residual
        q_at = jnp.take_along_axis(
            jnp.pad(logq, ((0, 0), (0, 1), (0, 0)), constant_values=-jnp.inf),
            L[:, None, None],
            axis=1,
        )[:, 0]                                                  # (B, V)
        # residual = max(p - q, 0); on bonus rows (L == draft_len) q is -inf
        # padded -> residual == p, exactly the bonus distribution.
        resid = jnp.maximum(jnp.exp(logp_at) - jnp.exp(q_at), 0.0)
        # rows can only be all-zero if p == q elementwise and a rejection
        # happened (prob-0 event up to fp error); fall back to p.
        fallback = resid.sum(-1, keepdims=True) <= 1e-12
        resid = jnp.where(fallback, jnp.exp(logp_at), resid)
        logresid = jnp.log(jnp.maximum(resid, 1e-38))
        token = _sample_rows(logresid)

    return {
        "accept_len": L.astype(jnp.int32),
        "token": token,
        "accept_mask": accept_mask,
        "num_emitted": (L + 1).astype(jnp.int32),
    }


def committed_tokens(draft_tokens, accept_len, token):
    """Assemble the committed block y_{1:L} + correction as a padded (B, K+1)
    array with length accept_len+1 (host-side convenience)."""
    B, K = draft_tokens.shape
    out = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = jax.vmap(lambda row, l, t: row.at[l].set(t))(out, accept_len, token)
    return out


def wasted_tokens(draft_len, accept_len):
    """Paper Eq. (7): W = (K - L)^+ per request."""
    return jnp.maximum(draft_len - accept_len, 0)
