"""Speculative decoding: the lossless accept/reject rule (paper Eq. 1-3).

Batched, ragged (per-row draft lengths), jit-friendly.

Two correction modes:
  * ``residual`` (default) — Leviathan et al.'s exact rule: on rejection at
    position R the replacement token is sampled from norm(max(p - q, 0)).
    This preserves the target distribution exactly (property-tested).
  * ``target`` — the paper's Eq. (3) as literally written (sample from p
    directly).  Kept for paper-faithful ablations; slightly over-weights
    high-q tokens.
  * ``greedy`` — deterministic: accept iff draft token == argmax(p);
    replacement = argmax.  Used by deterministic tests and greedy serving.

Convention: a verification forward feeds tokens ``[x_last, y_1 .. y_K]``
(K+1 tokens); its output ``p_logits[:, i]`` is the target distribution for
the token at draft index i (0-based), and ``p_logits[:, K]`` is the bonus
distribution after a fully accepted block.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _log_softmax(logits, temperature):
    return jax.nn.log_softmax(logits / jnp.maximum(temperature, 1e-6), axis=-1)


@partial(jax.jit, static_argnames=("method",))
def speculative_verify(
    rng,
    draft_tokens,        # (B, K) int32
    draft_len,           # (B,)   int32, number of valid draft tokens (<= K)
    q_logits,            # (B, K, V) draft-model logits at each draft position
    p_logits,            # (B, K+1, V) target logits (see module docstring)
    *,
    method: str = "residual",
    temperature: float = 1.0,
):
    """Returns dict with:
      accept_len   (B,)  L = number of accepted draft tokens
      token        (B,)  the correction/bonus token appended after y_{1:L}
      accept_mask  (B,K) which draft positions were accepted
      num_emitted  (B,)  L + 1 (tokens committed this round)
    """
    B, K = draft_tokens.shape
    logq = _log_softmax(q_logits, temperature)                   # (B,K,V)
    logp = _log_softmax(p_logits[:, :K], temperature)            # (B,K,V)
    idx = draft_tokens[..., None]
    logq_tok = jnp.take_along_axis(logq, idx, axis=-1)[..., 0]   # (B,K)
    logp_tok = jnp.take_along_axis(logp, idx, axis=-1)[..., 0]

    pos = jnp.arange(K)[None, :]
    valid = pos < draft_len[:, None]                             # (B,K)

    if method == "greedy":
        accept = draft_tokens == jnp.argmax(p_logits[:, :K], axis=-1)
    else:
        k_unif, rng = jax.random.split(rng)
        u = jax.random.uniform(k_unif, (B, K))
        accept = jnp.log(u) <= (logp_tok - logq_tok)             # u <= p/q

    accept = jnp.logical_and(accept, valid)
    # first rejection among valid positions
    rejected = jnp.logical_and(jnp.logical_not(accept), valid)
    any_rej = rejected.any(axis=-1)
    first_rej = jnp.argmax(rejected, axis=-1)                    # (B,)
    L = jnp.where(any_rej, first_rej, draft_len)                 # accept len
    # mask acceptances after the first rejection (verification stops there)
    accept_mask = jnp.logical_and(accept, pos < L[:, None])

    # distribution for the correction token at position L (0..K)
    p_at = jnp.take_along_axis(
        p_logits, L[:, None, None], axis=1
    )[:, 0]                                                      # (B, V)
    logp_at = _log_softmax(p_at, temperature)

    if method == "greedy":
        token = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    elif method == "target":
        k_s, rng = jax.random.split(rng)
        token = jax.random.categorical(k_s, logp_at).astype(jnp.int32)
    else:  # residual
        q_at = jnp.take_along_axis(
            jnp.pad(logq, ((0, 0), (0, 1), (0, 0)), constant_values=-jnp.inf),
            L[:, None, None],
            axis=1,
        )[:, 0]                                                  # (B, V)
        # residual = max(p - q, 0); on bonus rows (L == draft_len) q is -inf
        # padded -> residual == p, exactly the bonus distribution.
        resid = jnp.maximum(jnp.exp(logp_at) - jnp.exp(q_at), 0.0)
        # rows can only be all-zero if p == q elementwise and a rejection
        # happened (prob-0 event up to fp error); fall back to p.
        fallback = resid.sum(-1, keepdims=True) <= 1e-12
        resid = jnp.where(fallback, jnp.exp(logp_at), resid)
        logresid = jnp.log(jnp.maximum(resid, 1e-38))
        k_s, rng = jax.random.split(rng)
        token = jax.random.categorical(k_s, logresid).astype(jnp.int32)

    return {
        "accept_len": L.astype(jnp.int32),
        "token": token,
        "accept_mask": accept_mask,
        "num_emitted": (L + 1).astype(jnp.int32),
    }


def committed_tokens(draft_tokens, accept_len, token):
    """Assemble the committed block y_{1:L} + correction as a padded (B, K+1)
    array with length accept_len+1 (host-side convenience)."""
    B, K = draft_tokens.shape
    out = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = jax.vmap(lambda row, l, t: row.at[l].set(t))(out, accept_len, token)
    return out


def wasted_tokens(draft_len, accept_len):
    """Paper Eq. (7): W = (K - L)^+ per request."""
    return jnp.maximum(draft_len - accept_len, 0)
