"""Speculative decoding: the lossless accept/reject rule (paper Eq. 1-3).

Batched, ragged (per-row draft lengths), jit-friendly.

Two correction modes:
  * ``residual`` (default) — Leviathan et al.'s exact rule: on rejection at
    position R the replacement token is sampled from norm(max(p - q, 0)).
    This preserves the target distribution exactly (property-tested).
  * ``target`` — the paper's Eq. (3) as literally written (sample from p
    directly).  Kept for paper-faithful ablations; slightly over-weights
    high-q tokens.
  * ``greedy`` — deterministic: accept iff draft token == argmax(p);
    replacement = argmax.  Used by deterministic tests and greedy serving.

Convention: a verification forward feeds tokens ``[x_last, y_1 .. y_K]``
(K+1 tokens); its output ``p_logits[:, i]`` is the target distribution for
the token at draft index i (0-based), and ``p_logits[:, K]`` is the bonus
distribution after a fully accepted block.

Randomness: with the default ``rng`` alone, accept draws and correction
samples come from one batch-wide key — outcomes then depend on how requests
were batched together.  Passing per-row ``rng_tags`` (B, 2) int32 instead
derives every row's key as ``fold_in(fold_in(rng, tag0), tag1)`` with
per-position scalar draws, making each request's outcome a pure function of
(base seed, tag, tokens, logits) — independent of batch composition, draft-
length bucketing, and dispatch order.  The serving stack tags rows with
(session_id, committed_len) so the event-driven cluster runtime and the
lock-step driver commit identical streams.

Draft-side q representations (DESIGN.md §9)
-------------------------------------------
The accept test only needs ``log q(y_i)`` at the drafted token, and the
residual correction only needs q's distribution at ONE position (the stop
position).  `CompactQ` exploits that: instead of shipping dense ``(K, V)``
q-logits edge->server, a draft sends per-token log-probs (accept test,
**exact**) plus a top-C + tail-mass table per position (residual
reconstruction, bounded error).  Reconstruction spreads the tail mass
uniformly over the V-C non-top tokens, so the rebuilt q̂ satisfies
``||q̂ - q||_1 <= 2·tail`` (top entries are exact; at most ``tail``
probability is misplaced on each side), and the compact residual
distribution is within total-variation ``2·tail / Z`` of the exact one,
where ``Z = sum_v max(p_v - q_v, 0)`` is the exact residual mass at the
stop position (asserted in tests/test_hotpath.py).  Greedy verification
uses no q at all; exact ``residual`` remains available by sending dense
q-logits (the legacy wire format / fallback path).

``verify_epoch_rule`` is the *traceable* core shared by the public jitted
wrappers below and by the verification engine's fused per-epoch programs
(`repro.serving.engine` inlines it after the target forward so accept_len
and the correction token are computed on device and the ``(B, K+1, V)``
target logits never leave it).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _log_softmax(logits, temperature):
    return jax.nn.log_softmax(logits / jnp.maximum(temperature, 1e-6), axis=-1)


def _row_keys(rng, rng_tags):
    """(B,2) int32 tags -> per-row keys, batch-independent."""
    return jax.vmap(
        lambda t: jax.random.fold_in(jax.random.fold_in(rng, t[0]), t[1])
    )(rng_tags)


def _row_uniform(key, K):
    """K accept-draws for one row; draw i depends only on (key, i), never on
    K — so the same request gets the same draws in any draft-length bucket."""
    ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(2, 2 + K, dtype=jnp.int32)
    )
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(ks)


# ---------------------------------------------------------------------------
# compact draft-side q representation (wire format)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CompactQ:
    """Compact per-block draft distribution statistics (host-side numpy).

    ``logq_tok`` is exact — the accept test with CompactQ is bit-identical
    to the dense path.  ``top_idx``/``top_logq``/``tail`` reconstruct q̂
    for the residual correction within the bound documented in the module
    docstring.  The whole structure is O(K·C) instead of O(K·V)."""

    logq_tok: np.ndarray    # (k,)    float32: log q(y_i) at each draft token
    top_idx: np.ndarray     # (k, C)  int32:   top-C token ids per position
    top_logq: np.ndarray    # (k, C)  float32: their log-probs
    tail: np.ndarray        # (k,)    float32: prob mass outside the top-C

    @property
    def k(self) -> int:
        return int(self.logq_tok.shape[0])

    @property
    def C(self) -> int:
        return int(self.top_idx.shape[-1]) if self.top_idx.ndim == 2 else 0

    def wire_bytes(self) -> int:
        """Modelled uplink payload: per drafted token a float32 token
        log-prob, C (id: 4B + logit: 2B) table entries, and a float16 tail
        mass."""
        return self.k * (4 + self.C * 6 + 2)


@partial(jax.jit, static_argnames=("C",))
def _compact_q_kernel(logits, tokens, *, C: int):
    """(k, V) raw draft logits + (k,) drafted tokens -> compact stats.
    Runs on device so only O(k·C) crosses to the host."""
    logq = jax.nn.log_softmax(logits, axis=-1)
    logq_tok = jnp.take_along_axis(logq, tokens[:, None], axis=-1)[:, 0]
    top_logq, top_idx = jax.lax.top_k(logq, C)
    tail = jnp.maximum(1.0 - jnp.exp(top_logq).sum(-1), 0.0)
    return logq_tok, top_idx.astype(jnp.int32), top_logq, tail


def compact_from_logits(logits, tokens, C: int = 64) -> CompactQ:
    """Build a `CompactQ` from raw draft logits ``(k, V)`` and the drafted
    token ids ``(k,)``.  Always at temperature 1.0 — the verification rule
    softmaxes raw q-logits at 1.0, and the compact stats must describe the
    same distribution the dense path would."""
    toks = jnp.asarray(np.asarray(tokens, np.int32))
    lt, ti, tl, ta = _compact_q_kernel(jnp.asarray(logits), toks, C=C)
    lt, ti, tl, ta = jax.device_get((lt, ti, tl, ta))
    return CompactQ(
        logq_tok=np.asarray(lt, np.float32),
        top_idx=np.asarray(ti, np.int32),
        top_logq=np.asarray(tl, np.float32),
        tail=np.asarray(ta, np.float32),
    )


def stack_compact(items: list[CompactQ], nb: int, K: int, C: int,
                  *, pad_idx: int = 1 << 30):
    """Stack per-row CompactQ blocks into padded (nb, ...) batch arrays.
    Unused table cells get ``pad_idx`` — an out-of-vocab id whose scatter
    update is dropped during reconstruction (see `residual_qhat_compact`:
    an in-bounds pad would collide with a real top entry)."""
    logq_tok = np.zeros((nb, K), np.float32)
    top_idx = np.full((nb, K, C), pad_idx, np.int32)
    top_logq = np.full((nb, K, C), -30.0, np.float32)
    tail = np.zeros((nb, K), np.float32)
    for i, q in enumerate(items):
        k, c = q.k, q.C
        logq_tok[i, :k] = q.logq_tok
        top_idx[i, :k, :c] = q.top_idx
        top_logq[i, :k, :c] = q.top_logq
        tail[i, :k] = q.tail
    return logq_tok, top_idx, top_logq, tail


# ---------------------------------------------------------------------------
# traceable core (shared by the jitted wrappers and the engine's fused
# per-epoch programs)
# ---------------------------------------------------------------------------


def accept_draws(rng, B: int, K: int, method: str, rng_tags):
    """The accept-test uniforms and per-row keys.  Key-consumption order is
    part of the stream contract: row keys derive from the UNSPLIT rng;
    the batch-wide path splits once for the draws (greedy draws nothing)."""
    row_keys = None if rng_tags is None else _row_keys(rng, rng_tags)
    if method == "greedy":
        return None, row_keys, rng
    if row_keys is None:
        k_unif, rng = jax.random.split(rng)
        u = jax.random.uniform(k_unif, (B, K))
    else:
        u = jax.vmap(lambda k: _row_uniform(k, K))(row_keys)
    return u, row_keys, rng


def accept_length(accept, valid, draft_len):
    """First-rejection semantics: L per row + the masked accept positions."""
    K = accept.shape[1]
    pos = jnp.arange(K)[None, :]
    rejected = jnp.logical_and(jnp.logical_not(accept), valid)
    any_rej = rejected.any(axis=-1)
    first_rej = jnp.argmax(rejected, axis=-1)
    L = jnp.where(any_rej, first_rej, draft_len)
    accept_mask = jnp.logical_and(accept, pos < L[:, None])
    return L, accept_mask


def residual_qhat_dense(logq, L):
    """q probabilities at the stop position from dense (B,K,V) log-q.

    Bonus rows with L == K gather the appended -inf pad row -> q̂ = 0 ->
    residual == p, exactly the bonus distribution.  Bonus rows with
    L == draft_len < K gather whatever the CALLER staged at position
    draft_len: the engine's dense staging fills those positions with a
    -30.0 constant, whose softmax is the uniform distribution — so such
    bonus tokens sample from norm(max(p - 1/V, 0)), a small bias
    inherited from the seed engine and pinned by the golden-stream suite
    (the compact path's out-of-vocab pads yield q̂ ≈ 0 there, i.e. the
    exact bonus rule; fixing dense to match means regenerating the
    goldens in a behavior-change PR, not a refactor PR)."""
    q_at = jnp.take_along_axis(
        jnp.pad(logq, ((0, 0), (0, 1), (0, 0)), constant_values=-jnp.inf),
        L[:, None, None],
        axis=1,
    )[:, 0]
    return jnp.exp(q_at)


def residual_qhat_compact(top_idx, top_logq, tail, L, V: int):
    """Reconstructed q̂ probabilities at the stop position from the top-C +
    tail table: exact on the top-C ids, tail mass spread uniformly over the
    V-C others (``||q̂ - q||_1 <= 2·tail``; module docstring).  Bonus rows
    gather the out-of-bounds pad row, whose scatter updates XLA drops ->
    q̂ = 0 -> residual == p, exact.  Unused table columns (a block whose
    own C is narrower than the batch bucket) MUST carry index >= V — an
    in-bounds pad id would collide with that token's real entry in the
    scatter, where XLA leaves the duplicate winner unspecified."""
    C = top_idx.shape[-1]
    pad_i = jnp.pad(top_idx, ((0, 0), (0, 1), (0, 0)), constant_values=V)
    pad_l = jnp.pad(top_logq, ((0, 0), (0, 1), (0, 0)),
                    constant_values=-jnp.inf)
    pad_t = jnp.pad(tail, ((0, 0), (0, 1)))
    sel = L[:, None, None]
    idx_L = jnp.take_along_axis(pad_i, sel, axis=1)[:, 0]          # (B, C)
    logq_L = jnp.take_along_axis(pad_l, sel, axis=1)[:, 0]         # (B, C)
    tail_L = jnp.take_along_axis(pad_t, L[:, None], axis=1)[:, 0]  # (B,)
    uniform = tail_L / max(V - C, 1)
    base = jnp.broadcast_to(uniform[:, None], (L.shape[0], V))
    return jax.vmap(lambda q, i, v: q.at[i].set(v))(
        base, idx_L, jnp.exp(logq_L)
    )


def correction_token(rng, row_keys, p_at, qhat, *, method, temperature):
    """Sample/select the correction token from the RAW target logits at the
    stop position.  ``qhat``: q probabilities there (residual mode only).
    Returns (token, rng) — rng advanced only on the batch-wide path."""
    logp_at = _log_softmax(p_at, temperature)

    def _sample_rows(logits_rows):
        nonlocal rng
        if row_keys is None:
            k_s, rng = jax.random.split(rng)
            return jax.random.categorical(k_s, logits_rows).astype(jnp.int32)
        return jax.vmap(
            lambda k, lg: jax.random.categorical(jax.random.fold_in(k, 1), lg)
        )(row_keys, logits_rows).astype(jnp.int32)

    if method == "greedy":
        return jnp.argmax(p_at, axis=-1).astype(jnp.int32), rng
    if method == "target":
        return _sample_rows(logp_at), rng
    # residual = max(p - q̂, 0); rows can only be all-zero if p == q̂
    # elementwise and a rejection happened (prob-0 event up to fp error);
    # fall back to p.
    resid = jnp.maximum(jnp.exp(logp_at) - qhat, 0.0)
    fallback = resid.sum(-1, keepdims=True) <= 1e-12
    resid = jnp.where(fallback, jnp.exp(logp_at), resid)
    logresid = jnp.log(jnp.maximum(resid, 1e-38))
    return _sample_rows(logresid), rng


def verify_epoch_rule(
    rng,
    draft_tokens,            # (B, K) int32
    draft_len,               # (B,)   int32
    p_logits,                # (B, K+1, V) raw target logits
    *,
    method: str = "residual",
    temperature: float = 1.0,
    rng_tags=None,
    q_logits=None,           # dense (B, K, V) draft logits (exact residual)
    logq_tok=None,           # compact: (B, K) exact token log-probs
    top_idx=None,            # compact: (B, K, C)
    top_logq=None,           # compact: (B, K, C)
    tail=None,               # compact: (B, K)
):
    """The full accept/reject + correction rule, traceable (inline it into
    a larger jit program).  q comes in exactly one representation: dense
    ``q_logits``, compact (``logq_tok`` + table), or nothing (greedy)."""
    B, K = draft_tokens.shape
    if (q_logits is None and logq_tok is not None
            and method != "greedy" and temperature != 1.0):
        # compact statistics are built at temperature 1.0
        # (`_compact_q_kernel`); rescaling only the target side would make
        # the accept test compare p^(1/T) against unscaled q — silently
        # not min(1, p/q).  Dense q rescales both sides, so only the
        # compact path must refuse.
        raise ValueError(
            "compact q statistics support temperature=1.0 only "
            "(send dense q_logits to verify at other temperatures)"
        )
    logq = None
    if q_logits is not None:
        logq = _log_softmax(q_logits, temperature)
        logq_tok = jnp.take_along_axis(
            logq, draft_tokens[..., None], axis=-1
        )[..., 0]

    pos = jnp.arange(K)[None, :]
    valid = pos < draft_len[:, None]

    u, row_keys, rng = accept_draws(rng, B, K, method, rng_tags)
    if method == "greedy":
        accept = draft_tokens == jnp.argmax(p_logits[:, :K], axis=-1)
    else:
        if logq_tok is None:
            raise ValueError(f"method {method!r} needs draft q statistics")
        logp = _log_softmax(p_logits[:, :K], temperature)
        logp_tok = jnp.take_along_axis(
            logp, draft_tokens[..., None], axis=-1
        )[..., 0]
        accept = jnp.log(u) <= (logp_tok - logq_tok)         # u <= p/q
    accept = jnp.logical_and(accept, valid)
    L, accept_mask = accept_length(accept, valid, draft_len)

    p_at = jnp.take_along_axis(p_logits, L[:, None, None], axis=1)[:, 0]
    qhat = None
    if method == "residual":
        if logq is not None:
            qhat = residual_qhat_dense(logq, L)
        elif top_idx is not None:
            qhat = residual_qhat_compact(
                top_idx, top_logq, tail, L, p_logits.shape[-1]
            )
        else:
            raise ValueError("residual mode needs dense or compact q")
    token, rng = correction_token(
        rng, row_keys, p_at, qhat, method=method, temperature=temperature
    )
    return {
        "accept_len": L.astype(jnp.int32),
        "token": token,
        "accept_mask": accept_mask,
        "num_emitted": (L + 1).astype(jnp.int32),
    }


# ---------------------------------------------------------------------------
# public jitted wrappers
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("method", "temperature"))
def speculative_verify(
    rng,
    draft_tokens,        # (B, K) int32
    draft_len,           # (B,)   int32, number of valid draft tokens (<= K)
    q_logits,            # (B, K, V) draft-model logits at each draft position
    p_logits,            # (B, K+1, V) target logits (see module docstring)
    *,
    method: str = "residual",
    temperature: float = 1.0,
    rng_tags=None,       # (B, 2) int32 per-row key tags (see module docstring)
):
    """Returns dict with:
      accept_len   (B,)  L = number of accepted draft tokens
      token        (B,)  the correction/bonus token appended after y_{1:L}
      accept_mask  (B,K) which draft positions were accepted
      num_emitted  (B,)  L + 1 (tokens committed this round)
    """
    return verify_epoch_rule(
        rng, draft_tokens, draft_len, p_logits,
        method=method, temperature=temperature, rng_tags=rng_tags,
        q_logits=q_logits,
    )


@partial(jax.jit, static_argnames=("method", "temperature"))
def speculative_verify_compact(
    rng,
    draft_tokens,        # (B, K) int32
    draft_len,           # (B,)   int32
    logq_tok,            # (B, K)    exact draft token log-probs
    top_idx,             # (B, K, C) top-C ids per draft position
    top_logq,            # (B, K, C) their log-probs
    tail,                # (B, K)    tail mass per position
    p_logits,            # (B, K+1, V) target logits
    *,
    method: str = "residual",
    temperature: float = 1.0,
    rng_tags=None,
):
    """`speculative_verify` over the compact wire format: accept decisions
    (and greedy entirely) are exact; residual correction is within the
    documented TV bound of the dense rule."""
    return verify_epoch_rule(
        rng, draft_tokens, draft_len, p_logits,
        method=method, temperature=temperature, rng_tags=rng_tags,
        logq_tok=logq_tok, top_idx=top_idx, top_logq=top_logq, tail=tail,
    )


def committed_tokens(draft_tokens, accept_len, token):
    """Assemble the committed block y_{1:L} + correction as a padded (B, K+1)
    array with length accept_len+1 (host-side convenience)."""
    B, K = draft_tokens.shape
    out = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = jax.vmap(lambda row, l, t: row.at[l].set(t))(out, accept_len, token)
    return out


def wasted_tokens(draft_len, accept_len):
    """Paper Eq. (7): W = (K - L)^+ per request."""
    return jnp.maximum(draft_len - accept_len, 0)
