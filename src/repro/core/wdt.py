"""Wasted Drafting Time accounting (paper §3.2, Eq. 7-10)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class IterationLog:
    """One speculate-verify iteration of one session."""

    session_id: int
    round_index: int
    n_drafted: int           # K: tokens the edge physically drafted
    n_sent: int              # tokens submitted for verification
    n_accepted: int          # L
    n_committed: int         # L + 1 (with correction/bonus token)
    t_draft: float
    t_network: float
    t_queue: float
    t_verify: float
    deadline: float = 0.0
    slo_class: int = 0
    violated: bool = False
    #: the speculation controller's draft-length cap for this block
    #: (DESIGN.md §11); with no predictor n_drafted == k_used, so the
    #: per-round sequence of these IS the committed-prefix oracle's
    #: replay schedule (serving/oracle.py).  0 on legacy paths.
    k_used: int = 0

    @property
    def wasted(self) -> int:
        """W = (K - L)^+  (Eq. 7)."""
        return max(0, self.n_drafted - self.n_accepted)

    @property
    def t_total(self) -> float:
        return self.t_draft + self.t_network + self.t_queue + self.t_verify

    @property
    def token_speed(self) -> float:
        """Achieved committed tokens/s for this iteration (Eq. 4)."""
        return self.n_committed / max(self.t_total, 1e-9)

    def wdt(self, tau_d: float) -> float:
        """T_wdt = tau_d * W  (Eq. 8)."""
        return tau_d * self.wasted


@dataclasses.dataclass
class WDTStats:
    iterations: int = 0
    drafted: int = 0
    sent: int = 0
    accepted: int = 0
    committed: int = 0
    wasted: int = 0
    t_draft: float = 0.0
    t_wdt: float = 0.0
    t_queue: float = 0.0
    t_verify: float = 0.0
    t_network: float = 0.0
    violations: int = 0

    def add(self, it: IterationLog, tau_d: float):
        self.iterations += 1
        self.drafted += it.n_drafted
        self.sent += it.n_sent
        self.accepted += it.n_accepted
        self.committed += it.n_committed
        self.wasted += it.wasted
        self.t_draft += it.t_draft
        self.t_wdt += it.wdt(tau_d)
        self.t_queue += it.t_queue
        self.t_verify += it.t_verify
        self.t_network += it.t_network
        self.violations += int(it.violated)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.sent, 1)

    @property
    def waste_fraction(self) -> float:
        return self.wasted / max(self.drafted, 1)

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.iterations, 1)

    def goodput(self, wall_time: float) -> float:
        """Committed tokens per second of wall time."""
        return self.committed / max(wall_time, 1e-9)
