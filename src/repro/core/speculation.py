"""Per-session adaptive speculation control: dynamic draft length K.

The paper's "intelligent speculation controller" (§4.1) has two halves.
`core/controller.py` implements the *within-block* half — stop drafting
at the first predicted rejection.  This module implements the
*between-block* half: choose the next block's draft-length cap K from
the session's measured signals, so K tracks device/link heterogeneity
instead of being one static constant per run (SpecEdge's observation:
the edge-assisted goodput win lives in adapting K).

A `SpeculationController` is per-session edge-side state behind a
registry (mirroring the `SchedulingPolicy` registry in
`core/scheduler.py`).  The drive loop is::

    k = ctl.next_k()                       # cap for the next block
    ... draft (<= k tokens), submit, await verdict ...
    ctl.observe(accept_len=.., k_used=.., rtt=.., queue_depth=..)

Signals the adaptive law consumes, all EWMA-smoothed:

  * **acceptance** — the measured accept fraction of each verified
    block, or the predictor's calibrated per-token accept probability
    when one rides along (``p_accept``);
  * **round-trip time** — draft uplink + verdict downlink: a long link
    amortizes more drafting per round (the per-round fixed cost is paid
    either way);
  * **verifier load** — the server's pending-pool depth piggybacked on
    each verdict (`Verdict.queue_depth`): a saturated verifier rejects
    deep blocks' tail tokens anyway (batch slots are contended), so
    back off K and cut Wasted Drafting Time.

The chosen K is always clamped to ``[1, k_max]`` and slew-limited to
one step per observation (hysteresis) so K never thrashes on noise.

Determinism note (DESIGN.md §11): block boundaries feed the
verification rng keys ``(session_id, committed_len)``, so an adaptive
run's streams lawfully differ from a static-K run's — but each is a
pure function of its config, and equals a solo lock-step replay of the
same per-round K schedule (`serving/oracle.py`, the committed-prefix
oracle the `benchmarks/adaptive_k.py` gate checks byte-for-byte).
"""
from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# Registry (same shape as core/scheduler.py's policy registry)
# ---------------------------------------------------------------------------

SPEC_POLICIES: dict[str, type] = {}


def register_spec_policy(name: str, *aliases: str):
    """Class decorator: register a `SpeculationController` under ``name``
    (and aliases).  Sets ``cls.name`` to the canonical name."""

    def deco(cls):
        cls.name = name
        for n in (name, *aliases):
            SPEC_POLICIES[n] = cls
        return cls

    return deco


def available_spec_policies() -> list[str]:
    """Canonical registered names, sorted (aliases excluded)."""
    return sorted({cls.name for cls in SPEC_POLICIES.values()})


def make_spec_controller(policy="static", *, k_max: int = 8,
                         draft_speed: float = 50.0, predictor=None,
                         **kwargs) -> "SpeculationController":
    """Resolve ``policy`` (name, class, or ready instance) into a
    controller bound to one session's parameters."""
    if isinstance(policy, SpeculationController):
        return policy
    if isinstance(policy, type) and issubclass(policy, SpeculationController):
        cls = policy
    else:
        try:
            cls = SPEC_POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown speculation policy {policy!r}; "
                f"available: {available_spec_policies()}"
            ) from None
    return cls(k_max=k_max, draft_speed=draft_speed, predictor=predictor,
               **kwargs)


# ---------------------------------------------------------------------------
# Base + policies
# ---------------------------------------------------------------------------


class SpeculationController:
    """Chooses each block's draft-length cap for ONE session stream.

    Besides the per-policy ``next_k`` law, every controller carries the
    **link-health half of graceful degradation** (DESIGN.md §14): the
    driver reports each round's link outcome via ``observe_link`` (ok =
    a verdict landed; not-ok = a retry timeout; ``down`` = the runtime
    declared the link down after ``link_down_after`` consecutive
    timeouts), the controller EWMA-smooths it into ``link_health`` and,
    when ``degrade`` is enabled, ``choose_k`` shrinks the policy's K
    under flap and falls back to K=1 (one draft token per round — the
    server-side-decode floor) while the link is down.  Recovery is
    hysteretic: the down latch clears only after ``recover_streak``
    consecutive ok rounds AND health back above ``recover_above``, so K
    never thrashes across a flapping boundary.  With ``degrade`` off
    (the default) ``choose_k`` is exactly ``next_k`` — static-policy
    streams stay byte-identical to the fault-free run."""

    name = "base"

    def __init__(self, *, k_max: int = 8, draft_speed: float = 50.0,
                 predictor=None, degrade: bool = False,
                 link_ema: float = 0.35, degrade_below: float = 0.7,
                 recover_above: float = 0.9, recover_streak: int = 2, **_):
        self.k_max = max(1, int(k_max))
        self.draft_speed = float(draft_speed)
        self.predictor = predictor
        # -- link-health degradation law (DESIGN.md §14) -------------------
        self.degrade = bool(degrade)
        self.link_ema = float(link_ema)
        self.degrade_below = float(degrade_below)
        self.recover_above = float(recover_above)
        self.recover_streak = max(1, int(recover_streak))
        self.link_health = 1.0
        self.link_down = False
        self._ok_streak = 0
        #: the most recent ``choose_k`` shrank K below the policy's pick
        #: (or pinned the K=1 down-mode floor) — the runtime's
        #: degraded-round counter reads this
        self.degraded_last = False

    def start_session(self) -> None:
        """Reset any per-stream state (a device reuses its controller
        across churned sessions).  Link health deliberately survives —
        it is a property of the device's LINK, not of one session."""

    def next_k(self) -> int:
        """Draft-length cap for the next block, in ``[1, k_max]``."""
        raise NotImplementedError

    # -- link health + graceful degradation (DESIGN.md §14) ----------------
    def observe_link(self, ok: bool, *, down: bool = False) -> None:
        """Feed one link outcome: ``ok`` when a verdict reached the
        device, not-ok when a round timed out.  ``down=True`` latches
        hard-down mode (the runtime asserts it after
        ``link_down_after`` consecutive timeouts)."""
        self.link_health = ((1.0 - self.link_ema) * self.link_health
                            + self.link_ema * (1.0 if ok else 0.0))
        if ok:
            self._ok_streak += 1
            if (self.link_down and self._ok_streak >= self.recover_streak
                    and self.link_health >= self.recover_above):
                self.link_down = False
        else:
            self._ok_streak = 0
            if down:
                self.link_down = True

    def choose_k(self) -> int:
        """The policy's ``next_k``, degraded by link health when enabled:
        K=1 while the link is down (server-side decode — one draft token
        still carries the round, the verifier's bonus token does the
        committing), K scaled by the health EWMA under flap.  Identical
        to ``next_k`` when ``degrade`` is off."""
        k = self.next_k()
        self.degraded_last = False
        if not self.degrade:
            return k
        if self.link_down:
            self.degraded_last = True
            return 1
        if self.link_health < self.degrade_below:
            shrunk = max(1, min(k, int(math.ceil(k * self.link_health))))
            self.degraded_last = shrunk < k
            return shrunk
        return k

    def observe(self, *, accept_len: int = 0, k_used: int = 0,
                p_accept: float | None = None, rtt: float | None = None,
                queue_depth: float | None = None) -> None:
        """Feed back one verified round's signals (all optional — a
        driver reports what it measures)."""

    # -- migration (fleet failover carries controller state along) ---------
    def state(self) -> dict:
        """Serializable per-session state for migration hand-off."""
        return {}

    def load_state(self, state: dict) -> None:
        pass


@register_spec_policy("static", "fixed")
class StaticSpecController(SpeculationController):
    """The pre-adaptive behavior: every block gets the full ``k_max``
    budget (within-block early stop still applies via the predictor)."""

    def next_k(self) -> int:
        return self.k_max


@register_spec_policy("scripted", "schedule")
class ScriptedSpecController(SpeculationController):
    """Replay a fixed per-block K schedule — the committed-prefix
    oracle's controller (`serving/oracle.py`) and a test fixture.
    Past the schedule's end the last entry holds."""

    def __init__(self, *, schedule=(), k_max: int = 8, **kw):
        super().__init__(k_max=k_max, **kw)
        self.schedule = [int(k) for k in schedule]
        self._i = 0

    def start_session(self) -> None:
        self._i = 0

    def next_k(self) -> int:
        if not self.schedule:
            return self.k_max
        k = self.schedule[min(self._i, len(self.schedule) - 1)]
        self._i += 1
        return max(1, min(int(k), self.k_max))

    def state(self) -> dict:
        return {"i": self._i}

    def load_state(self, state: dict) -> None:
        self._i = int(state.get("i", 0))


@register_spec_policy("adaptive", "dynamic")
class AdaptiveSpecController(SpeculationController):
    """The control law (DESIGN.md §11).  Per verified block, with
    EWMA-smoothed acceptance ``p``, RTT ``r`` and verifier queue depth
    ``q``::

        k_p     = max k with p^k >= gain_floor      (acceptance term)
        k_rtt   = round(r * draft_speed * rtt_gain) (link-amortization)
        k_load  = floor(q / load_scale)             (congestion brake)
        target  = clamp(k_p + k_rtt - k_load, 1, k_max)
        k      <- k + sign(target - k)              (slew-limit: hysteresis)

    Intuition: ``p^k`` is the probability a depth-k block fully accepts;
    drafting past the depth where that falls under ``gain_floor`` is
    expected waste (Wasted Drafting Time, Eq. 7-8).  A slow link raises
    the fixed per-round cost, so deeper blocks amortize it (SpecEdge);
    a deep verifier queue means extra drafted tokens mostly wait to be
    rejected, so back off.  The one-step slew limit plus EWMA smoothing
    is the hysteresis that keeps K from thrashing between rounds.
    """

    def __init__(self, *, k_max: int = 8, draft_speed: float = 50.0,
                 predictor=None, alpha0: float = 0.6, ema: float = 0.3,
                 gain_floor: float = 0.35, rtt_gain: float = 0.5,
                 load_scale: float = 4.0, k0: int | None = None, **kw):
        super().__init__(k_max=k_max, draft_speed=draft_speed,
                         predictor=predictor, **kw)
        self.alpha0 = float(alpha0)
        self.ema = float(ema)
        self.gain_floor = float(gain_floor)
        self.rtt_gain = float(rtt_gain)
        self.load_scale = max(1e-6, float(load_scale))
        self._k0 = self.k_max if k0 is None else max(1, min(int(k0), self.k_max))
        self.start_session()

    def start_session(self) -> None:
        self.alpha = self.alpha0
        self.rtt = 0.0
        self.load = 0.0
        self.k = self._k0

    def _ewma(self, old: float, new: float) -> float:
        return (1.0 - self.ema) * old + self.ema * new

    def observe(self, *, accept_len: int = 0, k_used: int = 0,
                p_accept: float | None = None, rtt: float | None = None,
                queue_depth: float | None = None) -> None:
        # acceptance: prefer the predictor's calibrated estimate when the
        # driver passes one; fall back to the measured accept fraction
        if p_accept is not None and math.isfinite(p_accept):
            self.alpha = self._ewma(self.alpha, min(max(p_accept, 0.0), 1.0))
        elif k_used > 0:
            frac = min(max(accept_len / k_used, 0.0), 1.0)
            self.alpha = self._ewma(self.alpha, frac)
        if rtt is not None and math.isfinite(rtt) and rtt >= 0.0:
            self.rtt = self._ewma(self.rtt, rtt)
        if queue_depth is not None and math.isfinite(queue_depth) \
                and queue_depth >= 0.0:
            self.load = self._ewma(self.load, queue_depth)
        self.k = self._step_towards(self._target())

    def _target(self) -> int:
        p = min(max(self.alpha, 0.05), 0.95)
        # largest k with p^k >= gain_floor  <=>  k <= ln(floor)/ln(p)
        k_p = int(math.log(self.gain_floor) / math.log(p))
        k_rtt = int(round(self.rtt * self.draft_speed * self.rtt_gain))
        k_load = int(self.load / self.load_scale)
        return max(1, min(k_p + k_rtt - k_load, self.k_max))

    def _step_towards(self, target: int) -> int:
        if target > self.k:
            return min(self.k + 1, self.k_max)
        if target < self.k:
            return max(self.k - 1, 1)
        return self.k

    def next_k(self) -> int:
        return max(1, min(self.k, self.k_max))

    def state(self) -> dict:
        return {"alpha": self.alpha, "rtt": self.rtt, "load": self.load,
                "k": self.k}

    def load_state(self, state: dict) -> None:
        self.alpha = float(state.get("alpha", self.alpha0))
        self.rtt = float(state.get("rtt", 0.0))
        self.load = float(state.get("load", 0.0))
        self.k = max(1, min(int(state.get("k", self._k0)), self.k_max))
