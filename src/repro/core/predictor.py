"""Rejection predictor (paper §4.1, Appendix B).

The deployed model is a compact MLP trained with class-weighted BCE; the
operating point (decision threshold) is tuned for LOW false-positive rate on
the Rejected class, because a false "accept" lets the device draft past the
true first rejection — the direct cause of WDT (Theorem 1).

A gradient-boosted decision-stump ensemble over the same 5 features is
included as the tree-family baseline of Table 4 (pure numpy, edge-friendly;
stands in for XGBoost/LightGBM which are unavailable offline).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import NUM_FEATURES

# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MLPConfig:
    hidden: tuple[int, ...] = (64, 32)
    lr: float = 3e-3
    epochs: int = 30
    batch_size: int = 256
    pos_weight: float = 1.0      # weight on the Accepted(1) class
    neg_weight: float = 2.5      # weight on the Rejected(0) class
    threshold: float = 0.5       # P(accept) >= threshold -> predict accept
    seed: int = 0


def mlp_init(rng, cfg: MLPConfig, n_features=NUM_FEATURES):
    sizes = (n_features, *cfg.hidden, 1)
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        rng, k = jax.random.split(rng)
        params.append(
            {
                "w": jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5,
                "b": jnp.zeros((b,)),
            }
        )
    return params


def mlp_apply(params, x):
    """x: (..., F) -> logit (...,)"""
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    out = h @ params[-1]["w"] + params[-1]["b"]
    return out[..., 0]


def _bce_loss(params, x, y, wpos, wneg):
    logit = mlp_apply(params, x)
    logp1 = jax.nn.log_sigmoid(logit)
    logp0 = jax.nn.log_sigmoid(-logit)
    w = jnp.where(y > 0.5, wpos, wneg)
    return -jnp.mean(w * (y * logp1 + (1 - y) * logp0))


@dataclasses.dataclass
class RejectionPredictor:
    """Stateful wrapper: features -> P(accept); stop when P(accept) < thr."""

    params: list
    stats: dict                  # feature normalization
    threshold: float

    def proba(self, feats):
        x = (feats - self.stats["mu"]) / self.stats["sd"]
        return jax.nn.sigmoid(mlp_apply(self.params, x))

    def predict_accept(self, feats):
        return self.proba(feats) >= self.threshold

    def save(self, path):
        blob = {
            "params": [
                {"w": np.asarray(l["w"]).tolist(), "b": np.asarray(l["b"]).tolist()}
                for l in self.params
            ],
            "stats": {k: np.asarray(v).tolist() for k, v in self.stats.items()},
            "threshold": self.threshold,
        }
        with open(path, "w") as f:
            json.dump(blob, f)

    @classmethod
    def load(cls, path):
        with open(path) as f:
            blob = json.load(f)
        params = [
            {"w": jnp.asarray(l["w"]), "b": jnp.asarray(l["b"])}
            for l in blob["params"]
        ]
        stats = {k: jnp.asarray(v) for k, v in blob["stats"].items()}
        return cls(params, stats, blob["threshold"])


def train_mlp(feats, labels, cfg: MLPConfig = MLPConfig()) -> RejectionPredictor:
    """feats: (N, F) float; labels: (N,) {0 rejected, 1 accepted}."""
    feats = jnp.asarray(feats, jnp.float32)
    labels = jnp.asarray(labels, jnp.float32)
    mu = feats.mean(axis=0)
    sd = feats.std(axis=0) + 1e-6
    x = (feats - mu) / sd

    rng = jax.random.PRNGKey(cfg.seed)
    params = mlp_init(rng, cfg, feats.shape[-1])
    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    grad_fn = jax.jit(jax.grad(_bce_loss), static_argnums=())

    @jax.jit
    def step(params, m, v, x, y, t):
        g = jax.grad(_bce_loss)(params, x, y, cfg.pos_weight, cfg.neg_weight)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9**t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999**t), v)
        params = jax.tree.map(
            lambda p, a, b: p - cfg.lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh
        )
        return params, m, v

    N = x.shape[0]
    rng_np = np.random.default_rng(cfg.seed)
    t = 0
    for _ in range(cfg.epochs):
        order = rng_np.permutation(N)
        for s in range(0, N, cfg.batch_size):
            sel = order[s : s + cfg.batch_size]
            t += 1
            params, m, v = step(params, m, v, x[sel], labels[sel], t)
    return RejectionPredictor(params, {"mu": mu, "sd": sd}, cfg.threshold)


# ---------------------------------------------------------------------------
# gradient-boosted stumps (tree-family baseline, numpy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StumpEnsemble:
    stumps: list      # (feature, threshold, left_value, right_value)
    base: float
    threshold: float = 0.5

    def raw(self, X):
        X = np.asarray(X)
        out = np.full(X.shape[0], self.base)
        for f, thr, lv, rv in self.stumps:
            out += np.where(X[:, f] <= thr, lv, rv)
        return out

    def proba(self, X):
        return 1.0 / (1.0 + np.exp(-self.raw(X)))

    def predict_accept(self, X):
        return self.proba(X) >= self.threshold


def train_stumps(
    feats, labels, *, n_rounds=60, lr=0.3, n_bins=32, seed=0
) -> StumpEnsemble:
    """Gradient boosting with depth-1 trees on binned features (LightGBM-style
    histogram splits), logistic loss."""
    X = np.asarray(feats, np.float64)
    y = np.asarray(labels, np.float64)
    N, F = X.shape
    base = float(np.log(max(y.mean(), 1e-6) / max(1 - y.mean(), 1e-6)))
    raw = np.full(N, base)
    # candidate thresholds per feature (quantile bins)
    qs = np.linspace(0.02, 0.98, n_bins)
    cand = [np.unique(np.quantile(X[:, f], qs)) for f in range(F)]
    stumps = []
    for _ in range(n_rounds):
        p = 1.0 / (1.0 + np.exp(-raw))
        g = p - y                      # gradient
        h = p * (1 - p) + 1e-6         # hessian
        best = None
        for f in range(F):
            xf = X[:, f]
            for thr in cand[f]:
                mask = xf <= thr
                gl, hl = g[mask].sum(), h[mask].sum()
                gr, hr = g.sum() - gl, h.sum() - hl
                gain = gl * gl / (hl + 1.0) + gr * gr / (hr + 1.0)
                if best is None or gain > best[0]:
                    best = (gain, f, thr, -gl / (hl + 1.0), -gr / (hr + 1.0))
        _, f, thr, lv, rv = best
        lv *= lr
        rv *= lr
        stumps.append((f, thr, lv, rv))
        raw += np.where(X[:, f] <= thr, lv, rv)
    return StumpEnsemble(stumps, base)


# ---------------------------------------------------------------------------
# evaluation (Table 4 metrics)
# ---------------------------------------------------------------------------


def operating_point(pred_accept, labels):
    """Returns the paper's Table-4 metrics.  labels: 1=accepted, 0=rejected;
    pred_accept: predicted accept booleans."""
    y = np.asarray(labels).astype(bool)
    p = np.asarray(pred_accept).astype(bool)
    tp = int(np.sum(p & y))          # predicted accept, truly accepted
    fn = int(np.sum(~p & y))
    fp = int(np.sum(p & ~y))         # predicted accept, truly REJECTED
    tn = int(np.sum(~p & ~y))
    rec1 = tp / max(tp + fn, 1)      # Accepted-class recall (coverage)
    spec = tn / max(tn + fp, 1)      # Rejected-class specificity
    fpr = fp / max(tn + fp, 1)       # waste driver
    acc = (tp + tn) / max(len(y), 1)
    return {
        "acc": acc,
        "rec1": rec1,
        "spec": spec,
        "fpr": fpr,
        "bal_acc": 0.5 * (rec1 + spec),
        "confusion": {"tp": tp, "fn": fn, "fp": fp, "tn": tn},
    }


def auc_score(proba, labels):
    """ROC AUC via rank statistic (no sklearn)."""
    p = np.asarray(proba, np.float64)
    y = np.asarray(labels).astype(bool)
    order = np.argsort(p, kind="mergesort")
    ranks = np.empty(len(p), np.float64)
    # average ranks for ties
    sorted_p = p[order]
    i = 0
    r = np.arange(1, len(p) + 1, dtype=np.float64)
    while i < len(p):
        j = i
        while j + 1 < len(p) and sorted_p[j + 1] == sorted_p[i]:
            j += 1
        r[i : j + 1] = 0.5 * (i + 1 + j + 1)
        i = j + 1
    ranks[order] = r
    n1 = int(y.sum())
    n0 = len(y) - n1
    if n0 == 0 or n1 == 0:
        return 0.5
    return (ranks[y].sum() - n1 * (n1 + 1) / 2) / (n0 * n1)
