"""Intelligent drafting controller (paper §4.1).

Runs the draft model autoregressively on the edge device; after each drafted
token it computes logit features and queries the rejection predictor.
Drafting stops at the first predicted rejection (stop-at-first-predicted-
rejection) or at ``k_max``.

Paper-faithful semantics (Thm. 1): the token that triggered the stop is NOT
included in the draft block (K_theta counts consecutive predicted-accepts).
``include_flagged_token=True`` is a beyond-paper variant evaluated in the
ablations: the flagged token rides along for free since verifying K+1 vs K
tokens costs the same batch slot.

Two implementations:
  * ``BlockDrafter``     — token-granular Python stepping (edge devices are
                           sequential anyway; easiest to instrument, and the
                           event-driven cluster runtime interleaves its steps
                           with verification verdicts);
  * ``draft_block_scan`` — jit-friendly fixed-K lax.scan with halt masking
                           (device-efficient batched drafting; cache updates
                           are masked after the stop so state stays exact).

Sampling keys are *position-folded*: the token destined for stream index p
is sampled with ``fold_in(session_key, p)``, never by splitting a threaded
key.  Re-drafting a position after a rollback, or drafting it speculatively
while a verification is in flight, therefore reproduces the exact sample the
synchronous path would draw given the same prefix — the property the cluster
runtime's commit-or-rollback pipelining and the lock-step driver equivalence
tests rely on.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import logit_features
from repro.core.speculative import CompactQ, _compact_q_kernel


@dataclasses.dataclass
class DraftResult:
    tokens: np.ndarray        # (K_sent,) int32
    #: dense (K_sent, V) float32 draft logits (``q_mode="dense"``); empty
    #: under "compact"/"none" — the engine's exact-residual wire format
    q_logits: np.ndarray
    features: np.ndarray      # (K_sent, 5)
    n_drafted: int            # tokens physically drafted (incl. flagged one)
    n_sent: int               # tokens sent for verification
    stopped_by: str           # "predictor" | "max"
    draft_time: float         # simulated edge time = n_drafted / draft_speed
    #: the final token the draft model produced: tokens[-1] on a max-stop,
    #: the excluded flagged token on a predictor-stop.  The cluster runtime
    #: uses it as the bonus-token guess for speculative continuation.
    last_drafted: int = -1
    #: compact O(K·C) q statistics (``q_mode="compact"``, DESIGN.md §9):
    #: exact per-token log-probs for the accept test + top-C/tail table
    #: for residual reconstruction.  None under "dense"/"none".
    q_compact: CompactQ | None = None
    #: the draft-length cap this block ran under (the speculation
    #: controller's per-block K choice, DESIGN.md §11); with no predictor
    #: ``n_drafted == k_used``, so per-round logs of it reconstruct the
    #: K schedule the committed-prefix oracle replays
    k_used: int = 0

    def q_payload(self):
        """The q argument for `NetworkModel.uplink_bytes`/`uplink_time` —
        the single mapping from this block's representation to the wire
        pricing: the actual `CompactQ` table, the ``"modelled"`` top-k
        sentinel for dense logit rows, or None when no q rides at all."""
        if self.q_compact is not None:
            return self.q_compact
        if self.q_logits is not None and self.q_logits.size:
            return "modelled"
        return None


class DraftingController:
    """Edge-side controller bound to one draft model instance."""

    def __init__(
        self,
        bundle,
        params,
        *,
        predictor=None,
        k_max: int = 8,
        temperature: float = 1.0,
        greedy: bool = False,
        include_flagged_token: bool = False,
        draft_speed: float = 50.0,     # tokens/s on this device (paper Fig. 1)
        q_mode: str = "dense",         # "dense" | "compact" | "none"
        q_top_c: int = 64,             # top-C table width under "compact"
    ):
        self.bundle = bundle
        self.params = params
        self.predictor = predictor
        self.k_max = k_max
        self.temperature = temperature
        self.greedy = greedy
        self.include_flagged = include_flagged_token
        self.draft_speed = draft_speed
        if q_mode not in ("dense", "compact", "none"):
            raise ValueError(f"unknown q_mode {q_mode!r}")
        #: which q representation rides with a drafted block (DESIGN.md §9):
        #: "dense"   — full (K, V) logit rows (exact residual; the legacy
        #:             wire format and the default);
        #: "compact" — per-token log-prob + top-C/tail table, computed ON
        #:             DEVICE per step so only O(C) crosses to the host
        #:             (exact accept test, bounded-error residual);
        #: "none"    — nothing (a greedy verifier reads no q at all).
        self.q_mode = q_mode
        self.q_top_c = int(q_top_c)
        self._decode = jax.jit(bundle.decode)

    def sample_next(self, rng, last_token: int, cache, pos: int):
        """Feed ``last_token`` at cache index ``pos`` and sample the token
        for index ``pos + 1`` (key = ``fold_in(rng, pos + 1)``).

        Returns (token_id, logits_row (1, V), cache)."""
        tok = jnp.asarray([[int(last_token)]], jnp.int32)
        logits, cache = self._decode(self.params, tok, cache, jnp.int32(pos))
        lg = logits[:, -1]                                   # (1, V)
        if self.greedy:
            nxt = int(jnp.argmax(lg, axis=-1)[0])
        else:
            k = jax.random.fold_in(rng, pos + 1)
            nxt = int(jax.random.categorical(
                k, lg / max(self.temperature, 1e-6)
            )[0])
        return nxt, lg, cache

    def begin_block(self, rng, last_token: int, cache, pos: int,
                    k: int | None = None) -> "BlockDrafter":
        """Start drafting one block after ``last_token`` (stream index
        ``pos``); step the returned drafter to completion (``draft`` does)
        or one token at a time (cluster runtime).  ``k`` caps this block's
        draft length below ``k_max`` (the per-session speculation
        controller's choice, `core/speculation.py`); None = full budget."""
        return BlockDrafter(self, rng, last_token, cache, pos, k=k)

    def draft(self, rng, last_token, cache, pos):
        """Draft a block starting after ``last_token`` at position ``pos``.

        last_token: (B=1,) int32.  Returns (DraftResult, cache, rng).
        The cache is advanced by n_drafted tokens; the server's verdict
        decides the committed prefix (edge rolls forward from there).
        ``rng`` is returned unchanged — sampling keys are position-folded
        (module docstring), so the caller's key is session-stable."""
        drafter = self.begin_block(rng, int(np.asarray(last_token).reshape(-1)[0]),
                                   cache, int(pos))
        while drafter.step():
            pass
        return drafter.result(), drafter.cache, rng


class BlockDrafter:
    """Incremental drafting of a single block, one token per ``step()``.

    The event-driven cluster runtime advances a drafter between virtual-clock
    events (each step costs 1/draft_speed of device time) and may abandon it
    mid-block when a verdict invalidates a speculative continuation — the
    draft cache rolls back by pointer, so a dropped drafter costs nothing.
    ``DraftingController.draft`` is the run-to-completion wrapper.
    """

    def __init__(self, controller: DraftingController, rng, last_token: int,
                 cache, pos: int, k: int | None = None):
        self.ctl = controller
        self.rng = rng
        self.cache = cache
        self.pos = int(pos)           # cache index the next feed lands on
        self._next_feed = int(last_token)
        #: this block's draft-length cap: the speculation controller's
        #: per-block K, clamped into [1, k_max]
        self.k_cap = controller.k_max if k is None \
            else max(1, min(int(k), controller.k_max))
        self.toks: list = []
        self.qls: list = []
        self.qcs: list = []           # per-token compact stats (q_mode=compact)
        self.feats: list = []
        self.n_drafted = 0
        self.n_sent = 0
        self.stopped_by = "max"
        self.last_drafted = -1
        self.done = False

    def step(self) -> bool:
        """Draft one token; returns True while the block wants more."""
        if self.done:
            return False
        ctl = self.ctl
        nxt, lg, self.cache = ctl.sample_next(
            self.rng, self._next_feed, self.cache, self.pos + self.n_drafted
        )
        f = logit_features(lg)[0]                            # (5,)
        self.n_drafted += 1
        self.last_drafted = nxt
        pred_accept = True
        if ctl.predictor is not None:
            pred_accept = bool(ctl.predictor.predict_accept(f[None])[0])
        if pred_accept or ctl.include_flagged:
            self.toks.append(nxt)
            if ctl.q_mode == "dense":
                self.qls.append(np.asarray(lg[0], np.float32))
            elif ctl.q_mode == "compact":
                # device-side top-C + token log-prob: O(C) crosses to the
                # host instead of the (V,) logit row
                self.qcs.append(jax.device_get(_compact_q_kernel(
                    lg, jnp.asarray([nxt], jnp.int32), C=ctl.q_top_c
                )))
            self.feats.append(np.asarray(f, np.float32))
            self.n_sent += 1
        if not pred_accept:
            self.stopped_by = "predictor"
            self.done = True
        elif self.n_drafted >= self.k_cap:
            self.done = True
        else:
            self._next_feed = nxt
        return not self.done

    def result(self) -> DraftResult:
        qc = None
        if self.ctl.q_mode == "compact":
            if self.qcs:
                qc = CompactQ(
                    logq_tok=np.concatenate(
                        [np.asarray(s[0], np.float32) for s in self.qcs]),
                    top_idx=np.concatenate(
                        [np.asarray(s[1], np.int32) for s in self.qcs]),
                    top_logq=np.concatenate(
                        [np.asarray(s[2], np.float32) for s in self.qcs]),
                    tail=np.concatenate(
                        [np.asarray(s[3], np.float32) for s in self.qcs]),
                )
            else:
                C = self.ctl.q_top_c
                qc = CompactQ(
                    logq_tok=np.zeros((0,), np.float32),
                    top_idx=np.zeros((0, C), np.int32),
                    top_logq=np.zeros((0, C), np.float32),
                    tail=np.zeros((0,), np.float32),
                )
        return DraftResult(
            tokens=np.asarray(self.toks, np.int32),
            q_logits=np.stack(self.qls) if self.qls
            else np.zeros((0, 0), np.float32),
            q_compact=qc,
            features=np.stack(self.feats) if self.feats
            else np.zeros((0, 5), np.float32),
            n_drafted=self.n_drafted,
            n_sent=self.n_sent,
            stopped_by=self.stopped_by,
            draft_time=self.n_drafted / self.ctl.draft_speed,
            last_drafted=self.last_drafted,
            k_used=self.k_cap,
        )


# ---------------------------------------------------------------------------
# jit-friendly masked-scan variant (batched drafting on accelerators)
# ---------------------------------------------------------------------------


def draft_block_scan(
    decode_fn,
    params,
    last_token,          # (B,) int32
    cache,
    pos,                 # scalar int32
    rng,
    *,
    k_max: int,
    predictor_fn=None,   # features (B,5) -> accept bool (B,)
    greedy: bool = True,
    temperature: float = 1.0,
):
    """Fixed-K scan with halt masking.

    Restricted to attention-cache draft models (the serving stack's drafts
    are dense transformers): rows that halt keep decoding into their KV
    cache, which is harmless — entries past the committed length are never
    attended to once the next round restarts at the committed position
    (caches are length-capped, hence self-healing).  Recurrent-state drafts
    must use the Python-loop controller.

    Returns dict(tokens (B,K), q_logits (B,K,V), features (B,K,5),
    draft_len (B,), cache).
    """
    B = last_token.shape[0]

    def body(carry, i):
        tok, cache, halted, rng = carry
        logits, cache = decode_fn(params, tok[:, None], cache, pos + i)
        lg = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, lg / temperature).astype(jnp.int32)
        feats = logit_features(lg)
        if predictor_fn is not None:
            acc = predictor_fn(feats)
        else:
            acc = jnp.ones((B,), bool)
        emitted = jnp.logical_not(halted)                  # this token counts?
        halted_next = jnp.logical_or(halted, jnp.logical_not(acc))
        return (nxt, cache, halted_next, rng), (nxt, lg, feats, emitted)

    init = (last_token, cache, jnp.zeros((B,), bool), rng)
    (tok, cache, halted, rng), (toks, qls, feats, emitted) = jax.lax.scan(
        body, init, jnp.arange(k_max, dtype=jnp.int32)
    )
    draft_len = emitted.sum(axis=0).astype(jnp.int32)       # (B,)
    return {
        "tokens": jnp.moveaxis(toks, 0, 1),
        "q_logits": jnp.moveaxis(qls, 0, 1),
        "features": jnp.moveaxis(feats, 0, 1),
        "draft_len": draft_len,
        "cache": cache,
    }
