"""Intelligent drafting controller (paper §4.1).

Runs the draft model autoregressively on the edge device; after each drafted
token it computes logit features and queries the rejection predictor.
Drafting stops at the first predicted rejection (stop-at-first-predicted-
rejection) or at ``k_max``.

Paper-faithful semantics (Thm. 1): the token that triggered the stop is NOT
included in the draft block (K_theta counts consecutive predicted-accepts).
``include_flagged_token=True`` is a beyond-paper variant evaluated in the
ablations: the flagged token rides along for free since verifying K+1 vs K
tokens costs the same batch slot.

Two implementations:
  * ``draft_block``      — Python loop (edge devices are sequential anyway;
                           easiest to instrument);
  * ``draft_block_scan`` — jit-friendly fixed-K lax.scan with halt masking
                           (device-efficient batched drafting; cache updates
                           are masked after the stop so state stays exact).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import logit_features


@dataclasses.dataclass
class DraftResult:
    tokens: np.ndarray        # (K_drafted,) int32
    q_logits: np.ndarray      # (K_drafted, V) float32
    features: np.ndarray      # (K_drafted, 5)
    n_drafted: int            # tokens physically drafted (incl. flagged one)
    n_sent: int               # tokens sent for verification
    stopped_by: str           # "predictor" | "max"
    draft_time: float         # simulated edge time = n_drafted / draft_speed


class DraftingController:
    """Edge-side controller bound to one draft model instance."""

    def __init__(
        self,
        bundle,
        params,
        *,
        predictor=None,
        k_max: int = 8,
        temperature: float = 1.0,
        greedy: bool = False,
        include_flagged_token: bool = False,
        draft_speed: float = 50.0,     # tokens/s on this device (paper Fig. 1)
    ):
        self.bundle = bundle
        self.params = params
        self.predictor = predictor
        self.k_max = k_max
        self.temperature = temperature
        self.greedy = greedy
        self.include_flagged = include_flagged_token
        self.draft_speed = draft_speed
        self._decode = jax.jit(bundle.decode)

    def draft(self, rng, last_token, cache, pos):
        """Draft a block starting after ``last_token`` at position ``pos``.

        last_token: (B=1,) int32.  Returns (DraftResult, cache, rng).
        The cache is advanced by n_drafted tokens; the server's verdict
        decides the committed prefix (edge rolls forward from there).
        """
        toks, qls, feats = [], [], []
        tok = jnp.asarray(last_token).reshape(1, 1)
        stopped_by = "max"
        n_drafted = 0
        n_sent = 0
        for i in range(self.k_max):
            logits, cache = self._decode(self.params, tok, cache, jnp.int32(pos + i))
            lg = logits[:, -1]                               # (1, V)
            if self.greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                nxt = jax.random.categorical(
                    k, lg / max(self.temperature, 1e-6)
                ).astype(jnp.int32)
            f = logit_features(lg)[0]                        # (5,)
            n_drafted += 1
            pred_accept = True
            if self.predictor is not None:
                pred_accept = bool(self.predictor.predict_accept(f[None])[0])
            if pred_accept or self.include_flagged:
                toks.append(int(nxt[0]))
                qls.append(np.asarray(lg[0], np.float32))
                feats.append(np.asarray(f, np.float32))
                n_sent += 1
            if not pred_accept:
                stopped_by = "predictor"
                break
            tok = nxt.reshape(1, 1)
        return (
            DraftResult(
                tokens=np.asarray(toks, np.int32),
                q_logits=np.stack(qls) if qls else np.zeros((0, 0), np.float32),
                features=np.stack(feats) if feats else np.zeros((0, 5), np.float32),
                n_drafted=n_drafted,
                n_sent=n_sent,
                stopped_by=stopped_by,
                draft_time=n_drafted / self.draft_speed,
            ),
            cache,
            rng,
        )


# ---------------------------------------------------------------------------
# jit-friendly masked-scan variant (batched drafting on accelerators)
# ---------------------------------------------------------------------------


def draft_block_scan(
    decode_fn,
    params,
    last_token,          # (B,) int32
    cache,
    pos,                 # scalar int32
    rng,
    *,
    k_max: int,
    predictor_fn=None,   # features (B,5) -> accept bool (B,)
    greedy: bool = True,
    temperature: float = 1.0,
):
    """Fixed-K scan with halt masking.

    Restricted to attention-cache draft models (the serving stack's drafts
    are dense transformers): rows that halt keep decoding into their KV
    cache, which is harmless — entries past the committed length are never
    attended to once the next round restarts at the committed position
    (caches are length-capped, hence self-healing).  Recurrent-state drafts
    must use the Python-loop controller.

    Returns dict(tokens (B,K), q_logits (B,K,V), features (B,K,5),
    draft_len (B,), cache).
    """
    B = last_token.shape[0]

    def body(carry, i):
        tok, cache, halted, rng = carry
        logits, cache = decode_fn(params, tok[:, None], cache, pos + i)
        lg = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, lg / temperature).astype(jnp.int32)
        feats = logit_features(lg)
        if predictor_fn is not None:
            acc = predictor_fn(feats)
        else:
            acc = jnp.ones((B,), bool)
        emitted = jnp.logical_not(halted)                  # this token counts?
        halted_next = jnp.logical_or(halted, jnp.logical_not(acc))
        return (nxt, cache, halted_next, rng), (nxt, lg, feats, emitted)

    init = (last_token, cache, jnp.zeros((B,), bool), rng)
    (tok, cache, halted, rng), (toks, qls, feats, emitted) = jax.lax.scan(
        body, init, jnp.arange(k_max, dtype=jnp.int32)
    )
    draft_len = emitted.sum(axis=0).astype(jnp.int32)       # (B,)
    return {
        "tokens": jnp.moveaxis(toks, 0, 1),
        "q_logits": jnp.moveaxis(qls, 0, 1),
        "features": jnp.moveaxis(feats, 0, 1),
        "draft_len": draft_len,
        "cache": cache,
    }
