"""SLO-aware verification batch scheduling (paper §4.2-4.3, Algorithm 1)
behind a pluggable policy registry.

Two first-class abstractions (docs/API.md):

**WorkItem** — one schedulable unit of server work.  The pool holds a
small class hierarchy behind one scheduling protocol (uniform
``deadline`` / ``goodput_value`` / ``batch_shape()`` plus engine hooks
``make_engine_item`` / ``apply``):

  * ``VerifyWork`` — a drafted block awaiting verification; the deadline
    is the SLO-class token-speed budget (Eq. 6/12);
  * ``PrefillChunkWork`` — one chunk of a cold prompt's prefill; the
    deadline is the session's TTFT deadline (DESIGN.md §8).

A future work type (e.g. a non-speculative decode fallback) is additive:
subclass ``WorkItem``, implement the four hooks, and every policy, the
estimator pricing, and the server's dispatch loop handle it unchanged.

**SchedulingPolicy** — the batch-selection rule, one per name in a
registry.  Per dispatch epoch t_k a policy selects a batch B_k under
(i) a GPU/TPU memory budget and (ii) its own ordering rule:

  * ``"wisp"`` (alias ``"slo"``) — Algorithm 1: EDF critical fast path
    past the Latest Start Time, utility-density best-effort fill, every
    admission validated by FeasibleAdd;
  * ``"fcfs"`` — SLED-style arrival order, fill to limits;
  * ``"edf"``  — earliest-deadline-first fill (deadline awareness
    without the estimator-driven criticality split);
  * ``"priority"`` — strict SLO-class priority, EDF within a class;
  * ``"wfq"`` (alias ``"fair"``) — weighted fair queueing over per-tenant
    virtual finish times with an SRPT bias and aging (no tenant starves;
    DESIGN.md §13).

This is host-side control logic (pure Python, no jax) — it runs on the
serving coordinator between device steps.  Both the functional server
(`repro.serving`) and the analytic simulator (`repro.sim`) select
policies from the same registry.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.estimator import BatchShape, EstimatorCoeffs


# ---------------------------------------------------------------------------
# Work items
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WorkItem:
    """One pending work item on the server (the scheduling protocol).

    Subclasses define what the item *is* by overriding the pricing
    properties (``new_tokens``, ``goodput_value``) and the engine hooks
    (``make_engine_item``, ``apply``, ``deferred``); the scheduling
    fields are uniform so every `SchedulingPolicy` prices and orders any
    mix of kinds without branching.
    """

    req_id: int
    session_id: int
    slo_class: int               # index into class table
    arrival: float               # a_i (s)
    deadline: float              # d_i = a_i + tau_c (s); TTFT deadline for prefill
    draft_len: int = 0           # N_d (0 for non-verify work)
    cached_len: int = 0          # committed prefix length with valid KV
    alpha: float = 0.0           # expected acceptance rate of this session
    payload: object = None       # kind-specific (opaque to scheduling)
    #: verify: prefix tokens that must be re-prefilled because no KV is
    #: cached (cold start / cache eviction / SLED's no-cache baseline);
    #: prefill: the chunk length
    prefill_tokens: int = 0
    #: tokens whose KV currently sits in the host spill tier (DESIGN.md
    #: §12): part of ``cached_len`` for *memory* accounting (their pages
    #: re-enter the device pool on page-in), but an extra *time* cost —
    #: ``batch_shape()`` prices them like new tokens so a spilled
    #: session's verify is dearer than a resident one's and the
    #: utility-density fill prefers resident work under pressure
    pagein_tokens: int = 0
    # bookkeeping
    enqueued_at: float = 0.0
    round_index: int = 0
    # -- multi-tenant fields (DESIGN.md §13) ------------------------------
    #: owning tenant (the ``"wfq"`` policy buckets virtual time by this;
    #: every other policy ignores it)
    tenant: str = "default"
    #: the tenant's fair-share weight, stamped from the `TenantRegistry`
    #: at submit time (policies take a fixed (cfg, coeffs) constructor,
    #: so weights ride the items, not the policy)
    tenant_weight: float = 1.0
    #: the rate limiter borrowed from the tenant's debt band for this
    #: item — WFQ serves it at a fraction of the tenant's weight
    deprioritized: bool = False

    #: kind tag (class attribute, kept for observability and the legacy
    #: ``VerifyRequest(kind=...)`` constructor shim)
    kind = "work"

    # -- pricing (what every policy needs) --------------------------------
    @property
    def new_tokens(self) -> int:
        raise NotImplementedError

    @property
    def goodput_value(self) -> float:
        """g_hat: expected committed tokens if this item executes."""
        raise NotImplementedError

    def batch_shape(self) -> BatchShape:
        # pagein_tokens ride the new_tokens axis for TIME pricing only
        # (page-in moves whole pages across the host boundary, the same
        # bandwidth class as writing fresh KV); memory accounting keeps
        # using ``cached_len + new_tokens`` — the reloaded pages are the
        # cached tokens, already counted there
        return BatchShape(
            new_tokens=self.new_tokens + self.pagein_tokens,
            cached_tokens=self.cached_len,
        )

    # -- engine hooks (the serving coordinator protocol) ------------------
    def make_engine_item(self, server):
        """Build the engine-level item (`repro.serving.engine`) this work
        executes as.  ``server`` is the coordinator (duck-typed: session
        table, engine, determinism switches)."""
        raise NotImplementedError

    def apply(self, server, outcome, now: float, tv_epoch: float):
        """Commit one executed outcome back into the coordinator; returns
        a ``Verdict`` for verify-like work, ``None`` otherwise."""
        raise NotImplementedError

    def deferred(self, outcome) -> bool:
        """True when ``outcome`` means "could not run, requeue me" (e.g. a
        prefill chunk the page pool could not cover this epoch)."""
        return False


@dataclasses.dataclass
class VerifyWork(WorkItem):
    """A drafted block awaiting verification (``payload`` = (draft token
    ids, dense q logits | None, `CompactQ` | None) — exactly one q
    representation is set unless the verifier is greedy, which reads
    neither).  Deadline is the SLO-class token-speed budget."""

    kind = "verify"

    @property
    def new_tokens(self) -> int:
        # + the re-fed last committed token + any uncached prefix
        return self.draft_len + 1 + self.prefill_tokens

    @property
    def goodput_value(self) -> float:
        """Expected committed tokens (paper Eq. 5, + bonus token)."""
        return self.alpha * self.draft_len + 1.0

    def make_engine_item(self, server):
        from repro.serving.engine import VerifyItem

        s = server.sessions[self.session_id]
        toks, qlog, qc = (self.payload if len(self.payload) == 3
                          else (*self.payload, None))
        return VerifyItem(
            slot=s.slot, draft_tokens=toks, q_logits=qlog, q_compact=qc,
            rng_tag=(self.session_id, self.cached_len)
            if server.deterministic_verify else None,
        )

    def apply(self, server, outcome, now, tv_epoch):
        return server.commit_verify(self, outcome, now, tv_epoch)


@dataclasses.dataclass
class PrefillChunkWork(WorkItem):
    """One chunk of a cold prompt's prefill (``payload`` = the server's
    PrefillingSession; ``prefill_tokens`` = chunk length; ``cached_len``
    = prompt prefix already prefilled or prefix-cache-covered).

    Every chunk of a session carries the session's **TTFT deadline**.
    Chunks are usually best-effort fill; as the TTFT deadline nears, LST
    promotes the remaining chunks to the critical fast path like any
    verify request.  g_hat is 1.0 — a prefill commits at most the
    session's first token — so long prompts get a low utility density
    and fill spare capacity instead of outbidding verification, exactly
    the paper's interference suppression (DESIGN.md §8)."""

    kind = "prefill"

    @property
    def new_tokens(self) -> int:
        # a chunk feeds exactly its prompt tokens (no draft block, no
        # re-fed last-committed token — the session has none yet)
        return self.prefill_tokens

    @property
    def goodput_value(self) -> float:
        return 1.0

    def make_engine_item(self, server):
        from repro.serving.engine import PrefillChunkItem

        return PrefillChunkItem(self.payload.state, self.prefill_tokens)

    def apply(self, server, outcome, now, tv_epoch):
        server.apply_chunk(self, outcome, now, tv_epoch)
        return None

    def deferred(self, outcome) -> bool:
        return bool(outcome.oom)


#: kind tag -> concrete work class (extended by new work types)
WORK_KINDS: dict[str, type] = {
    VerifyWork.kind: VerifyWork,
    PrefillChunkWork.kind: PrefillChunkWork,
}


def VerifyRequest(*args, kind: str = "verify", **kwargs) -> WorkItem:
    """Deprecated constructor shim: the stringly-typed
    ``VerifyRequest(kind=...)`` now dispatches to the `WorkItem` class
    hierarchy (``VerifyWork`` / ``PrefillChunkWork``).  Field names and
    order are unchanged; new code should construct the classes directly."""
    try:
        cls = WORK_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown work kind {kind!r}; registered: {sorted(WORK_KINDS)}"
        ) from None
    return cls(*args, **kwargs)


@dataclasses.dataclass
class SchedulerConfig:
    #: KV-token budget M(t_k).  A static default for standalone use; the
    #: serving coordinator overrides it per dispatch epoch (via
    #: ``schedule(..., memory_budget_tokens=...)``) from the verification
    #: engine's live page-allocator state (free + evictable pages), so
    #: admission tracks real cache pressure, not a constant.
    memory_budget_tokens: int = 1 << 20
    guard_time: float = 0.005             # delta (s)
    #: how long before LST a request enters the critical fast path.  The
    #: paper's "t >= LST_i" alone leaves a zero-width window between
    #: "critical" and "already hopeless"; opening the window eta early is
    #: what makes the EDF fast path actually fire.
    criticality_window: float = 0.020
    max_batch_requests: int = 64
    kv_bytes_per_token: int = 0           # informational


@dataclasses.dataclass
class ScheduleDecision:
    batch: list        # [WorkItem]
    est_time: float    # T_hat(B_k)
    critical: int      # how many came from the critical fast path
    skipped_infeasible: int
    epoch: float
    #: the budget this epoch was admitted against (observability: dynamic
    #: budgets change per epoch with cache pressure)
    memory_budget_tokens: int = 0
    #: registry name of the policy that produced this decision
    policy: str = ""


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------
#: registry name (and aliases) -> policy class
POLICIES: dict[str, type] = {}


def register_policy(name: str, *aliases: str):
    """Class decorator: register a `SchedulingPolicy` under ``name`` (its
    canonical ``cls.name``) plus any legacy aliases."""

    def deco(cls):
        cls.name = name
        for n in (name, *aliases):
            POLICIES[n] = cls
        return cls

    return deco


def available_policies() -> list[str]:
    """Canonical registered policy names, sorted."""
    return sorted({cls.name for cls in POLICIES.values()})


def make_policy(policy, cfg: SchedulerConfig, coeffs: EstimatorCoeffs):
    """Resolve ``policy`` — a registry name (``"wisp"``, ``"fcfs"``,
    ``"edf"``, ``"priority"``; legacy alias ``"slo"``), a policy class,
    or an already-built instance — into a policy instance."""
    if isinstance(policy, str):
        try:
            cls = POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown scheduling policy {policy!r}; registered: "
                f"{available_policies()}"
            ) from None
        return cls(cfg, coeffs)
    if isinstance(policy, type):
        return policy(cfg, coeffs)
    return policy


class SchedulingPolicy:
    """Batch-selection protocol + the pricing helpers every rule shares.

    ``schedule(pending, t_k, *, memory_budget_tokens=None) ->
    ScheduleDecision`` must (a) draw its batch from ``pending`` without
    duplicates, (b) keep ``memory_tokens(batch)`` within the budget and
    ``len(batch)`` within ``cfg.max_batch_requests``, and (c) report the
    estimator's batch time as ``est_time``.  ``memory_budget_tokens``
    overrides the static config budget for one epoch (the coordinator
    passes the engine's live free-page capacity here)."""

    name = "?"

    def __init__(self, cfg: SchedulerConfig, coeffs: EstimatorCoeffs):
        self.cfg = cfg
        self.coeffs = coeffs

    # -- shared pricing ----------------------------------------------------
    def batch_time(self, batch: Iterable[WorkItem]) -> float:
        shapes = [r.batch_shape() for r in batch]
        if not shapes:
            return 0.0
        return self.coeffs.predict(shapes)

    def memory_tokens(self, batch: Iterable[WorkItem]) -> int:
        return sum(r.cached_len + r.new_tokens for r in batch)

    def _budget(self, memory_budget_tokens: int | None) -> int:
        return (
            self.cfg.memory_budget_tokens
            if memory_budget_tokens is None
            else memory_budget_tokens
        )

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        raise NotImplementedError

    def _decision(self, batch, t_k, budget, *, critical=0, skipped=0):
        return ScheduleDecision(
            batch=batch,
            est_time=self.batch_time(batch),
            critical=critical,
            skipped_infeasible=skipped,
            epoch=t_k,
            memory_budget_tokens=budget,
            policy=self.name,
        )

    def _fill_in_order(self, pending, t_k, budget, key) -> ScheduleDecision:
        """Greedy fill in ``key`` order under the memory/batch caps —
        the shared body of the strict-order baselines (EDF, priority):
        no estimator feasibility check, no smaller-item bypass past the
        first one that does not fit."""
        batch: list = []
        tokens = 0
        skipped = 0
        for r in sorted(pending, key=key):
            if len(batch) >= self.cfg.max_batch_requests:
                break
            need = r.cached_len + r.new_tokens
            if tokens + need > budget:
                skipped += 1
                break
            batch.append(r)
            tokens += need
        return self._decision(batch, t_k, budget, skipped=skipped)


@register_policy("wisp", "slo")
class SLOScheduler(SchedulingPolicy):
    """Algorithm 1: EDF critical fast path + utility-density fill, every
    admission validated by FeasibleAdd against the estimator."""

    # -- per-request estimates -------------------------------------------
    def v_hat(self, r: WorkItem) -> float:
        """Marginal verification cost of r alone (used for U_i and LST_i)."""
        return self.coeffs.predict([r.batch_shape()])

    def utility(self, r: WorkItem) -> float:
        return r.goodput_value / max(self.v_hat(r), 1e-9)

    def lst(self, r: WorkItem) -> float:
        return r.deadline - self.v_hat(r) - self.cfg.guard_time

    # -- batch feasibility (FeasibleAdd) ----------------------------------
    def feasible_add(
        self, batch, r, t_k, doomed: set | None = None,
        memory_budget_tokens: int | None = None,
    ) -> bool:
        """FeasibleAdd (Alg. 1): memory + earliest *winnable* deadline vs
        estimated batch completion.  Requests in ``doomed`` have already
        missed their deadline — Eq. 15 cannot bind for them (they violate
        regardless), so they do not constrain d_min; excluding them avoids
        the one-request death-spiral a literal reading would cause."""
        budget = self._budget(memory_budget_tokens)
        nb = batch + [r]
        if len(nb) > self.cfg.max_batch_requests:
            return False
        if self.memory_tokens(nb) > budget:
            return False
        doomed = doomed or set()
        winnable = [x.deadline for x in nb if x.req_id not in doomed]
        if not winnable:
            return True
        return t_k + self.batch_time(nb) + self.cfg.guard_time <= min(winnable)

    # -- Algorithm 1 -------------------------------------------------------
    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        budget = self._budget(memory_budget_tokens)
        # Requests that cannot meet their deadline even alone are "doomed":
        # they violate regardless of what we do, so they must not block the
        # critical fast path (a literal Alg. 1 would dispatch them one at a
        # time and death-spiral the verifier).  They join the best-effort
        # fill — served promptly, batched efficiently, violation recorded.
        v_hats = {r.req_id: self.v_hat(r) for r in pending}
        doomed = {
            r.req_id
            for r in pending
            if t_k + v_hats[r.req_id] > r.deadline      # missed even solo
        }
        crit = [
            r for r in pending
            if r.req_id not in doomed
            and t_k >= (r.deadline - v_hats[r.req_id] - self.cfg.guard_time
                        - self.cfg.criticality_window)
        ]
        non = [r for r in pending if r not in crit]
        crit.sort(key=lambda r: r.deadline)                 # EDF
        non.sort(key=lambda r: -self.utility(r))            # utility density

        batch: list = []
        skipped = 0
        stop = False
        for r in crit:
            if self.feasible_add(batch, r, t_k, doomed,
                                 memory_budget_tokens=budget):
                batch.append(r)
            else:
                stop = True
                skipped += 1
                break
        n_crit = len(batch)
        if not stop:
            for r in non:
                if self.feasible_add(batch, r, t_k, doomed,
                                     memory_budget_tokens=budget):
                    batch.append(r)
                else:
                    skipped += 1
                    break
        return self._decision(batch, t_k, budget, critical=n_crit,
                              skipped=skipped)


@register_policy("fcfs")
class FCFSScheduler(SchedulingPolicy):
    """SLED-style baseline: first-come-first-served, fill to limits, no
    deadline awareness."""

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        budget = self._budget(memory_budget_tokens)
        batch: list = []
        for r in sorted(pending, key=lambda x: x.arrival):
            if len(batch) >= self.cfg.max_batch_requests:
                break
            if self.memory_tokens(batch + [r]) > budget:
                break
            batch.append(r)
        return self._decision(batch, t_k, budget)


@register_policy("edf")
class EDFScheduler(SchedulingPolicy):
    """Earliest-deadline-first baseline: admit in deadline order, fill to
    the memory/batch caps.

    Deadline-aware but estimator-blind: no Latest-Start-Time criticality
    split, no utility-density fill, no FeasibleAdd completion check — so
    a batch may still blow the earliest deadline it contains.  Isolates
    how much of WISP's win comes from mere deadline *ordering* vs from
    Algorithm 1's estimator-validated admission."""

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        return self._fill_in_order(
            pending, t_k, self._budget(memory_budget_tokens),
            key=lambda x: (x.deadline, x.arrival, x.req_id),
        )


@register_policy("priority")
class PriorityScheduler(SchedulingPolicy):
    """Strict SLO-class priority: premium classes (lower class index =
    faster token-speed promise) always outrank best-effort ones; EDF
    order within a class; fill to the memory/batch caps.

    The classic starvation-prone baseline — a saturated premium tier
    locks lower tiers out entirely, which is exactly the failure mode
    WISP's utility-density fill avoids."""

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        return self._fill_in_order(
            pending, t_k, self._budget(memory_budget_tokens),
            key=lambda x: (x.slo_class, x.deadline, x.arrival, x.req_id),
        )


@register_policy("wfq", "fair")
class WFQScheduler(SchedulingPolicy):
    """Weighted fair queueing over per-tenant virtual finish times, with
    an SRPT bias and aging (DESIGN.md §13).

    Each item's cost is its token footprint; its virtual finish time is

        vfinish = max(V, vt[tenant]) + cost / w_eff

    where ``V`` is the global virtual clock, ``vt[tenant]`` the tenant's
    last virtual finish, and ``w_eff`` the tenant's weight (cut to
    ``deprio_factor`` of itself for items the rate limiter borrowed from
    the debt band).  Items are admitted in order of

        vfinish + srpt_bias * cost - aging_rate * wait

    so short items edge ahead within a fair share (SRPT) and long-waiting
    items climb monotonically (aging: an item backlogged ``t`` seconds
    gains ``aging_rate * t`` of virtual-time credit, which bounds any
    backlogged tenant's wait — no tenant starves).  Kind-agnostic like
    every policy: verify and prefill work compete in one order.

    Virtual-time state lives on the policy instance and persists across
    epochs; after each selection the tenant clocks advance by the served
    cost over weight and the global clock jumps to the smallest
    backlogged tenant clock (standard virtual-time tracking — an idle
    tenant does not bank credit forever).
    """

    #: cost multiplier favoring short items within a fair share
    srpt_bias = 0.5
    #: virtual-time credit per real second of queueing wait
    aging_rate = 1.0
    #: weight multiplier for debt-band (deprioritized) items
    deprio_factor = 0.25

    def __init__(self, cfg: SchedulerConfig, coeffs: EstimatorCoeffs):
        super().__init__(cfg, coeffs)
        self.vtime = 0.0
        self.tenant_vt: dict[str, float] = {}

    @staticmethod
    def _cost(r: WorkItem) -> float:
        # token footprint (same axis the memory budget is charged in);
        # normalized so typical blocks are O(1e-2) virtual seconds and the
        # aging credit (1 vt/s real) can actually overtake them
        return (r.cached_len + r.new_tokens) / 1024.0

    def _weight(self, r: WorkItem) -> float:
        w = r.tenant_weight * (self.deprio_factor if r.deprioritized else 1.0)
        return max(w, 1e-6)

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        budget = self._budget(memory_budget_tokens)

        def vfinish(r: WorkItem) -> float:
            start = max(self.vtime, self.tenant_vt.get(r.tenant, 0.0))
            return start + self._cost(r) / self._weight(r)

        def key(r: WorkItem):
            wait = max(t_k - r.enqueued_at, 0.0)
            return (
                vfinish(r) + self.srpt_bias * self._cost(r)
                - self.aging_rate * wait,
                r.deadline,
                r.req_id,
            )

        decision = self._fill_in_order(pending, t_k, budget, key=key)
        # advance virtual time for the work actually served
        for r in decision.batch:
            start = max(self.vtime, self.tenant_vt.get(r.tenant, 0.0))
            self.tenant_vt[r.tenant] = start + self._cost(r) / self._weight(r)
        served = {r.req_id for r in decision.batch}
        backlog_vt = [
            self.tenant_vt.get(r.tenant, 0.0)
            for r in pending if r.req_id not in served
        ]
        if backlog_vt:
            self.vtime = max(self.vtime, min(backlog_vt))
        elif self.tenant_vt:
            self.vtime = max(self.vtime, max(self.tenant_vt.values()))
        return decision
