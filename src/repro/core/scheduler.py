"""SLO-aware verification batch scheduler (paper §4.2-4.3, Algorithm 1).

Per dispatch epoch t_k, select a batch B_k maximizing goodput density
under (i) a GPU/TPU memory budget and (ii) per-request deadlines checked
against the verification-time estimator:

  * critical fast path: requests past their Latest Start Time
    (LST_i = d_i - v_hat_i - delta) are admitted first in EDF order;
  * best-effort fill: remaining capacity is filled by decreasing utility
    density U_i = g_hat_i / v_hat_i;
  * every tentative admission is validated by FeasibleAdd (memory + the
    earliest deadline in the batch vs estimated batch completion).

The pool holds TWO kinds of work item behind one interface: verification
requests and chunked-prefill chunks (``VerifyRequest.kind``) — prompt
prefill competes for the verifier under the same LST/utility-density
rules instead of blocking it from outside the scheduler (DESIGN.md §8).

This is host-side control logic (pure Python, no jax) — it runs on the
serving coordinator between device steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

from repro.core.estimator import BatchShape, EstimatorCoeffs, batch_features


@dataclasses.dataclass
class VerifyRequest:
    """A pending work item on the server.

    Two kinds flow through the same Algorithm 1 pool (DESIGN.md §8):

      * ``kind="verify"`` — a drafted block awaiting verification; the
        deadline is the SLO-class token-speed budget (Eq. 6/12).
      * ``kind="prefill"`` — one chunk of a cold prompt's prefill; the
        deadline is the session's **TTFT deadline** (every chunk of a
        session carries the same one), ``cached_len`` is the prompt prefix
        already prefilled (or prefix-cache-covered), and
        ``prefill_tokens`` is the chunk length.  Chunks are usually
        best-effort fill; as the TTFT deadline nears, LST promotes the
        remaining chunks to the critical fast path like any verify
        request.
    """

    req_id: int
    session_id: int
    slo_class: int               # index into class table
    arrival: float               # a_i (s)
    deadline: float              # d_i = a_i + tau_c (s); TTFT deadline for prefill
    draft_len: int               # N_d (0 for prefill chunks)
    cached_len: int              # committed prefix length with valid KV
    alpha: float                 # expected acceptance rate of this session
    payload: object = None       # draft tokens + q stats (opaque here)
    #: verify: prefix tokens that must be re-prefilled because no KV is
    #: cached (cold start / cache eviction / SLED's no-cache baseline);
    #: prefill: the chunk length
    prefill_tokens: int = 0
    #: "verify" | "prefill"
    kind: str = "verify"
    # bookkeeping
    enqueued_at: float = 0.0
    round_index: int = 0

    @property
    def new_tokens(self) -> int:
        if self.kind == "prefill":
            # a chunk feeds exactly its prompt tokens (no draft block, no
            # re-fed last-committed token — the session has none yet)
            return self.prefill_tokens
        # + the re-fed last committed token + any uncached prefix
        return self.draft_len + 1 + self.prefill_tokens

    @property
    def goodput_value(self) -> float:
        """g_hat: expected committed tokens (paper Eq. 5, + bonus token).

        A prefill chunk commits at most the session's first token (and
        that only when the final chunk lands), so its g_hat is 1.0: long
        prompts get a low utility density and fill spare capacity instead
        of outbidding verification — exactly the paper's interference
        suppression, with escalation left to the TTFT deadline's LST."""
        if self.kind == "prefill":
            return 1.0
        return self.alpha * self.draft_len + 1.0

    def batch_shape(self) -> BatchShape:
        return BatchShape(new_tokens=self.new_tokens, cached_tokens=self.cached_len)


@dataclasses.dataclass
class SchedulerConfig:
    #: KV-token budget M(t_k).  A static default for standalone use; the
    #: serving coordinator overrides it per dispatch epoch (via
    #: ``schedule(..., memory_budget_tokens=...)``) from the verification
    #: engine's live page-allocator state (free + evictable pages), so
    #: admission tracks real cache pressure, not a constant.
    memory_budget_tokens: int = 1 << 20
    guard_time: float = 0.005             # delta (s)
    #: how long before LST a request enters the critical fast path.  The
    #: paper's "t >= LST_i" alone leaves a zero-width window between
    #: "critical" and "already hopeless"; opening the window eta early is
    #: what makes the EDF fast path actually fire.
    criticality_window: float = 0.020
    max_batch_requests: int = 64
    kv_bytes_per_token: int = 0           # informational


@dataclasses.dataclass
class ScheduleDecision:
    batch: list        # [VerifyRequest]
    est_time: float    # T_hat(B_k)
    critical: int      # how many came from the critical fast path
    skipped_infeasible: int
    epoch: float
    #: the budget this epoch was admitted against (observability: dynamic
    #: budgets change per epoch with cache pressure)
    memory_budget_tokens: int = 0


class SLOScheduler:
    """Algorithm 1.  ``estimator`` maps a list of BatchShape -> seconds."""

    def __init__(self, cfg: SchedulerConfig, coeffs: EstimatorCoeffs):
        self.cfg = cfg
        self.coeffs = coeffs

    # -- per-request estimates -------------------------------------------
    def v_hat(self, r: VerifyRequest) -> float:
        """Marginal verification cost of r alone (used for U_i and LST_i)."""
        return self.coeffs.predict([r.batch_shape()])

    def utility(self, r: VerifyRequest) -> float:
        return r.goodput_value / max(self.v_hat(r), 1e-9)

    def lst(self, r: VerifyRequest) -> float:
        return r.deadline - self.v_hat(r) - self.cfg.guard_time

    # -- batch feasibility (FeasibleAdd) ----------------------------------
    def batch_time(self, batch: Iterable[VerifyRequest]) -> float:
        shapes = [r.batch_shape() for r in batch]
        if not shapes:
            return 0.0
        return self.coeffs.predict(shapes)

    def memory_tokens(self, batch: Iterable[VerifyRequest]) -> int:
        return sum(r.cached_len + r.new_tokens for r in batch)

    def feasible_add(
        self, batch, r, t_k, doomed: set | None = None,
        memory_budget_tokens: int | None = None,
    ) -> bool:
        """FeasibleAdd (Alg. 1): memory + earliest *winnable* deadline vs
        estimated batch completion.  Requests in ``doomed`` have already
        missed their deadline — Eq. 15 cannot bind for them (they violate
        regardless), so they do not constrain d_min; excluding them avoids
        the one-request death-spiral a literal reading would cause."""
        budget = (
            self.cfg.memory_budget_tokens
            if memory_budget_tokens is None
            else memory_budget_tokens
        )
        nb = batch + [r]
        if len(nb) > self.cfg.max_batch_requests:
            return False
        if self.memory_tokens(nb) > budget:
            return False
        doomed = doomed or set()
        winnable = [x.deadline for x in nb if x.req_id not in doomed]
        if not winnable:
            return True
        return t_k + self.batch_time(nb) + self.cfg.guard_time <= min(winnable)

    # -- Algorithm 1 -------------------------------------------------------
    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        """``memory_budget_tokens`` overrides the static config budget for
        this epoch (the coordinator passes the engine's live free-page
        capacity here)."""
        budget = (
            self.cfg.memory_budget_tokens
            if memory_budget_tokens is None
            else memory_budget_tokens
        )
        # Requests that cannot meet their deadline even alone are "doomed":
        # they violate regardless of what we do, so they must not block the
        # critical fast path (a literal Alg. 1 would dispatch them one at a
        # time and death-spiral the verifier).  They join the best-effort
        # fill — served promptly, batched efficiently, violation recorded.
        v_hats = {r.req_id: self.v_hat(r) for r in pending}
        doomed = {
            r.req_id
            for r in pending
            if t_k + v_hats[r.req_id] > r.deadline      # missed even solo
        }
        crit = [
            r for r in pending
            if r.req_id not in doomed
            and t_k >= (r.deadline - v_hats[r.req_id] - self.cfg.guard_time
                        - self.cfg.criticality_window)
        ]
        non = [r for r in pending if r not in crit]
        crit.sort(key=lambda r: r.deadline)                 # EDF
        non.sort(key=lambda r: -self.utility(r))            # utility density

        batch: list = []
        skipped = 0
        stop = False
        for r in crit:
            if self.feasible_add(batch, r, t_k, doomed,
                                 memory_budget_tokens=budget):
                batch.append(r)
            else:
                stop = True
                skipped += 1
                break
        n_crit = len(batch)
        if not stop:
            for r in non:
                if self.feasible_add(batch, r, t_k, doomed,
                                     memory_budget_tokens=budget):
                    batch.append(r)
                else:
                    skipped += 1
                    break
        return ScheduleDecision(
            batch=batch,
            est_time=self.batch_time(batch),
            critical=n_crit,
            skipped_infeasible=skipped,
            epoch=t_k,
            memory_budget_tokens=budget,
        )


class FCFSScheduler:
    """SLED-style baseline: first-come-first-served, fill to limits, no
    deadline awareness."""

    def __init__(self, cfg: SchedulerConfig, coeffs: EstimatorCoeffs):
        self.cfg = cfg
        self.coeffs = coeffs

    def batch_time(self, batch) -> float:
        shapes = [r.batch_shape() for r in batch]
        return self.coeffs.predict(shapes) if shapes else 0.0

    def memory_tokens(self, batch) -> int:
        return sum(r.cached_len + r.new_tokens for r in batch)

    def schedule(
        self, pending: list, t_k: float, *,
        memory_budget_tokens: int | None = None,
    ) -> ScheduleDecision:
        budget = (
            self.cfg.memory_budget_tokens
            if memory_budget_tokens is None
            else memory_budget_tokens
        )
        batch: list = []
        for r in sorted(pending, key=lambda x: x.arrival):
            if len(batch) >= self.cfg.max_batch_requests:
                break
            if self.memory_tokens(batch + [r]) > budget:
                break
            batch.append(r)
        return ScheduleDecision(
            batch=batch,
            est_time=self.batch_time(batch),
            critical=0,
            skipped_infeasible=0,
            epoch=t_k,
            memory_budget_tokens=budget,
        )
