"""Draft-logit summary features for the rejection predictor (paper §3.3).

Five features per drafted token, all computable in one pass over the vocab
(the Pallas kernel `kernels/logit_features` fuses this pass; this module is
its jnp oracle and the default CPU path):

  0. confidence  — max softmax probability
  1. entropy     — softmax entropy, normalized by log(V)
  2. margin      — top-1 minus top-2 softmax probability
  3. logit_std   — standard deviation of the raw logits
  4. top8_mass   — total probability of the 8 most likely tokens
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NUM_FEATURES = 5
FEATURE_NAMES = ("confidence", "entropy", "margin", "logit_std", "top8_mass")


def logit_features(logits):
    """logits: (..., V) -> features (..., 5), float32."""
    x = logits.astype(jnp.float32)
    V = x.shape[-1]
    logp = jax.nn.log_softmax(x, axis=-1)
    p = jnp.exp(logp)
    top8, _ = jax.lax.top_k(p, 8)
    conf = top8[..., 0]
    margin = top8[..., 0] - top8[..., 1]
    entropy = -jnp.sum(p * logp, axis=-1) / jnp.log(V)
    std = jnp.std(x, axis=-1)
    mass8 = top8.sum(axis=-1)
    return jnp.stack([conf, entropy, margin, std, mass8], axis=-1)


def normalize_features(feats, stats=None):
    """Standardize features; returns (normed, stats).  ``stats`` from the
    training set is reused at inference."""
    if stats is None:
        mu = feats.mean(axis=0)
        sd = feats.std(axis=0) + 1e-6
        stats = {"mu": mu, "sd": sd}
    return (feats - stats["mu"]) / stats["sd"], stats
