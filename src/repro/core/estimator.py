"""Verification-time estimator (paper §4.4, Appendix C).

    T_batch = a * N_linear + b_compute * N_interactions + b_read * N_cached + c

  N_linear       = sum_i L_new_i          (tokens entering the model)
  N_interactions = sum_i L_total_i * L_new_i   (query-key dot products)
  N_cached       = sum_i L_cached_i       (KV tokens read from HBM)

Fit by OLS (numpy lstsq) with bootstrap confidence intervals — the same
pipeline as the paper's App. C, refit for the deployment hardware.  The
module also provides analytic TPU-v5e coefficients derived from the machine
model (197 TFLOP/s bf16, 819 GB/s HBM) for simulator use before any
profiling data exists.

This is the latency model every control decision rests on: Algorithm 1's
LST / utility-density / FeasibleAdd checks, the server's deadline
bookkeeping, the cluster runtime's virtual verification epochs and
monolithic-prefill spans, and the analytic simulator's service times.
Chunked-prefill chunks are priced through the same features — a chunk is
``BatchShape(new_tokens=chunk_len, cached_tokens=tokens_already_done)``,
so a prompt's chunks sum to its triangular causal attention cost
(DESIGN.md §8).  Coefficients round-trip as flat JSON via ``save_coeffs``
/ ``load_coeffs`` — provenance, units and the file format are documented
in docs/ESTIMATOR.md.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass
class BatchShape:
    """One request's contribution to a verification batch."""

    new_tokens: int          # L_new
    cached_tokens: int       # L_cached

    @property
    def total(self):
        return self.new_tokens + self.cached_tokens


def batch_features(reqs) -> np.ndarray:
    """[N_linear, N_interactions, N_cached] for a batch of BatchShape."""
    n_lin = sum(r.new_tokens for r in reqs)
    n_int = sum(r.total * r.new_tokens for r in reqs)
    n_cache = sum(r.cached_tokens for r in reqs)
    return np.array([n_lin, n_int, n_cache], np.float64)


@dataclasses.dataclass
class EstimatorCoeffs:
    a: float                 # sec / new token        (linear ops)
    b_compute: float         # sec / qk interaction   (attention compute)
    b_read: float            # sec / cached token     (HBM reads)
    c: float                 # sec                    (constant overhead)

    def predict(self, reqs) -> float:
        f = batch_features(reqs)
        return float(self.a * f[0] + self.b_compute * f[1] + self.b_read * f[2] + self.c)

    def predict_features(self, f) -> float:
        return float(self.a * f[0] + self.b_compute * f[1] + self.b_read * f[2] + self.c)


def analytic_tpu_coeffs(
    cfg,
    *,
    chips: int = 1,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    mfu: float = 0.5,
    hbm_eff: float = 0.8,
    overhead_s: float = 0.002,
) -> EstimatorCoeffs:
    """Machine-model coefficients for a target config on TPU v5e.

    a          ~ 2 * n_params_active / (chips * peak * mfu)  per token
    b_compute  ~ qk+av flops per interaction / peak
    b_read     ~ kv bytes per cached token / hbm_bw
    """
    from repro.roofline.model_flops import active_param_count

    n_active = active_param_count(cfg)
    flops_per_tok = 2.0 * n_active
    a = flops_per_tok / (chips * peak_flops * mfu)
    hd = cfg.resolved_head_dim
    flops_per_inter = 2 * 2 * cfg.n_heads * hd  # qk + av per layer-pair token
    b_compute = cfg.n_layers * flops_per_inter / (chips * peak_flops * mfu)
    kv_bytes = cfg.n_layers * 2 * cfg.n_kv_heads * hd * 2  # bf16
    b_read = kv_bytes / (chips * hbm_bw * hbm_eff)
    return EstimatorCoeffs(a=a, b_compute=b_compute, b_read=b_read, c=overhead_s)


@dataclasses.dataclass
class FitResult:
    coeffs: EstimatorCoeffs
    r2: float
    rmse: float
    mae: float
    mape: float
    max_err: float
    ci95: dict | None = None

    def metrics(self):
        return {
            "r2": self.r2,
            "rmse": self.rmse,
            "mae": self.mae,
            "mape": self.mape,
            "max_err": self.max_err,
        }


def _metrics(y, yhat):
    resid = y - yhat
    ss_res = float(np.sum(resid**2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
    return dict(
        r2=1 - ss_res / ss_tot,
        rmse=float(np.sqrt(np.mean(resid**2))),
        mae=float(np.mean(np.abs(resid))),
        mape=float(np.mean(np.abs(resid) / np.maximum(np.abs(y), 1e-9)) * 100),
        max_err=float(np.max(np.abs(resid))),
    )


def fit_ols(features, latencies, *, bootstrap: int = 0, seed: int = 0) -> FitResult:
    """features: (n, 3) [N_linear, N_interactions, N_cached]; latencies (n,) sec."""
    X = np.asarray(features, np.float64)
    y = np.asarray(latencies, np.float64)
    A = np.concatenate([X, np.ones((len(X), 1))], axis=1)
    # column scaling for conditioning (N_interactions is ~1e6x N_linear)
    scale = np.maximum(np.abs(A).max(axis=0), 1e-12)
    theta_s, *_ = np.linalg.lstsq(A / scale, y, rcond=None)
    theta = theta_s / scale
    coeffs = EstimatorCoeffs(*theta)
    m = _metrics(y, A @ theta)

    ci = None
    if bootstrap:
        rng = np.random.default_rng(seed)
        samples = []
        for _ in range(bootstrap):
            idx = rng.integers(0, len(X), len(X))
            th_s, *_ = np.linalg.lstsq(A[idx] / scale, y[idx], rcond=None)
            samples.append(th_s / scale)
        S = np.stack(samples)
        lo, hi = np.percentile(S, [2.5, 97.5], axis=0)
        names = ["a", "b_compute", "b_read", "c"]
        ci = {n: (float(l), float(h)) for n, l, h in zip(names, lo, hi)}
    return FitResult(coeffs=coeffs, ci95=ci, **m)


def evaluate(coeffs: EstimatorCoeffs, features, latencies) -> dict:
    X = np.asarray(features, np.float64)
    y = np.asarray(latencies, np.float64)
    yhat = X @ np.array([coeffs.a, coeffs.b_compute, coeffs.b_read]) + coeffs.c
    return _metrics(y, yhat)


def save_coeffs(coeffs: EstimatorCoeffs, path):
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(coeffs), f)


def load_coeffs(path) -> EstimatorCoeffs:
    with open(path) as f:
        return EstimatorCoeffs(**json.load(f))
