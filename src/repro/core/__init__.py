"""WISP core: the paper's primary contribution.

  speculative — lossless accept/reject rule (Eq. 1-3)
  features    — draft-logit summary statistics (§3.3)
  predictor   — rejection predictor: MLP + stump-ensemble baseline (§4.1)
  controller  — stop-at-first-predicted-rejection drafting (§4.1, Thm. 1)
  estimator   — verification-time estimator, OLS-fit (§4.4, App. C)
  scheduler   — work items + scheduling-policy registry; Algorithm 1
                ("wisp") plus fcfs/edf/priority baselines (§4.2-4.3)
  wdt         — Wasted-Drafting-Time accounting (§3.2)
"""
from repro.core.speculative import speculative_verify, committed_tokens, wasted_tokens
from repro.core.features import logit_features, NUM_FEATURES, FEATURE_NAMES
from repro.core.predictor import (
    MLPConfig,
    RejectionPredictor,
    StumpEnsemble,
    train_mlp,
    train_stumps,
    operating_point,
    auc_score,
)
from repro.core.controller import (
    BlockDrafter,
    DraftingController,
    DraftResult,
    draft_block_scan,
)
from repro.core.estimator import (
    BatchShape,
    EstimatorCoeffs,
    FitResult,
    analytic_tpu_coeffs,
    batch_features,
    evaluate,
    fit_ols,
    load_coeffs,
    save_coeffs,
)
from repro.core.scheduler import (
    EDFScheduler,
    FCFSScheduler,
    PrefillChunkWork,
    PriorityScheduler,
    ScheduleDecision,
    SchedulerConfig,
    SchedulingPolicy,
    SLOScheduler,
    VerifyRequest,
    VerifyWork,
    WorkItem,
    available_policies,
    make_policy,
    register_policy,
)
from repro.core.wdt import IterationLog, WDTStats

__all__ = [
    "speculative_verify",
    "committed_tokens",
    "wasted_tokens",
    "logit_features",
    "NUM_FEATURES",
    "FEATURE_NAMES",
    "MLPConfig",
    "RejectionPredictor",
    "StumpEnsemble",
    "train_mlp",
    "train_stumps",
    "operating_point",
    "auc_score",
    "BlockDrafter",
    "DraftingController",
    "DraftResult",
    "draft_block_scan",
    "BatchShape",
    "EstimatorCoeffs",
    "FitResult",
    "analytic_tpu_coeffs",
    "batch_features",
    "evaluate",
    "fit_ols",
    "load_coeffs",
    "save_coeffs",
    "EDFScheduler",
    "FCFSScheduler",
    "PrefillChunkWork",
    "PriorityScheduler",
    "ScheduleDecision",
    "SchedulerConfig",
    "SchedulingPolicy",
    "SLOScheduler",
    "VerifyRequest",
    "VerifyWork",
    "WorkItem",
    "available_policies",
    "make_policy",
    "register_policy",
    "IterationLog",
    "WDTStats",
]
