"""Verifier fleet: prefix-locality routing, heartbeat failover, hedged
re-dispatch (docs/ARCHITECTURE.md §7, DESIGN.md §10).

``build_verifier_fleet`` constructs N independent `WISPServer` verifiers
— same target params, same engine seed, so they are functionally
interchangeable under rng-tagged verification — behind one `FleetRouter`;
`FleetRuntime` drives the ensemble on the cluster's virtual clock with
deterministic failure/straggler injection (`ClusterConfig.fail_at` /
``straggle``).
"""
from __future__ import annotations

from repro.fleet.router import FleetCapacityError, FleetRouter
from repro.fleet.runtime import FleetRuntime


def build_verifier_fleet(
    model_cfg,
    tparams,
    n_verifiers: int,
    coeffs,
    *,
    max_slots: int,
    max_len: int,
    method: str = "residual",
    policy="wisp",
    sched_cfg=None,
    network=None,
    prefill: str = "monolithic",
    prefill_chunk_tokens: int = 256,
    slo_classes=None,
    ttft_slo=None,
    engine_seed: int = 0,
    heartbeat_timeout: float = 0.15,
    hedge_factor: float = 8.0,
    hedge_guard: float = 0.01,
    kv_tier_pages: int = 0,
    spill_quantize: bool = False,
    spill_idle_epochs: int = 2,
    tenants=None,
) -> FleetRouter:
    """N same-seed verifiers (each its own engine + page pool + scheduler
    instance) behind a prefix-locality router.  ``max_slots`` is PER
    VERIFIER — the fleet's aggregate capacity is ``n_verifiers x
    max_slots`` — and every verifier shares ``tparams`` (one trained
    target model, replicated), which is what makes migration lossless.

    ``tenants`` (a `TenantRegistry`, or an iterable of `TenantSpec` /
    CLI spec strings) is instantiated ONCE and shared by every verifier:
    tenant budgets and fair-share accounting are fleet-global, which is
    what a fleet-wide SLO means (DESIGN.md §13)."""
    from repro.serving.engine import VerificationEngine
    from repro.serving.server import WISPServer
    from repro.tenancy import TenantRegistry

    if tenants is not None and not isinstance(tenants, TenantRegistry):
        tenants = TenantRegistry(tenants)
    registry = tenants if tenants is not None else TenantRegistry()

    verifiers = {}
    for i in range(int(n_verifiers)):
        engine = VerificationEngine(
            model_cfg, tparams, max_slots=max_slots, max_len=max_len,
            method=method, seed=engine_seed,
            kv_tier_pages=kv_tier_pages, spill_quantize=spill_quantize,
            spill_idle_epochs=spill_idle_epochs,
        )
        verifiers[f"v{i}"] = WISPServer(
            engine, coeffs, policy=policy, sched_cfg=sched_cfg,
            network=network, prefill=prefill,
            prefill_chunk_tokens=prefill_chunk_tokens,
            slo_classes=slo_classes, ttft_slo=ttft_slo,
            tenants=registry,
        )
    return FleetRouter(verifiers, heartbeat_timeout=heartbeat_timeout,
                       hedge_factor=hedge_factor, hedge_guard=hedge_guard)


__all__ = [
    "FleetCapacityError",
    "FleetRouter",
    "FleetRuntime",
    "build_verifier_fleet",
]
