"""Multi-verifier cluster runtime: per-verifier clocks + failure injection.

`FleetRuntime` extends the single-server `ClusterRuntime` with a verifier
*fleet* behind a `FleetRouter`:

  * every verifier has its own busy clock (``GPU_DONE``/``DISPATCH``
    events carry the verifier id) and its own dispatch-epoch timer, so
    epochs on different verifiers overlap in virtual time;
  * a recurring ``HEARTBEAT`` event per verifier (``EventKind`` value 7 —
    the golden 0–6 priorities are untouched) beats the router's monitor
    while the injected `FailurePlan` says the verifier is up, and runs the
    failover sweep; the sweep also runs at the top of every dispatch
    epoch, so detection latency is bounded by min(heartbeat_interval,
    dispatch cadence) past the timeout;
  * failure injection is deterministic config (`ClusterConfig.fail_at` /
    ``straggle``): a down verifier executes no epochs and any epoch that
    would have completed after its death never delivers (the verdicts are
    *lost*, exercising the re-dispatch path);
  * when a verifier is declared dead, its never-started sessions re-open
    elsewhere and its streaming sessions migrate — committed stream
    replayed as an estimator-priced prefill on the destination's clock —
    after which any round the dead verifier held is re-submitted to the
    new owner under the same (session_id, round_index) key.  Straggling
    rounds that blow through the hedge guard take the same
    migrate-and-resubmit path.

Losslessness (DESIGN.md §10): verification draws are keyed by
(session_id, committed_len) against a never-advanced rng base and prefill
first-tokens are argmax, so same-seed verifier engines are *functionally
interchangeable* — committed streams are invariant to fleet size,
routing, failures and hedging; only timing changes.  The chaos test
(tests/test_fleet.py) pins this byte-for-byte.
"""
from __future__ import annotations

import numpy as np

from repro.cluster.events import EventKind
from repro.cluster.runtime import ClusterRuntime
from repro.core.estimator import BatchShape
from repro.runtime.failure import FailurePlan

_EPS = 1e-12


class FleetRuntime(ClusterRuntime):
    """Drives EdgeDevices + a FleetRouter of verifiers on a virtual clock."""

    def __init__(self, router, edge_devices, fleet, cfg, *, vocab: int):
        if cfg.prefill_mode == "monolithic":
            raise ValueError(
                "FleetRuntime supports prefill_mode 'zero' and 'chunked'; "
                "monolithic prefill is a single-verifier blocking span"
            )
        super().__init__(router, edge_devices, fleet, cfg, vocab=vocab)
        self.router = router
        self.vids = list(router.verifiers)
        # verifier fault domain from the unified schedule (the base class
        # resolved it: DSL/preset rows + legacy cfg.fail_at / cfg.straggle
        # shims, already merged by resolve_fault_schedule)
        self.plan = FailurePlan([
            (f"v{int(i)}", float(t0), None if t1 is None else float(t1))
            for (i, t0, t1) in self.fault_schedule.verifier_fail
        ])
        self._straggle = [
            (f"v{int(i)}", float(t0), float(t1), float(f))
            for (i, t0, t1, f) in self.fault_schedule.verifier_straggle
        ]
        self._busy_until = {vid: 0.0 for vid in self.vids}
        self._disp_at: dict[str, float | None] = {v: None for v in self.vids}
        #: MIGRATED / VERIFIER_DOWN events, in delivery order (observability)
        self.fleet_log: list = []

    # -- per-verifier clocks --------------------------------------------------
    def _busy(self, vid: str, t: float) -> bool:
        return t + _EPS < self._busy_until[vid]

    def _occupy(self, vid: str, t: float, dt: float) -> None:
        """Extend the verifier's busy span by ``dt`` (spans chain: a
        migration replay landing during an epoch queues behind it) and
        arm a GPU_DONE at the new end; earlier GPU_DONEs for the old end
        are superseded (ignored on pop)."""
        end = max(t, self._busy_until[vid]) + dt
        self._busy_until[vid] = end
        self.events.push(end, EventKind.GPU_DONE, vid)

    def _sched_dispatch(self, vid: str, t: float) -> None:
        cur = self._disp_at.get(vid)
        if cur is not None and cur <= t:
            return
        self._disp_at[vid] = t
        self.events.push(t, EventKind.DISPATCH, vid)

    def _kick(self, vid: str, t: float) -> None:
        backlog = (self.router.queue_depth(vid)
                   or self.router.verifiers[vid].throttle_backlog)
        if backlog and not self._busy(vid, t) and self.plan.is_up(vid, t):
            self._sched_dispatch(vid, t)

    def _verify_time_v(self, vid: str, served, t: float) -> float:
        """Per-verifier epoch duration: that verifier's scheduler pricing,
        shared jitter, and any injected straggle window."""
        dt = self.router.verifiers[vid].scheduler.batch_time(served)
        if self.cfg.latency_noise_sigma:
            dt *= float(np.exp(self._noise_rng.normal(
                0.0, self.cfg.latency_noise_sigma)))
        for svid, t0, t1, f in self._straggle:
            if svid == vid and t0 <= t < t1:
                dt *= f
        return dt

    # -- heartbeats + failover sweep -----------------------------------------
    def _before_run(self) -> None:
        for vid in self.vids:
            self.events.push(self.cfg.heartbeat_interval,
                             EventKind.HEARTBEAT, vid)

    def _handle_event(self, ev) -> None:
        if ev.kind == EventKind.HEARTBEAT:
            self._on_heartbeat(ev.payload, ev.time)
        else:
            super()._handle_event(ev)

    def _on_heartbeat(self, vid: str, t: float) -> None:
        if self.plan.is_up(vid, t):
            self.router.beat(vid, t)        # fires on_rejoin on recovery
        self._fleet_sweep(t)
        if not (self.cfg.rounds is not None
                and self._done_devices == len(self.devs)):
            self.events.push(t + self.cfg.heartbeat_interval,
                             EventKind.HEARTBEAT, vid)

    def _fleet_sweep(self, t: float) -> None:
        """Death detection + straggler hedging (runs every heartbeat and
        at the top of every dispatch epoch)."""
        for vid in self.router.sweep(t):
            self._on_verifier_down(vid, t)
        for (sid, rnd), backup in self.router.sweep_hedges(t):
            dev = self._by_session.get(sid)
            if (dev is None or dev.inflight is None
                    or not dev.request_arrived or dev.rounds_done != rnd):
                continue                    # round resolved/closed under us
            self._migrate(dev, t, target=backup)
        self._drain_fleet(t)

    def _on_verifier_down(self, vid: str, t: float) -> None:
        # Never-started sessions first: their cancellation has no side
        # effects, so the later closes' _try_admit retries find an empty
        # queue instead of re-admitting onto the dead verifier.
        started = []
        for sid in self.router.sessions_on(vid):
            dev = self._by_session.get(sid)
            if dev is None:
                continue
            if dev.state in ("admission", "prefill"):
                self.router.reopen_session(sid, self._pending_open[sid],
                                           now=t)
            elif dev.state in ("draft", "wait"):
                started.append(dev)
        for dev in started:
            self._migrate(dev, t)
        self.router.scrub(vid)

    def _migrate(self, dev, t: float, target: str | None = None) -> None:
        """Move a streaming session to a new verifier: replay its
        committed stream (estimator-priced on the destination's clock,
        prefix-cache hits come off the bill) and re-dispatch the round the
        old owner was holding, if any."""
        sid = dev.session_id
        committed = list(dev.device.session.committed)
        dst, replayed = self.router.migrate_session(
            sid, committed, rounds=dev.rounds_done, now=t, target=target,
        )
        if replayed > 0:
            dt = self.router.coeffs.predict([BatchShape(
                new_tokens=replayed,
                cached_tokens=len(committed) - 1 - replayed,
            )])
            self._occupy(dst, t, float(dt))
        if dev.inflight is not None and dev.request_arrived:
            res = dev.inflight
            self.router.resubmit(
                sid, res.tokens, res.q_logits, q_compact=res.q_compact,
                now=t, t_draft=dev.last_t_draft, t_network=dev.last_t_net,
                round_index=dev.rounds_done,
            )
        self._kick(dst, t)

    # -- serving-tier hooks (routed versions of the base seams) ---------------
    def _admit_session(self, dev, sid, prompt, t: float) -> None:
        vid = self.router.open_session(
            sid, prompt, slo_class=dev.profile.slo_class,
            draft_speed=dev.profile.draft_speed, now=t,
            tenant=dev.profile.tenant,
        )
        self._drain_fleet(t)
        if self.cfg.prefill_mode == "chunked" and dev.state == "admission":
            self._kick(vid, t)

    def _server_close(self, sid: int, t: float) -> None:
        vid = self.router.close_session(sid, now=t)
        self._drain_fleet(t)
        if vid is not None:
            self._kick(vid, t)

    def _on_request(self, dev, t: float, rnd: int | None = None) -> None:
        res = dev.inflight
        if res is None or dev.session_id not in self.router.owner:
            return                          # closed/raced under us
        if rnd is not None and dev.rounds_done != rnd:
            # late duplicate of an already-resolved round (chaos uplink)
            self.metrics.chaos.stale_requests_dropped += 1
            return
        dev.request_arrived = True
        vid = self.router.submit(
            dev.session_id, res.tokens, res.q_logits, q_compact=res.q_compact,
            now=t, t_draft=dev.last_t_draft, t_network=dev.last_t_net,
            round_index=dev.rounds_done,
        )
        # replayed verdicts emitted during submit ride the downlink now
        self._drain_fleet(t)
        self._kick(vid, t)

    # -- event handlers -------------------------------------------------------
    def _on_dispatch(self, t: float, payload=None) -> None:
        vid = payload
        self._disp_at[vid] = None
        self._fleet_sweep(t)                # failover check every epoch
        if not self.plan.is_up(vid, t):
            return                          # a down verifier runs nothing
        if self._busy(vid, t):
            return
        srv = self.router.verifiers[vid]
        if not (srv.queue_depth or srv.throttle_backlog):
            return
        self.router.step(
            vid, t, verify_time=lambda served: self._verify_time_v(
                vid, served, t),
        )
        self.metrics.sample_queue(
            t, sum(self.router.queue_depth(v) for v in self.vids)
        )
        if srv.last_served:
            dt = srv.last_verify_time
            self._occupy(vid, t, dt)
            self._drain_fleet(t, src=vid, t_sent=t + dt)
        else:
            self._drain_fleet(t)
            if srv.queue_depth or srv.throttle_backlog:
                self._sched_dispatch(vid, t + self.cfg.dispatch_interval)

    def _on_gpu_done(self, t: float, payload=None) -> None:
        vid = payload
        if self._busy(vid, t):
            return                          # superseded by a longer span
        self._kick(vid, t)

    def _on_verdict(self, payload, t: float) -> None:
        vid, t_sent, v = payload
        if not self.plan.is_up(vid, t_sent):
            # the epoch would have completed after the verifier died: the
            # verdict was never sent (re-dispatch will resolve the round)
            self.router.note_lost_verdict()
            return
        if not self.router.deliver_verdict(vid, v):
            return                          # stale owner / duplicate round
        super()._on_verdict(v, t)

    def _on_first_token(self, payload, t: float) -> None:
        vid, sid, first = payload
        if self.router.owner.get(sid) != vid:
            return                          # stale: session moved on
        super()._on_first_token((sid, first), t)

    # -- event routing --------------------------------------------------------
    def _drain_fleet(self, t: float, src: str | None = None,
                     t_sent: float | None = None) -> None:
        """Route the merged fleet event stream onto the virtual clock.
        Events from the epoch just executed on ``src`` leave the server
        at ``t_sent`` (epoch end — also the died-before-sending stamp);
        everything else — admission retries, instant zero-mode first
        tokens, replayed verdicts surfaced during an idempotent submit —
        leaves now.  Verdicts ride the downlink through
        `_push_fleet_verdict` (per-message jitter + chaos fates)."""
        for vid, ev in self.router.pop_events():
            from_epoch = vid == src and t_sent is not None
            ts = t_sent if from_epoch else t
            if ev.kind == "VERDICT":
                self._push_fleet_verdict(vid, ev.verdict, ts)
            elif ev.kind == "FIRST_TOKEN":
                if self.cfg.prefill_mode == "chunked" and from_epoch:
                    self.events.push(ts + self.net.downlink_time(),
                                     EventKind.FIRST_TOKEN,
                                     (vid, ev.session_id, ev.token))
                else:
                    self._on_first_token((vid, ev.session_id, ev.token), t)
            elif ev.kind == "REJECTED":
                self._on_rejected(ev.session_id, t)
            elif ev.kind in ("MIGRATED", "VERIFIER_DOWN"):
                self.fleet_log.append(ev)
            # ADMITTED / THROTTLED / PREEMPTED / TTFT_RECORD / CLOSED:
            # no runtime action

    def _push_fleet_verdict(self, vid: str, v, t_sent: float) -> None:
        """Fleet twin of the base `_push_verdict`: same downlink pricing
        and chaos fates, but the VERDICT payload carries the sending
        verifier (owner gate) and the send stamp (died-before-sending
        check)."""
        dev = self._by_session.get(v.session_id)
        rnd = int(getattr(v, "round_index", -1))
        n = 0
        if dev is not None:
            n = dev.down_attempts
            dev.down_attempts += 1
        lat = self.net.downlink_time(
            key=self._net_key(1, v.session_id, rnd, n))
        payload = (vid, t_sent, v)
        if dev is not None and dev.chaos is not None:
            times = dev.chaos.deliveries(
                "down", (v.session_id, rnd + 1, n), t_sent, lat)
            ch = self.metrics.chaos
            if not times:
                ch.downlink_drops += 1
            elif len(times) > 1:
                ch.downlink_dups += len(times) - 1
            for ts in times:
                self.events.push(ts, EventKind.VERDICT, payload)
        else:
            self.events.push(t_sent + lat, EventKind.VERDICT, payload)

    def _serving_nodes(self) -> list:
        return list(self.router.verifiers.values())

    def _drain_server_events(self, t, t_sent=None):  # pragma: no cover
        raise NotImplementedError(
            "fleet runtime drains through _drain_fleet"
        )
