"""Verifier-fleet router: prefix-locality placement + failover plumbing.

One `FleetRouter` fronts N independent `WISPServer` verifiers (each with
its own engine, page pool and SLO scheduler) and owns the three fleet
concerns (docs/ARCHITECTURE.md §7):

  * **placement** — a new session routes to the alive verifier whose
    content-addressed prefix index (`PageAllocator.prefix_index`, the
    chained page hashes from PR 1) covers the longest leading stretch of
    its prompt; on a tie or full miss, to the least-loaded verifier.
    The walk is read-only: routing must not perturb cache hit/refcount
    accounting.
  * **liveness** — a `HeartbeatMonitor` declares verifiers dead after a
    missed-beat window and fires death/rejoin hooks that keep the
    `HedgedDispatcher`'s rotation in sync (the ISSUE-6 membership bug);
  * **failover** — every in-flight verify round is tracked under the
    idempotency key ``(session_id, round_index)``; verdicts are delivered
    owner-authoritatively (a verdict from a verifier that no longer owns
    the session is dropped — the re-dispatched round on the new owner is
    the one that advances the device) and deduped through the
    dispatcher's first-wins commit.  Dead or straggling verifiers hand
    their sessions over via `migrate_session`: the committed stream is
    replayed as a chunked prefill (`WISPServer.restore_session`) on the
    destination, which is lossless under rng-tagged verification
    (DESIGN.md §10).

The router is driver-agnostic: `repro.fleet.runtime.FleetRuntime` drives
it on the virtual clock, but every method is plain synchronous Python.
Events drain as ``(verifier_id, ServerEvent)`` pairs via ``pop_events``;
events emitted by a verifier that lost ownership of the session in the
meantime are filtered out (stale-owner events would double-deliver).
"""
from __future__ import annotations

import dataclasses

from repro.core.estimator import BatchShape
from repro.runtime.failure import HeartbeatMonitor
from repro.runtime.straggler import HedgedDispatcher
from repro.serving.events import Migrated, VerifierDown
from repro.tenancy import DEFAULT_TENANT


class FleetCapacityError(RuntimeError):
    """No alive verifier can take the session (all dead, or none has the
    slots/pages a restore needs)."""


@dataclasses.dataclass
class SessionMeta:
    """Router-side soft state per session (survives verifier death).

    ``alpha`` / ``spec_k`` replicate the owner's live adaptive-speculation
    context (EWMA acceptance, last draft-length cap) — refreshed on every
    submit, when the owner is by construction alive — so a migrated
    session's restore does NOT reset them to cold-start defaults (the
    adaptive-K controller would otherwise re-converge from scratch after
    every failover)."""

    slo_class: int
    draft_speed: float
    extras: object = None
    alpha: float = 0.6
    spec_k: int = 0
    #: owning tenant (DESIGN.md §13) — must survive migration so the
    #: restored session keeps its fair-share weight and budget accounting
    tenant: str = DEFAULT_TENANT


class FleetRouter:
    """Routes sessions across verifiers; see module docstring."""

    def __init__(
        self,
        verifiers,
        *,
        heartbeat_timeout: float = 0.15,
        hedge_factor: float = 8.0,
        hedge_guard: float = 0.01,
    ):
        if not verifiers:
            raise ValueError("need at least one verifier")
        if isinstance(verifiers, dict):
            self.verifiers = dict(verifiers)
        else:
            self.verifiers = {f"v{i}": srv for i, srv in enumerate(verifiers)}
        self.monitor = HeartbeatMonitor(
            timeout=heartbeat_timeout,
            on_death=self._on_death,
            on_rejoin=self._on_rejoin,
        )
        for vid in self.verifiers:
            self.monitor.register(vid, 0.0)
        self.dispatcher = HedgedDispatcher(
            list(self.verifiers), guard=hedge_guard, hedge_factor=hedge_factor
        )
        #: session id -> verifier id currently authoritative for it
        self.owner: dict[int, str] = {}
        self.meta: dict[int, SessionMeta] = {}
        self._events: list[tuple] = []      # (vid, ServerEvent)
        self.stats = {
            "opened": 0,
            "migrations": 0,
            "reopens": 0,
            "redispatches": 0,
            "verifier_downs": 0,
            "rejoins": 0,
            "stale_events_dropped": 0,
            "dropped_verdicts": 0,
            "lost_verdicts": 0,
            "replayed_verdicts": 0,
        }

    # -- uniform-fleet conveniences (the runtime reads these) ----------------
    @property
    def network(self):
        return next(iter(self.verifiers.values())).network

    @property
    def slo_classes(self):
        return next(iter(self.verifiers.values())).slo_classes

    @property
    def coeffs(self):
        return next(iter(self.verifiers.values())).coeffs

    @property
    def policy(self):
        return next(iter(self.verifiers.values())).policy

    @property
    def ttft_slo(self):
        return next(iter(self.verifiers.values())).ttft_slo

    @property
    def prefill_log(self):
        """Fleet-wide view of the verifiers' completed chunked prefills."""
        return [r for v in self.verifiers.values() for r in v.prefill_log]

    @property
    def engines(self):
        return [v.engine for v in self.verifiers.values()]

    # -- liveness ------------------------------------------------------------
    def beat(self, vid: str, now: float) -> None:
        self.monitor.beat(vid, now)

    def sweep(self, now: float) -> list[str]:
        """Heartbeat sweep; returns verifiers newly declared dead (their
        death hooks — dispatcher removal, VERIFIER_DOWN event — already
        ran).  The caller migrates the dead verifiers' sessions."""
        return self.monitor.sweep(now)

    def sweep_hedges(self, now: float) -> list[tuple]:
        """Straggler sweep: in-flight rounds past their hedge deadline,
        as ``((session_id, round_index), backup_vid)`` pairs."""
        return self.dispatcher.sweep(now)

    def alive_ids(self) -> list[str]:
        return [v for v in self.verifiers if self.monitor.peers[v].alive]

    def _on_death(self, vid: str, now: float) -> None:
        self.stats["verifier_downs"] += 1
        self.dispatcher.remove_replica(vid)
        self._events.append((vid, VerifierDown(-1, now, vid)))

    def _on_rejoin(self, vid: str, now: float) -> None:
        self.stats["rejoins"] += 1
        self.dispatcher.add_replica(vid)

    # -- placement -----------------------------------------------------------
    def _prefix_coverage(self, vid: str, tokens) -> int:
        """Leading tokens of ``tokens`` resident in the verifier's prefix
        index, by the read-only chained-page-hash walk (no hit/refcount
        mutation — this is a routing probe, not an open)."""
        engine = self.verifiers[vid].engine
        if not getattr(engine, "paged", False):
            return 0
        alloc = engine.kv.allocator
        ps = alloc.page_size
        h = b"root"
        n = 0
        for s in range(0, len(tokens) - ps + 1, ps):
            h = alloc.chain_hash(h, tokens[s:s + ps])
            if h not in alloc.prefix_index:
                break
            n += ps
        return n

    def _load(self, vid: str) -> int:
        srv = self.verifiers[vid]
        return len(srv.sessions) + len(srv.prefilling) + len(srv.admission_queue)

    def route(self, prompt_tokens, exclude=()) -> str:
        """Pick a verifier for a prompt: longest prefix-index coverage
        among alive candidates, falling back to least-loaded (ties break
        on the verifier id, which self-balances: the winner's load rises
        by one and the next tie goes elsewhere)."""
        alive = [v for v in self.alive_ids() if v not in exclude]
        if not alive:
            raise FleetCapacityError("no alive verifier to route to")
        best, best_cov = None, 0
        for vid in alive:
            cov = self._prefix_coverage(vid, prompt_tokens)
            if cov > best_cov:
                best, best_cov = vid, cov
        if best is not None:
            return best
        return min(alive, key=lambda v: (self._load(v), v))

    # -- session lifecycle ---------------------------------------------------
    def open_session(self, session_id: int, prompt_tokens, *,
                     slo_class: int | None = None, draft_speed: float = 50.0,
                     extras=None, now: float = 0.0,
                     tenant: str = DEFAULT_TENANT) -> str:
        vid = self.route(prompt_tokens)
        self.owner[session_id] = vid
        srv = self.verifiers[vid]
        srv.open_session(
            session_id, prompt_tokens, slo_class=slo_class,
            draft_speed=draft_speed, extras=extras, queue_on_full=True,
            now=now, tenant=tenant,
        )
        # record the RESOLVED class (tenant default applied server-side)
        # so a migration restores the same contract
        spec = srv.tenants.get(tenant).spec
        if slo_class is None:
            slo_class = spec.slo_class if spec.slo_class is not None else 3
        self.meta[session_id] = SessionMeta(
            slo_class, draft_speed, extras, tenant=tenant,
        )
        self.stats["opened"] += 1
        self._drain(vid)
        return vid

    def close_session(self, session_id: int, now: float = 0.0) -> str | None:
        vid = self.owner.pop(session_id, None)
        self.meta.pop(session_id, None)
        self.dispatcher.inflight = {
            k: f for k, f in self.dispatcher.inflight.items()
            if k[0] != session_id
        }
        if vid is None:
            return None
        self.verifiers[vid].close_session(session_id, now=now)
        self._drain(vid)
        return vid

    def sessions_on(self, vid: str) -> list[int]:
        return sorted(s for s, v in self.owner.items() if v == vid)

    # -- request path --------------------------------------------------------
    def _track(self, session_id: int, vid: str, n_draft: int, now: float,
               hedged: bool) -> None:
        srv = self.verifiers[vid]
        s = srv.sessions[session_id]
        eta = srv.coeffs.predict([BatchShape(
            new_tokens=n_draft + 1, cached_tokens=s.committed_len - 1,
        )])
        # replicate the session's adaptive-speculation context into the
        # router's soft state while the owner is alive: a later migration
        # restores alpha/spec_k instead of cold-start defaults
        m = self.meta.get(session_id)
        if m is not None:
            m.alpha, m.spec_k = s.alpha, s.spec_k
        key = (session_id, s.rounds)
        self.dispatcher.track(key, vid, float(eta), now)
        if hedged:
            self.dispatcher.inflight[key].hedged = True

    def submit(self, session_id: int, draft_tokens, q_logits=None, *,
               q_compact=None, now: float, t_draft: float,
               t_network: float, round_index: int | None = None) -> str:
        """Queue a drafted block on the session's owner; the round enters
        the dispatcher's in-flight tracking under (session_id, rounds).
        A duplicate the owner absorbed (idempotent ``WISPServer.submit``
        returned None) is NOT re-tracked: tracking an already-committed
        round would leave a stale in-flight entry the straggler sweep
        hedges forever."""
        vid = self.owner[session_id]
        srv = self.verifiers[vid]
        rid = srv.submit(session_id, draft_tokens, q_logits,
                         q_compact=q_compact, now=now, t_draft=t_draft,
                         t_network=t_network, round_index=round_index)
        if rid is not None:
            self._track(session_id, vid, len(draft_tokens), now,
                        hedged=False)
        self._drain(vid)
        return vid

    def resubmit(self, session_id: int, draft_tokens, q_logits=None, *,
                 q_compact=None, now: float, t_draft: float,
                 t_network: float, round_index: int | None = None) -> str:
        """Re-dispatch an in-flight round to the session's (new) owner
        after a migration; marked hedged so the sweep never re-hedges it."""
        vid = self.owner[session_id]
        srv = self.verifiers[vid]
        rid = srv.submit(session_id, draft_tokens, q_logits,
                         q_compact=q_compact, now=now, t_draft=t_draft,
                         t_network=t_network, round_index=round_index)
        if rid is not None:
            self._track(session_id, vid, len(draft_tokens), now,
                        hedged=True)
        self.stats["redispatches"] += 1
        self._drain(vid)
        return vid

    def step(self, vid: str, now: float, *, verify_time=None) -> list:
        verdicts = self.verifiers[vid].step(now, verify_time=verify_time)
        self._drain(vid)
        return verdicts

    def queue_depth(self, vid: str) -> int:
        return self.verifiers[vid].queue_depth

    # -- failover ------------------------------------------------------------
    def migrate_session(self, session_id: int, committed_tokens, *,
                        rounds: int, now: float = 0.0,
                        target: str | None = None) -> tuple[str, int]:
        """Move a session off its owner by replaying its committed stream
        (device-side ground truth) as a prefill on a destination picked by
        prefix locality (the dead verifier may not be the only one holding
        the prefix) then least-loaded.  Returns ``(dst, replayed_tokens)``.

        ``rounds`` must be the device's delivered-verdict count: the
        restored server session resumes the (session_id, round_index)
        keying exactly where the device left it, so re-dispatched rounds
        collide with — and are deduped against — their lost originals."""
        src = self.owner[session_id]
        m = self.meta[session_id]
        committed = [int(t) for t in committed_tokens]
        candidates = [v for v in self.alive_ids() if v != src]
        if target in candidates:
            candidates.remove(target)
            candidates.insert(0, target)
        else:
            ordered = self.route(committed, exclude=(src,))
            candidates.remove(ordered)
            candidates.insert(0, ordered)
        last_err = None
        for dst in candidates:
            try:
                replayed = self.verifiers[dst].restore_session(
                    session_id, committed, slo_class=m.slo_class,
                    draft_speed=m.draft_speed, rounds=rounds,
                    alpha=m.alpha, spec_k=m.spec_k,
                    extras=m.extras, now=now, tenant=m.tenant,
                )
            except Exception as e:          # OutOfPages / NoFreeSlots
                last_err = e
                continue
            self.owner[session_id] = dst
            # tear down the source copy AFTER ownership moved: its CLOSED
            # (and any queued-admission) events now fail the owner filter
            if self._has_session(src, session_id):
                self.verifiers[src].close_session(session_id, now=now)
            self._drain(src)
            self._drain(dst)
            self.stats["migrations"] += 1
            self._events.append((dst, Migrated(
                session_id, now, src, dst, replayed)))
            return dst, replayed
        raise FleetCapacityError(
            f"no verifier can restore session {session_id}"
        ) from last_err

    def reopen_session(self, session_id: int, prompt_tokens,
                       now: float = 0.0) -> str:
        """Failover for a session that never started streaming (queued or
        still prefilling on a dead verifier): cancel the source copy and
        open it afresh elsewhere — nothing committed, nothing to replay."""
        src = self.owner[session_id]
        m = self.meta[session_id]
        dst = self.route(prompt_tokens, exclude=(src,))
        self.owner[session_id] = dst
        if self._has_session(src, session_id):
            self.verifiers[src].close_session(session_id, now=now)
        self._drain(src)
        self.verifiers[dst].open_session(
            session_id, prompt_tokens, slo_class=m.slo_class,
            draft_speed=m.draft_speed, extras=m.extras, queue_on_full=True,
            now=now, tenant=m.tenant,
        )
        self.stats["reopens"] += 1
        self._drain(dst)
        self._events.append((dst, Migrated(session_id, now, src, dst, 0)))
        return dst

    def scrub(self, vid: str) -> None:
        """Post-failover cleanup of a dead verifier's host-side state:
        close any leftover sessions (their owners have all moved, so the
        events are filtered) and empty its pending pool."""
        srv = self.verifiers[vid]
        for sid in (set(srv.sessions) | set(srv.prefilling)
                    | {e[0] for e in srv.admission_queue}
                    | srv.throttled_session_ids()):
            srv.close_session(sid, now=srv.now)
        srv.pending = []
        self._drain(vid)

    def deliver_verdict(self, vid: str, verdict) -> bool:
        """Delivery-time gate: owner-authoritative + idempotent.  A verdict
        from a verifier that lost the session (migration raced it) is
        dropped — the re-dispatched round on the new owner advances the
        device instead, keeping device and owner state in lockstep.  The
        dispatcher's first-wins commit on (session_id, round_index)
        dedupes the round; an owner-sent copy of an already-committed
        round is a *replay* (the original verdict died on a flaky
        downlink and the device re-submitted, DESIGN.md §14) and IS
        delivered — the device's own round gate absorbs true duplicates."""
        sid = verdict.session_id
        if self.owner.get(sid) != vid:
            self.stats["dropped_verdicts"] += 1
            return False
        if not self.dispatcher.commit((sid, verdict.round_index)):
            self.stats["replayed_verdicts"] += 1
        return True

    def note_lost_verdict(self) -> None:
        """A verdict's epoch never finished (verifier died mid-epoch)."""
        self.stats["lost_verdicts"] += 1

    # -- event stream --------------------------------------------------------
    def _has_session(self, vid: str, sid: int) -> bool:
        srv = self.verifiers[vid]
        return (sid in srv.sessions or sid in srv.prefilling
                or sid in srv.admission_queue
                or sid in srv.throttled_session_ids())

    def _drain(self, vid: str) -> None:
        for ev in self.verifiers[vid].pop_events():
            if self.owner.get(ev.session_id) != vid:
                self.stats["stale_events_dropped"] += 1
                continue
            self._events.append((vid, ev))
            if ev.kind == "REJECTED":
                # terminal for the session: release router ownership so a
                # retry under the same id routes (and counts) fresh
                self.owner.pop(ev.session_id, None)
                self.meta.pop(ev.session_id, None)

    def pop_events(self) -> list[tuple]:
        """Drain the merged fleet stream as (verifier_id, ServerEvent)
        pairs: every verifier's surviving (owner-matching) events plus the
        router's own MIGRATED / VERIFIER_DOWN emissions, in order."""
        out, self._events = self._events, []
        return out
