"""Architecture configs (one module per assigned arch) + paper's own pair.

``get_config(name)`` resolves any of the 10 assigned architectures plus the
paper's Qwen3-style draft/target pair used in end-to-end WISP examples.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, get_config, list_archs, register
from repro.configs.shapes import SHAPES, ShapeConfig, cell_status, cells

# Assigned pool — importing registers each config.
from repro.configs import xlstm_350m          # noqa: F401
from repro.configs import llama_32_vision_90b  # noqa: F401
from repro.configs import gemma2_9b           # noqa: F401
from repro.configs import starcoder2_15b      # noqa: F401
from repro.configs import stablelm_12b        # noqa: F401
from repro.configs import qwen2_7b            # noqa: F401
from repro.configs import grok_1_314b         # noqa: F401
from repro.configs import deepseek_moe_16b    # noqa: F401
from repro.configs import whisper_tiny        # noqa: F401
from repro.configs import zamba2_1p2b         # noqa: F401

# Paper's own serving pair (Qwen3-32B target / Qwen3-0.6B..8B drafts).
from repro.configs import qwen3_wisp          # noqa: F401

#: The 10 assigned architectures (dry-run / roofline cell enumeration).
ASSIGNED = [
    "xlstm-350m",
    "llama-3.2-vision-90b",
    "gemma2-9b",
    "starcoder2-15b",
    "stablelm-12b",
    "qwen2-7b",
    "grok-1-314b",
    "deepseek-moe-16b",
    "whisper-tiny",
    "zamba2-1.2b",
]

__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "list_archs",
    "register",
    "SHAPES",
    "ShapeConfig",
    "cell_status",
    "cells",
]
