"""xLSTM-350M [arXiv:2405.04517]: sLSTM + mLSTM blocks, d_ff=0 (blocks carry
their own up/down projections).  1 sLSTM per 4 layers, rest mLSTM."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50_304,
        head_dim=256,
        ssm=SSMConfig(state_dim=256, chunk=256, slstm_every=4),
    )
)
