"""Assigned input shapes and the (arch x shape) cell enumeration."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def cell_status(cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason).  long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: full softmax attention is quadratic"
    return True, ""


def cells(archs=None):
    """Yield (arch_name, shape_name, runnable, reason) for all 40 cells."""
    from repro.configs import ASSIGNED, get_config

    for a in archs or ASSIGNED:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = cell_status(cfg, s)
            yield a, s.name, ok, why
