"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64 routed
experts top-6, per-expert hidden 1408 (d_ff field)."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=102_400,
        head_dim=128,
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            num_shared_experts=2,
            d_expert=1408,
            parallelism="ep",
        ),
    )
)
