"""Gemma2-9B [arXiv:2408.00118]: local/global alternating attention
(window 4096 on local layers), attn-logit softcap 50, final-logit softcap 30,
head_dim 256 (query dim != d_model), tied embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        d_ff=14_336,
        vocab=256_000,
        head_dim=256,
        attn_softcap=50.0,
        final_softcap=30.0,
        sliding_window=4096,
        local_global_alternate=True,
        tie_embeddings=True,
        sandwich_norm=True,
        scale_embed=True,
    )
)
