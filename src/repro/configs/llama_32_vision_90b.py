"""Llama-3.2-Vision-90B backbone [hf:meta-llama/Llama-3.2-90B-Vision]:
100 layers with a cross-attention (image) layer every 5 self layers.
Vision frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings of length ``num_image_tokens``."""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28_672,
        vocab=128_256,
        head_dim=128,
        rope_theta=500_000.0,
        cross_attn_every=5,
        num_image_tokens=4096,
    )
)
