"""StarCoder2-15B [arXiv:2402.19173]: GQA kv=4, RoPE."""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24_576,
        vocab=49_152,
        head_dim=128,
        rope_theta=100_000.0,
        qkv_bias=True,
        gated_mlp=False,
    )
)
