"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder; conv frontend is a STUB
(``input_specs()`` supplies precomputed frame embeddings)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,              # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51_865,
        head_dim=64,
        encoder_layers=4,
        encoder_frames=1500,
        gated_mlp=False,
        tie_embeddings=True,
    )
)
