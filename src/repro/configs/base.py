"""Architecture config schema + registry.

One ``ArchConfig`` covers every family in the assigned pool:
dense / MoE / SSM (xLSTM) / hybrid (Mamba2+attn) / enc-dec (whisper) /
VLM (cross-attention).  Family-specific knobs default to "off".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

_REGISTRY: dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_expert: int = 0            # per-expert FFN hidden (0 -> use d_ff)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # "tp": experts sharded on the FFN hidden dim (no all-to-all);
    # "ep": experts sharded on the expert dim (GSPMD inserts all-to-all).
    parallelism: str = "tp"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # Mamba2 d_state / mLSTM key dim scale
    conv_kernel: int = 4         # Mamba2 local conv width
    expand: int = 2              # Mamba2 inner expansion
    chunk: int = 256             # SSD / chunked-scan chunk length
    slstm_every: int = 0         # xLSTM: 1 sLSTM block per this many layers
    attn_every: int = 0          # zamba: shared attention every N layers


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention variants
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0    # gemma2: 50.0 on attn logits
    final_softcap: float = 0.0   # gemma2: 30.0 on output logits
    sliding_window: int = 0      # local attention window (0 = full)
    local_global_alternate: bool = False  # gemma2: even layers local
    sandwich_norm: bool = False  # gemma2: post-norms after attn/mlp
    scale_embed: bool = False    # gemma2: embeddings scaled by sqrt(d)
    gated_mlp: bool = True       # False -> plain GELU MLP (starcoder2, whisper)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # family extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    cross_attn_every: int = 0    # vlm: cross-attn layer every N self layers
    num_image_tokens: int = 0    # vlm stub frontend output length
    encoder_layers: int = 0      # audio enc-dec
    encoder_frames: int = 0      # audio stub frontend output length
    # serving
    max_draft_len: int = 16

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        """True when no layer does full softmax attention over the prefix."""
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (recurrent state decode)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every pool member has an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        from repro.roofline.model_flops import param_count

        return param_count(self)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_expert=64 if self.moe.d_expert else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm,
                state_dim=16,
                chunk=16,
                slstm_every=min(self.ssm.slstm_every, 2),
                attn_every=min(self.ssm.attn_every, 2),
            )
        n_layers = 4 if (self.ssm or self.cross_attn_every) else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            moe=moe,
            ssm=ssm,
            cross_attn_every=2 if self.cross_attn_every else 0,
            num_image_tokens=8 if self.num_image_tokens else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_frames=16 if self.encoder_frames else 0,
            sliding_window=32 if self.sliding_window else 0,
        )


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
