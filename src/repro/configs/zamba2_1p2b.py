"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone with a SHARED attention
block applied every 6 layers (shared = same params each application)."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32_000,
        head_dim=64,
        ssm=SSMConfig(state_dim=64, conv_kernel=4, expand=2, chunk=256, attn_every=6),
    )
)
