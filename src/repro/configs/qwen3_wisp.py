"""The paper's own model pair: Qwen3-32B verification target and the
Qwen3-{0.6B,1.7B,4B,8B} draft ladder (§5.1).  Configs follow the published
Qwen3 geometry; used by the WISP serving examples and benchmarks."""
from repro.configs.base import ArchConfig, register

TARGET_32B = register(
    ArchConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_ff=25_600,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
    )
)

TARGET_14B = register(
    ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=17_408,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
    )
)

DRAFT_0p6B = register(
    ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
)

DRAFT_1p7B = register(
    ArchConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=6144,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
)

DRAFT_4B = register(
    ArchConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=9728,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
)

DRAFT_8B = register(
    ArchConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12_288,
        vocab=151_936,
        head_dim=128,
        rope_theta=1_000_000.0,
    )
)

DRAFTS = {
    "qwen3-0.6b": DRAFT_0p6B,
    "qwen3-1.7b": DRAFT_1p7B,
    "qwen3-4b": DRAFT_4B,
    "qwen3-8b": DRAFT_8B,
}
