"""Pallas TPU kernels for WISP's compute hot spots.

  verify_attention — small-Q x long-KV flash attention for batched
                     verification (the server hot path)
  paged_attention  — decode attention over paged KV with scalar-prefetched
                     block tables (PagedAttention, TPU-native)
  logit_features   — fused single-pass rejection-predictor features

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (public
jit'd wrapper with backend dispatch) and ref.py (pure-jnp oracle).
"""
from repro.kernels.verify_attention.ops import verify_attention_op, verify_attention_ref
from repro.kernels.paged_attention.ops import paged_attention_op, paged_attention_ref
from repro.kernels.logit_features.ops import logit_features_op, logit_features_ref

__all__ = [
    "verify_attention_op",
    "verify_attention_ref",
    "paged_attention_op",
    "paged_attention_ref",
    "logit_features_op",
    "logit_features_ref",
]
