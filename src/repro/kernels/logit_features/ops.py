"""Public entry for fused rejection features: Pallas on TPU, interpret mode
elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.logit_features.logit_features import logit_features as _kernel
from repro.kernels.logit_features.ref import logit_features_ref


def logit_features_op(logits, *, blk=2048):
    interpret = jax.default_backend() != "tpu"
    return _kernel(logits, blk=blk, interpret=interpret)


__all__ = ["logit_features_op", "logit_features_ref"]
