"""Oracle for the fused rejection-feature kernel = the predictor's feature
definition (`repro.core.features.logit_features`)."""
from repro.core.features import logit_features as logit_features_ref  # noqa: F401
