"""Pallas TPU kernel: fused rejection-predictor features (paper §3.3).

One pass over the vocabulary computes all five features per drafted token
— confidence, normalized entropy, top-2 margin, logit std, top-8 mass —
with "negligible overhead" as the paper requires: on the edge accelerator
this fuses what would otherwise be 4 separate vocab reductions (softmax,
top-k, entropy, std) into a single HBM sweep of the logits.

grid = (B, V // BLK).  Running state in VMEM scratch:
  m1/m2          global top-2 logits (pairwise merge per block)
  s0, s1         sum exp(x - mref), sum exp(x - mref) * x   (entropy)
  sx, sxx        sum x, sum x^2                             (std)
  top8           per-block top-8 merged into a running top-8 buffer

Entropy uses the flash-style shifted accumulators: when the running max
changes, s0/s1 are rescaled — H = logZ - E[x] with Z = s0 * e^{mref},
E[x] = s1/s0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(
    x_ref,        # (1, BLK)
    o_ref,        # (1, 5)
    m1_scr,       # (1, 1) running max
    m2_scr,       # (1, 1) running 2nd max
    s0_scr,       # (1, 1)
    s1_scr,       # (1, 1)
    sx_scr,       # (1, 1)
    sxx_scr,      # (1, 1)
    top8_scr,     # (1, 8)
    *,
    blk: int,
    nblk: int,
    V: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m1_scr[...] = jnp.full_like(m1_scr, NEG)
        m2_scr[...] = jnp.full_like(m2_scr, NEG)
        s0_scr[...] = jnp.zeros_like(s0_scr)
        s1_scr[...] = jnp.zeros_like(s1_scr)
        sx_scr[...] = jnp.zeros_like(sx_scr)
        sxx_scr[...] = jnp.zeros_like(sxx_scr)
        top8_scr[...] = jnp.full_like(top8_scr, NEG)

    x = x_ref[0].astype(jnp.float32)                       # (BLK,)
    # mask tail padding beyond V
    pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (blk,), 0)
    valid = pos < V
    xm = jnp.where(valid, x, NEG)

    # top-2 merge (duplicated maxima make the 2nd max equal the max)
    bm1 = jnp.max(xm)
    bm2 = jnp.max(jnp.where(xm == bm1, NEG, xm))
    dup = jnp.sum(jnp.where(xm == bm1, 1.0, 0.0)) > 1.5
    bm2 = jnp.where(dup, bm1, bm2)
    m1_old = m1_scr[0, 0]
    m2_old = m2_scr[0, 0]
    m1_new = jnp.maximum(m1_old, bm1)
    m2_new = jnp.maximum(
        m2_old,
        jnp.where(bm1 > m1_old, jnp.maximum(m1_old, bm2), bm1),
    )
    m2_new = jnp.minimum(m2_new, m1_new)
    m1_scr[0, 0] = m1_new
    m2_scr[0, 0] = m2_new

    # shifted exp accumulators (reference point = running max)
    corr = jnp.exp(m1_old - m1_new)
    e = jnp.where(valid, jnp.exp(xm - m1_new), 0.0)
    s0_scr[0, 0] = s0_scr[0, 0] * corr + jnp.sum(e)
    s1_scr[0, 0] = s1_scr[0, 0] * corr + jnp.sum(e * xm)

    # raw moments
    x0 = jnp.where(valid, x, 0.0)
    sx_scr[0, 0] = sx_scr[0, 0] + jnp.sum(x0)
    sxx_scr[0, 0] = sxx_scr[0, 0] + jnp.sum(x0 * x0)

    # running top-8: global top-8 is contained in (running top-8 U block top-8)
    cat = jnp.concatenate([top8_scr[0], jax.lax.top_k(xm, 8)[0]])
    top8_scr[0] = jax.lax.top_k(cat, 8)[0]

    @pl.when(j == nblk - 1)
    def _finish():
        m1 = m1_scr[0, 0]
        s0 = s0_scr[0, 0]
        s1 = s1_scr[0, 0]
        logz = jnp.log(s0) + m1
        mean_x = s1 / s0
        entropy = (logz - mean_x) / jnp.log(jnp.float32(V))
        conf = jnp.exp(m1 - logz)
        margin = conf - jnp.exp(m2_scr[0, 0] - logz)
        mean = sx_scr[0, 0] / V
        var = jnp.maximum(sxx_scr[0, 0] / V - mean * mean, 0.0)
        std = jnp.sqrt(var)
        mass8 = jnp.sum(jnp.exp(top8_scr[0] - logz))
        o_ref[0, 0] = conf
        o_ref[0, 1] = entropy
        o_ref[0, 2] = margin
        o_ref[0, 3] = std
        o_ref[0, 4] = mass8


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def logit_features(logits, *, blk: int = 2048, interpret: bool = False):
    """logits: (B, V) -> (B, 5) float32 feature rows."""
    B, V = logits.shape
    blk = min(blk, V)
    nblk = pl.cdiv(V, blk)
    if V % blk:
        logits = jnp.pad(logits, ((0, 0), (0, nblk * blk - V)))

    kernel = functools.partial(_kernel, blk=blk, nblk=nblk, V=V)
    out = pl.pallas_call(
        kernel,
        grid=(B, nblk),
        in_specs=[pl.BlockSpec((1, blk), lambda b, j: (b, j))],
        out_specs=pl.BlockSpec((1, 5), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 5), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 8), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return out
