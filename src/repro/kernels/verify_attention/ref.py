"""Pure-jnp oracle for the verification-attention kernel.

Semantics: for each row b, the T query tokens sit at absolute positions
``lengths[b] - T + t`` (t = 0..T-1); keys/values are valid on
``[0, lengths[b])``; causal within the block; optional sliding window and
logit softcap.  GQA via head groups.
"""
from __future__ import annotations

import jax.numpy as jnp


def verify_attention_ref(
    q,                  # (B, T, H, D)
    k,                  # (B, S, Hkv, D)
    v,                  # (B, S, Hkv, D)
    lengths,            # (B,) int32: valid KV length INCLUDING the T new ones
    *,
    softcap: float = 0.0,
    window: int = 0,
    scale=None,
):
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D**-0.5
    qg = q.reshape(B, T, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, kf) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kv_pos = jnp.arange(S)[None, :]                      # (1, S)
    q_pos = lengths[:, None] - T + jnp.arange(T)[None, :]  # (B, T)
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]       # (B, T, S) causal+len
    if window:
        mask = jnp.logical_and(
            mask, (q_pos[:, :, None] - kv_pos[:, None, :]) < window
        )
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgts,bshd->bthgd", p, vf)
    return o.reshape(B, T, H, D).astype(q.dtype)
