"""Public entry for verification attention: Pallas on TPU, interpret mode
(same kernel body, Python-evaluated) elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.verify_attention.verify_attention import verify_attention as _kernel
from repro.kernels.verify_attention.ref import verify_attention_ref


def verify_attention_op(q, k, v, lengths, *, softcap=0.0, window=0, blk_kv=512):
    interpret = jax.default_backend() != "tpu"
    return _kernel(
        q, k, v, lengths,
        softcap=softcap, window=window, blk_kv=blk_kv, interpret=interpret,
    )


__all__ = ["verify_attention_op", "verify_attention_ref"]
