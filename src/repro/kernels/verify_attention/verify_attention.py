"""Pallas TPU kernel: verification attention (small Q x long KV).

The server-side hot spot of WISP (DESIGN.md §2): each verification step
attends T = K+1 draft tokens (K <= 16) against a long committed prefix.

TPU mapping:
  * grid = (B, Hkv, S // BLK_KV) — KV-block loop innermost, so the online
    softmax state lives in VMEM scratch across grid steps (TPU grids are
    sequential on the last axis);
  * the Q tile for one (batch, kv-head) is all G = H/Hkv group heads x T
    tokens, flattened to (G*T, D) rows — one MXU matmul per KV block of
    shape (G*T, D) x (D, BLK_KV);
  * per-row absolute positions implement causal + length + window masking
    from a scalar-prefetched ``lengths`` vector;
  * softcap (gemma/grok) is applied pre-mask, matching the reference.

VMEM budget per step: q (G*T, D) + k/v (BLK_KV, D) + acc (G*T, D) + scores
(G*T, BLK_KV) — with D=128, BLK_KV=512, G*T<=128: ~0.6 MB << 16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    lengths_ref,          # scalar prefetch: (B,) int32
    q_ref,                # (1, T, 1, G, D)
    k_ref,                # (1, BLK, 1, D)
    v_ref,                # (1, BLK, 1, D)
    o_ref,                # (1, T, 1, G, D)
    m_scr,                # (GT, 1) f32
    l_scr,                # (GT, 1) f32
    acc_scr,              # (GT, D) f32
    *,
    T: int,
    G: int,
    blk: int,
    nblk: int,
    softcap: float,
    window: int,
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    GT = G * T

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    # skip blocks entirely past the valid length
    @pl.when(j * blk < length)
    def _compute():
        q = q_ref[0, :, 0].reshape(GT, -1).astype(jnp.float32)   # (T*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)                   # (BLK, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                                # (GT, BLK)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        # row r of the (T, G) flattening -> token index t = r // G
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (GT, blk), 0) // G
        q_pos = length - T + t_idx
        kv_pos = j * blk + jax.lax.broadcasted_iota(jnp.int32, (GT, blk), 1)
        mask = kv_pos <= q_pos
        if window:
            mask = jnp.logical_and(mask, (q_pos - kv_pos) < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                      # (GT, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                           # (GT, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == nblk - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(T, G, -1).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "window", "blk_kv", "interpret"),
)
def verify_attention(
    q,                  # (B, T, H, D)
    k,                  # (B, S, Hkv, D)
    v,                  # (B, S, Hkv, D)
    lengths,            # (B,) int32
    *,
    softcap: float = 0.0,
    window: int = 0,
    blk_kv: int = 512,
    interpret: bool = False,
):
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    blk = min(blk_kv, S)
    nblk = pl.cdiv(S, blk)
    if S % blk:
        pad = nblk * blk - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, T, Hkv, G, D)

    kernel = functools.partial(
        _kernel,
        T=T,
        G=G,
        blk=blk,
        nblk=nblk,
        softcap=softcap,
        window=window,
        scale=D**-0.5,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, T, 1, G, D), lambda b, h, j, L: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, blk, 1, D), lambda b, h, j, L: (b, j, h, 0)),
            pl.BlockSpec((1, blk, 1, D), lambda b, h, j, L: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, T, 1, G, D), lambda b, h, j, L: (b, 0, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G * T, 1), jnp.float32),
            pltpu.VMEM((G * T, 1), jnp.float32),
            pltpu.VMEM((G * T, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, T, H, D)
