"""Pure-jnp oracle for paged decode attention.

Gathers pages through the block table into dense KV and runs masked
attention of the single new token per sequence.
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_pages(pages, block_table):
    """pages: (n_pages, P, Hkv, D); block_table: (B, n_max) ->
    (B, n_max*P, Hkv, D)."""
    g = pages[block_table]                       # (B, n_max, P, Hkv, D)
    B, n_max, P = g.shape[:3]
    return g.reshape(B, n_max * P, *g.shape[3:])


def paged_verify_attention_ref(
    q,                 # (B, T, H, D) new tokens at positions base..base+T-1
    k_pages,           # (n_pages, P, Hkv, D) — new K/V already scattered in
    v_pages,           # (n_pages, P, Hkv, D)
    block_table,       # (B, n_max) int32
    base_lens,         # (B,) int32 committed kv tokens BEFORE the new block
    *,
    softcap: float = 0.0,
    scale=None,
):
    """Oracle for speculative verification over paged KV: query t of row b
    attends to kv positions < base_lens[b] + t + 1 (history + the new
    tokens up to and including itself)."""
    B, T, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D**-0.5
    k = gather_pages(k_pages, block_table).astype(jnp.float32)
    v = gather_pages(v_pages, block_table).astype(jnp.float32)
    S = k.shape[1]
    qg = q.reshape(B, T, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    lens = base_lens[:, None] + jnp.arange(T)[None, :] + 1          # (B, T)
    mask = jnp.arange(S)[None, None, :] < lens[:, :, None]          # (B, T, S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgts,bshd->bthgd", p, v)
    return o.reshape(B, T, H, D).astype(q.dtype)


def paged_attention_ref(
    q,                 # (B, H, D) one new token per sequence
    k_pages,           # (n_pages, P, Hkv, D)
    v_pages,           # (n_pages, P, Hkv, D)
    block_table,       # (B, n_max) int32
    lengths,           # (B,) int32 valid kv tokens (including current)
    *,
    softcap: float = 0.0,
    scale=None,
):
    B, H, D = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D**-0.5
    k = gather_pages(k_pages, block_table).astype(jnp.float32)
    v = gather_pages(v_pages, block_table).astype(jnp.float32)
    S = k.shape[1]
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(S)[None, :] < lengths[:, None]      # (B, S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return o.reshape(B, H, D).astype(q.dtype)
