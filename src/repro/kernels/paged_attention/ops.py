"""Public entry for paged decode attention: Pallas on TPU, interpret mode
elsewhere.

Two consumers:
  * plain decode — ``paged_attention_op``, one query token per sequence;
  * speculative verification — ``paged_verify_attention_op``, K+1 query
    tokens per sequence.  The verify block is flattened to (B*T) single-
    token rows whose per-row length pointers encode causality (row (b, t)
    sees base_lens[b] + t + 1 kv tokens), so the same single-query kernel
    serves both paths and the block table stays the only addressing
    structure (DESIGN.md §2).

``scatter_kv_pages`` is the functional write path: new K/V land in the
pages named by the block table; masked/padded positions are redirected to
the reserved scratch page 0 so they can never clobber live pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention as _kernel
from repro.kernels.paged_attention.ref import (
    gather_pages,
    paged_attention_ref,
    paged_verify_attention_ref,
)


def paged_attention_op(q, k_pages, v_pages, block_table, lengths, *, softcap=0.0):
    interpret = jax.default_backend() != "tpu"
    return _kernel(
        q, k_pages, v_pages, block_table, lengths,
        softcap=softcap, interpret=interpret,
    )


def paged_verify_attention_op(
    q,                 # (B, T, H, D) new tokens at positions base..base+T-1
    k_pages,           # (n_pages, P, Hkv, D) — new K/V already scattered in
    v_pages,           # (n_pages, P, Hkv, D)
    block_table,       # (B, n_max) int32
    base_lens,         # (B,) int32 committed kv tokens BEFORE the new block
    *,
    softcap: float = 0.0,
):
    """Batched multi-token verification attention over paged KV.

    Requires the new tokens' K/V to be scattered into the pages first (see
    ``scatter_kv_pages``); causality within the block then falls out of the
    per-row length pointer alone."""
    B, T, H, D = q.shape
    n_max = block_table.shape[1]
    qf = q.reshape(B * T, H, D)
    btf = jnp.repeat(block_table, T, axis=0)                        # (B*T, n_max)
    lenf = (base_lens[:, None] + jnp.arange(T)[None, :] + 1).reshape(-1)
    out = paged_attention_op(
        qf, k_pages, v_pages, btf, lenf.astype(jnp.int32), softcap=softcap
    )
    return out.reshape(B, T, H, D)


def scatter_kv_pages(
    k_pages,           # (n_pages, P, Hkv, D) one layer's pages
    v_pages,
    k_new,             # (B, T, Hkv, D) K/V of the new tokens
    v_new,
    block_table,       # (B, n_max) int32
    base_lens,         # (B,) int32 write offset (committed kv tokens)
    t_lens,            # (B,) int32 valid new tokens per row (<= T)
):
    """Write new K/V through the block table (functional scatter).

    Row b token t lands at page block_table[b, (base+t)//P], offset
    (base+t)%P.  Positions past t_lens[b] (draft-length padding, padded
    batch rows) are redirected to scratch page 0: distinct live rows write
    disjoint pages, so the only scatter collisions are garbage-on-garbage
    inside the scratch page."""
    n_pages, P = k_pages.shape[:2]
    B, T = k_new.shape[:2]
    n_max = block_table.shape[1]
    pos = base_lens[:, None] + jnp.arange(T)[None, :]               # (B, T)
    valid = jnp.arange(T)[None, :] < t_lens[:, None]                # (B, T)
    slot = jnp.clip(pos // P, 0, n_max - 1)
    pid = jnp.take_along_axis(block_table, slot, axis=1)            # (B, T)
    pid = jnp.where(valid, pid, 0)
    off = jnp.where(valid, pos % P, 0)
    k_pages = k_pages.at[pid, off].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[pid, off].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


__all__ = [
    "paged_attention_op",
    "paged_attention_ref",
    "paged_verify_attention_op",
    "paged_verify_attention_ref",
    "scatter_kv_pages",
    "gather_pages",
]
