"""Public entry for paged decode attention: Pallas on TPU, interpret mode
elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref, gather_pages


def paged_attention_op(q, k_pages, v_pages, block_table, lengths, *, softcap=0.0):
    interpret = jax.default_backend() != "tpu"
    return _kernel(
        q, k_pages, v_pages, block_table, lengths,
        softcap=softcap, interpret=interpret,
    )


__all__ = ["paged_attention_op", "paged_attention_ref", "gather_pages"]
