"""Pallas TPU kernel: paged decode attention (PagedAttention, TPU-native).

vLLM pages (16 tokens, per-SM gather) do not map to TPU; instead a page IS
a KV tile (256 tokens = one DMA) and the block table drives the BlockSpec
``index_map`` through scalar prefetch — page lookup becomes tile prefetch,
the TPU-idiomatic equivalent of paged gathering (DESIGN.md §2).

grid = (B, Hkv, n_max_pages); the online-softmax state for the single query
token (x G group heads) lives in VMEM scratch across the page loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    block_table_ref,     # scalar prefetch: (B, n_max) int32
    lengths_ref,         # scalar prefetch: (B,) int32
    q_ref,               # (1, 1, G, D)
    k_ref,               # (1, P, 1, D)   page selected by index_map
    v_ref,               # (1, P, 1, D)
    o_ref,               # (1, 1, G, D)
    m_scr,               # (G, 1)
    l_scr,               # (G, 1)
    acc_scr,             # (G, D)
    *,
    page: int,
    n_max: int,
    softcap: float,
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]

    @pl.when(j * page < length)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)              # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)              # (P, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                           # (G, P)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kv_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(j == n_max - 1)
    def _finish():
        o_ref[0, 0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "interpret")
)
def paged_attention(
    q,                 # (B, H, D)
    k_pages,           # (n_pages, P, Hkv, D)
    v_pages,           # (n_pages, P, Hkv, D)
    block_table,       # (B, n_max) int32
    lengths,           # (B,) int32
    *,
    softcap: float = 0.0,
    interpret: bool = False,
):
    B, H, D = q.shape
    n_pages, P, Hkv, _ = k_pages.shape
    G = H // Hkv
    n_max = block_table.shape[1]
    qg = q.reshape(B, 1, Hkv, G, D)

    kernel = functools.partial(
        _kernel, page=P, n_max=n_max, softcap=softcap, scale=D**-0.5
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_max),
        in_specs=[
            pl.BlockSpec((1, 1, 1, G, D), lambda b, h, j, bt, L: (b, 0, h, 0, 0)),
            # the paged lookup: page id comes from the scalar-prefetched table
            pl.BlockSpec((1, P, 1, D), lambda b, h, j, bt, L: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, P, 1, D), lambda b, h, j, bt, L: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, G, D), lambda b, h, j, bt, L: (b, 0, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32), qg, k_pages, v_pages)
    return out.reshape(B, H, D)
