"""Optimizers: AdamW (f32 states over bf16 params) and Adafactor
(factored second moments — the memory-feasible choice for the 90B/314B
training cells; see EXPERIMENTS.md §Dry-run memory notes).

Pure-pytree implementations; optimizer states mirror parameter logical axes
so FSDP/TP sharding applies to them unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        # f32 master weights: updates accumulate here and params are the
        # bf16 cast.  Casting p - lr*u straight back to bf16 silently
        # drops any update below the bf16 spacing (~4e-4 relative) — at
        # warmup learning rates that is EVERY update, and training
        # flatlines.
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, mi, vi, mw):
        u = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            u = u + cfg.weight_decay * mw
        return mw - lr * u

    master = jax.tree.map(upd, params, m, v, state["master"])
    params = jax.tree.map(lambda p, mw: mw.astype(p.dtype), params, master)
    return params, {"m": m, "v": v, "master": master, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(one, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)

    def upd(p, g, v):
        g2 = g * g + 1e-30
        if _factored(p):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30)
            )
            u = g * jax.lax.rsqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            u = g * jax.lax.rsqrt(nv["v"] + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    params = tdef.unflatten([o[0] for o in out])
    v = tdef.unflatten([o[1] for o in out])
    return params, {"v": v, "step": step}, {"grad_norm": gnorm, "lr": lr}


def opt_init(name: str):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name]


def opt_update(name: str):
    return {"adamw": adamw_update, "adafactor": adafactor_update}[name]


def opt_state_axes(name: str, param_axes):
    """Logical axes for the optimizer state (mirrors params)."""
    is_ax = lambda x: isinstance(x, tuple)
    if name == "adamw":
        return {
            "m": param_axes,
            "v": param_axes,
            "master": param_axes,
            "step": (),
        }
    # adafactor: vr drops the last axis, vc drops the second-to-last
    def one(axes):
        if len(axes) >= 2:
            return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
        return {"v": axes}

    return {
        "v": jax.tree.map(one, param_axes, is_leaf=is_ax),
        "step": (),
    }
