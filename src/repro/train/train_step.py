"""pjit train/serve step factories with logical-axis shardings.

``make_train_step(cfg, mesh)`` returns (step_fn, shardings) where step_fn is
a jit-compiled ``(params, opt_state, batch) -> (params, opt_state, metrics)``
with FSDP+TP shardings resolved from the config's logical axes, remat over
layer scans, and the fused chunked loss.

``make_serve_steps(cfg, mesh)`` returns jit-compiled prefill/decode entry
points with serving shardings (same functions the dry-run lowers).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    ShardCtx,
    logical_to_spec,
    make_param_shardings,
)
from repro.models import batch_axes, batch_specs, build
from repro.models.zoo import cache_specs
from repro.train.optimizer import OptConfig, opt_init, opt_state_axes, opt_update


def _shardings_for(tree_axes, tree_shapes, mesh, rules):
    return make_param_shardings(tree_axes, tree_shapes, mesh, rules)


def param_shapes(cfg, dtype=jnp.bfloat16):
    bundle = build(cfg)
    return jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0), dtype=dtype))


def make_train_step(
    cfg,
    mesh,
    *,
    opt_cfg: OptConfig = OptConfig(),
    remat: bool = True,
    loss_aux_coeff: float = 0.01,
    param_dtype=jnp.bfloat16,
    micro_batches: int = 1,
):
    """``micro_batches > 1`` splits the global batch along the batch axis and
    accumulates gradients sequentially (f32) before one optimizer update —
    peak activation memory drops ~linearly at no arithmetic cost (§Perf)."""
    bundle = build(cfg)
    rules = TRAIN_RULES
    ctx = ShardCtx(mesh, rules)
    init_opt = opt_init(opt_cfg.name)
    update = opt_update(opt_cfg.name)

    def loss_fn(params, batch):
        loss, aux = bundle.forward_train(params, batch, ctx=ctx, remat=remat)
        if "load_balance" in aux:
            loss = loss + loss_aux_coeff * aux["load_balance"]
        return loss, aux

    def step(params, opt_state, batch):
        if micro_batches > 1:
            def split(x):
                B = x.shape[0]
                assert B % micro_batches == 0, "batch must divide microbatches"
                return x.reshape(micro_batches, B // micro_batches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            from repro.common import loops

            (grads, loss), _ = loops.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
            loss = loss / micro_batches
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        params, opt_state, om = update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    # shardings
    p_shapes = param_shapes(cfg, param_dtype)
    p_axes = bundle.param_axes()
    p_sh = _shardings_for(p_axes, p_shapes, mesh, rules)
    o_axes = opt_state_axes(opt_cfg.name, p_axes)
    o_shapes = jax.eval_shape(init_opt, p_shapes)
    o_sh = _shardings_for(o_axes, o_shapes, mesh, rules)
    b_axes = batch_axes(cfg, with_targets=True)
    bs = batch_specs(cfg, 1, 1, with_targets=True)  # structure only
    b_sh = {
        k: NamedSharding(
            mesh, logical_to_spec(b_axes[k], bs[k].shape, mesh, rules)
        )
        for k in bs
    }
    # NOTE: batch shardings resolved with dummy shapes can mis-handle the
    # divisibility guard; resolve against real shapes at lowering instead.
    step_jit = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return step_jit, {
        "params": p_sh,
        "opt": o_sh,
        "param_shapes": p_shapes,
        "opt_shapes": o_shapes,
        "init_opt": init_opt,
    }


def batch_shardings(cfg, mesh, shape, with_targets=True, rules=TRAIN_RULES):
    axes = batch_axes(cfg, with_targets=with_targets)
    specs = batch_specs(cfg, shape.global_batch, shape.seq_len, with_targets)
    return {
        k: NamedSharding(mesh, logical_to_spec(axes[k], specs[k].shape, mesh, rules))
        for k in specs
    }


def make_serve_steps(cfg, mesh, *, cache_dtype=jnp.bfloat16):
    """Returns (prefill_fn, decode_fn, shardings dict)."""
    bundle = build(cfg)
    rules = SERVE_RULES
    ctx = ShardCtx(mesh, rules)

    def prefill_fn(params, batch, cache):
        return bundle.prefill(params, batch, cache, ctx=ctx)

    def decode_fn(params, tokens, cache, pos):
        return bundle.decode(params, tokens, cache, pos, ctx=ctx)

    p_shapes = param_shapes(cfg)
    p_sh = _shardings_for(bundle.param_axes(), p_shapes, mesh, rules)
    return prefill_fn, decode_fn, {"params": p_sh, "param_shapes": p_shapes}


def cache_shardings(cfg, mesh, B, max_len, rules=SERVE_RULES):
    bundle = build(cfg)
    shapes = cache_specs(cfg, B, max_len)
    axes = bundle.cache_axes()
    return make_param_shardings(axes, shapes, mesh, rules)
