"""Core layer library: norms, RoPE, GQA attention (chunked/flash in jnp),
gated MLP, embeddings.

Conventions
-----------
* Pure functional: ``init_*`` builds a param pytree, ``*_apply`` consumes it.
* Every param pytree has a parallel *logical axes* pytree (same structure,
  leaves are tuples of logical axis names) used by the sharding resolver.
* Layer stacks are scanned: per-layer params carry a leading ``layers`` dim.
* Attention over long sequences is computed with an online-softmax chunked
  scan over KV blocks (bounded memory — the pure-jnp analogue of flash
  attention, and the oracle for the Pallas kernels).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.common import loops

from repro.common.dtypes import DTypePolicy, DEFAULT_POLICY

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(rng, shape, in_axis_size, dtype):
    scale = in_axis_size**-0.5
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def dense_param(rng, in_dim, out_shape, dtype):
    """Weight of shape (in_dim, *out_shape) with fan-in init."""
    return _dense_init(rng, (in_dim, *out_shape), in_dim, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(x, p, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (S,) or (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    # broadcast over head axis: (..., S, 1, half)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (chunked online-softmax; GQA; softcap; sliding window)
# ---------------------------------------------------------------------------


def _softcap(s, cap):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


NEG_INF = -1e30


def chunked_attention(
    q,                      # (B, Tq, H, D)
    k,                      # (B, Tk, Hkv, D)
    v,                      # (B, Tk, Hkv, D)
    *,
    q_start=0,              # absolute position of q[0] (int or scalar array)
    causal: bool = True,
    window: int = 0,        # sliding window size (0 = unlimited)
    local=True,             # bool (may be traced): apply the window mask?
    softcap: float = 0.0,
    kv_chunk: int = 1024,
    kv_len=None,            # (B,) valid KV length per row (ragged batches)
    scale=None,
):
    """Online-softmax attention, scanning KV in chunks.

    Covers training (Tq == Tk, q_start=0), prefill, verification
    (small Tq, long Tk) and decode (Tq == 1).  Memory is
    O(B * H * Tq * kv_chunk) regardless of Tk.
    """
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    scale = scale if scale is not None else D**-0.5

    nchunks = max(1, (Tk + kv_chunk - 1) // kv_chunk)
    pad = nchunks * kv_chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, kv_chunk, Hkv, D)
    vc = v.reshape(B, nchunks, kv_chunk, Hkv, D)

    qg = q.reshape(B, Tq, Hkv, G, D)
    # q_start may be a scalar or per-row (B,) vector (ragged serving batches)
    q_start = jnp.asarray(q_start)
    if q_start.ndim == 0:
        q_pos = jnp.broadcast_to(q_start + jnp.arange(Tq), (B, Tq))
    else:
        q_pos = q_start[:, None] + jnp.arange(Tq)[None, :]   # (B, Tq)
    valid_len = kv_len if kv_len is not None else jnp.full((B,), Tk, jnp.int32)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        kv_pos = j * kv_chunk + jnp.arange(kv_chunk)  # (chunk,)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kj, preferred_element_type=jnp.float32
        ) * scale
        s = _softcap(s, softcap)
        mask = kv_pos[None, None, :] < valid_len[:, None, None]  # (B,1,chunk)
        if causal:
            cm = q_pos[:, :, None] >= kv_pos[None, None, :]      # (B,Tq,chunk)
            mask = jnp.logical_and(mask, cm)
        if window and window > 0:
            wm = (q_pos[:, :, None] - kv_pos[None, None, :]) < window
            # `local` may be a traced per-layer flag (scanned layer stacks):
            # when False the window mask is disabled.
            wm = jnp.logical_or(wm, jnp.logical_not(local))
            mask = jnp.logical_and(mask, wm)
        mask = mask[:, None, None, :, :]                         # (B,1,1,Tq,ck)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vj, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Tq, D), jnp.float32)
    if nchunks == 1:
        (m, l, acc), _ = body((m0, l0, a0), (kc[:, 0], vc[:, 0], jnp.int32(0)))
    else:
        (m, l, acc), _ = loops.scan(
            body,
            (m0, l0, a0),
            (
                jnp.moveaxis(kc, 1, 0),
                jnp.moveaxis(vc, 1, 0),
                jnp.arange(nchunks, dtype=jnp.int32),
            ),
        )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Tq, H, D)  # (B,Tq,Hkv,G,D)->(B,Tq,H,D)
    return out.astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    softcap: float = 0.0
    window: int = 0          # applied when layer is "local"
    use_rope: bool = True


def init_attention(rng, spec: AttnSpec, dtype):
    ks = jax.random.split(rng, 4)
    D, H, Hkv, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim
    p = {
        "wq": dense_param(ks[0], D, (H, hd), dtype),
        "wk": dense_param(ks[1], D, (Hkv, hd), dtype),
        "wv": dense_param(ks[2], D, (Hkv, hd), dtype),
        "wo": _dense_init(ks[3], (H, hd, D), H * hd, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    return p


def attention_axes(spec: AttnSpec):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if spec.qkv_bias:
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    return a


def qkv_proj(p, x, spec: AttnSpec, positions):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if spec.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if spec.use_rope:
        q = rope(q, positions, spec.rope_theta)
        k = rope(k, positions, spec.rope_theta)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def self_attention(
    p,
    x,
    spec: AttnSpec,
    *,
    q_start=0,
    positions=None,
    causal=True,
    local=False,
    kv_chunk=1024,
    ctx=None,
):
    """Full self-attention over x (train/prefill, no external cache)."""
    B, S, _ = x.shape
    positions = positions if positions is not None else q_start + jnp.arange(S)
    q, k, v = qkv_proj(p, x, spec, positions)
    if ctx is not None:
        q = ctx.cs(q, ("act_batch", "act_seq", "act_heads", None))
        k = ctx.cs(k, ("act_batch", "act_seq", "act_kv", None))
        v = ctx.cs(v, ("act_batch", "act_seq", "act_kv", None))
    o = chunked_attention(
        q, k, v,
        q_start=q_start,
        causal=causal,
        window=spec.window,
        local=local,
        softcap=spec.softcap,
        kv_chunk=kv_chunk,
    )
    return attn_out(p, o), (k, v)


def cached_attention(
    p,
    x,                      # (B, T, D) new tokens (decode T=1, verify T=K+1)
    spec: AttnSpec,
    k_cache,                # (B, S_max, Hkv, hd)
    v_cache,
    pos,                    # scalar: current committed length
    *,
    local=False,
    kv_chunk=1024,
    ctx=None,
):
    """Attention of new tokens against cache + themselves; returns updated
    caches (new K/V written at [pos : pos+T]).  ``pos`` may be a scalar or a
    per-row (B,) vector (ragged serving batches)."""
    B, T, _ = x.shape
    # Serving path (decode/verify, T small): do NOT chunk the KV loop.  A
    # scan over KV chunks defeats GSPMD's sequence sharding of the cache —
    # each device would redundantly compute every chunk (measured 16x
    # per-device FLOPs/bytes inflation at decode_32k; EXPERIMENTS.md §Perf
    # cell A).  One full-length masked einsum keeps the seq axis sharded
    # and lowers to flash-decoding-style partial softmax + a small reduce.
    if T <= 32:
        kv_chunk = k_cache.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        positions = pos + jnp.arange(T)
    else:
        positions = pos[:, None] + jnp.arange(T)[None, :]    # (B, T)
    q, k, v = qkv_proj(p, x, spec, positions)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0)
        )
    else:
        upd = jax.vmap(
            lambda c, n, p0: jax.lax.dynamic_update_slice(c, n, (p0, 0, 0))
        )
        k_cache = upd(k_cache, k.astype(k_cache.dtype), pos)
        v_cache = upd(v_cache, v.astype(v_cache.dtype), pos)
    kv_len = (pos + T).astype(jnp.int32)
    kv_len = jnp.broadcast_to(kv_len, (B,))
    o = chunked_attention(
        q,
        k_cache,
        v_cache,
        q_start=pos,
        causal=True,
        window=spec.window,
        local=local,
        softcap=spec.softcap,
        kv_chunk=kv_chunk,
        kv_len=kv_len,
    )
    return attn_out(p, o), (k_cache, v_cache)


def cross_attention(p, x, spec: AttnSpec, k_mem, v_mem, *, kv_chunk=1024):
    """Non-causal attention of x over a fixed memory (encoder / image)."""
    B, T, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if spec.qkv_bias:
        q = q + p["bq"]
    o = chunked_attention(
        q, k_mem, v_mem, causal=False, softcap=spec.softcap, kv_chunk=kv_chunk
    )
    return attn_out(p, o)


def cross_kv(p, mem, spec: AttnSpec):
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    if spec.qkv_bias:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, d, f, dtype, gated=True):
    ks = jax.random.split(rng, 3)
    if gated:
        return {
            "gate": dense_param(ks[0], d, (f,), dtype),
            "up": dense_param(ks[1], d, (f,), dtype),
            "down": _dense_init(ks[2], (f, d), f, dtype),
        }
    return {
        "up": dense_param(ks[1], d, (f,), dtype),
        "down": _dense_init(ks[2], (f, d), f, dtype),
    }


def mlp_axes(gated=True):
    a = {"up": ("embed", "mlp"), "down": ("mlp", "embed")}
    if gated:
        a["gate"] = ("embed", "mlp")
    return a


def mlp_apply(p, x, gated=True, ctx=None):
    u = jnp.einsum("bsd,df->bsf", x, p["up"])
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, p["gate"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    if ctx is not None:
        h = ctx.cs(h, ("act_batch", "act_seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["down"])


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def init_embedding(rng, vocab, d, dtype):
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def logits_out(x, table_or_unembed, *, tied: bool, softcap: float = 0.0):
    if tied:
        lg = jnp.einsum(
            "bsd,vd->bsv", x, table_or_unembed, preferred_element_type=jnp.float32
        )
    else:
        lg = jnp.einsum(
            "bsd,dv->bsv", x, table_or_unembed, preferred_element_type=jnp.float32
        )
    return _softcap(lg, softcap)


__all__ = [
    "AttnSpec",
    "DTypePolicy",
    "DEFAULT_POLICY",
    "attention_axes",
    "attn_out",
    "cached_attention",
    "chunked_attention",
    "cross_attention",
    "cross_kv",
    "dense_param",
    "embed",
    "init_attention",
    "init_embedding",
    "init_layernorm",
    "init_mlp",
    "init_rmsnorm",
    "layernorm",
    "logits_out",
    "mlp_apply",
    "mlp_axes",
    "qkv_proj",
    "rmsnorm",
    "rope",
    "self_attention",
]
