"""Decoder-only transformer LM covering the dense / moe / vlm families.

Layer stacks are ``lax.scan``-ed over stacked parameters so HLO size is
O(1) in depth (required for 100-layer dry-runs).  The VLM variant scans over
*groups* of (cross_attn_every self layers + 1 gated cross-attention layer).

Three entry points (shared across families, see `repro.models.zoo`):
  * forward_train(params, batch)              -> logits (B, S, V)
  * prefill(params, batch, cache)             -> (logits, cache)
  * decode(params, tokens, cache, pos)        -> (logits (B,T,V), cache)
    (T = 1 for decode, K+1 for speculative verification)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import loops

from repro.common.sharding import NULL_CTX
from repro.configs.base import ArchConfig
from repro.kernels.paged_attention.ops import (
    paged_verify_attention_op,
    scatter_kv_pages,
)
from repro.models import layers as L
from repro.models.moe import init_moe, moe_apply, moe_axes


def attn_spec(cfg: ArchConfig) -> L.AttnSpec:
    return L.AttnSpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        qkv_bias=cfg.qkv_bias,
        softcap=cfg.attn_softcap,
        window=cfg.sliding_window,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, rng, dtype):
    spec = attn_spec(cfg)
    ka, km = jax.random.split(rng)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(ka, spec, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(km, cfg.d_model, cfg.d_ff, cfg.moe, dtype)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp)
    if cfg.sandwich_norm:
        p["ln1_post"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["ln2_post"] = L.init_rmsnorm(cfg.d_model, dtype)
    return p


def _block_axes(cfg: ArchConfig):
    spec = attn_spec(cfg)
    a = {
        "ln1": ("embed",),
        "attn": L.attention_axes(spec),
        "ln2": ("embed",),
    }
    if cfg.moe is not None:
        a["moe"] = moe_axes(cfg.moe)
    else:
        a["mlp"] = L.mlp_axes(cfg.gated_mlp)
    if cfg.sandwich_norm:
        a["ln1_post"] = ("embed",)
        a["ln2_post"] = ("embed",)
    return a


def _init_cross_block(cfg: ArchConfig, rng, dtype):
    spec = attn_spec(cfg)
    ka, km = jax.random.split(rng)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(ka, spec, dtype),
        "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _cross_block_axes(cfg: ArchConfig):
    spec = attn_spec(cfg)
    return {
        "ln1": ("embed",),
        "attn": L.attention_axes(spec),
        "ln2": ("embed",),
        "mlp": L.mlp_axes(cfg.gated_mlp),
        "gate_attn": (),
        "gate_mlp": (),
    }


def _stack_init(init_fn, rng, n):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def _stack_axes(axes, extra=("layers",)):
    return jax.tree.map(
        lambda a: (*extra, *a), axes, is_leaf=lambda x: isinstance(x, tuple)
    )


def init_params(cfg: ArchConfig, rng, dtype=jnp.bfloat16):
    ke, kl, kc, ku = jax.random.split(rng, 4)
    p = {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.cross_attn_every:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every

        def group_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "self": _stack_init(lambda kk: _init_block(cfg, kk, dtype), k1, per),
                "cross": _init_cross_block(cfg, k2, dtype),
            }

        p["groups"] = _stack_init(group_init, kl, n_groups)
    else:
        p["blocks"] = _stack_init(
            lambda kk: _init_block(cfg, kk, dtype), kl, cfg.n_layers
        )
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_param(ku, cfg.d_model, (cfg.vocab,), dtype)
    return p


def param_axes(cfg: ArchConfig):
    a = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
    }
    if cfg.cross_attn_every:
        a["groups"] = {
            "self": _stack_axes(_block_axes(cfg), ("layers", "layers_inner")),
            "cross": _stack_axes(_cross_block_axes(cfg)),
        }
    else:
        a["blocks"] = _stack_axes(_block_axes(cfg))
    if not cfg.tie_embeddings:
        a["unembed"] = ("embed", "vocab")
    return a


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block_full(cfg, spec, bp, x, *, local, ctx, kv_chunk=1024, dropless=False):
    """Self-attn + FFN over the full sequence (train/prefill). Returns
    (x, (k, v), aux)."""
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    att, (k, v) = L.self_attention(
        bp["attn"], h, spec, local=local, kv_chunk=kv_chunk, ctx=ctx
    )
    if cfg.sandwich_norm:
        att = L.rmsnorm(att, bp["ln1_post"], cfg.norm_eps)
    x = x + att
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    aux = {}
    if cfg.moe is not None:
        f, aux = moe_apply(bp["moe"], h, cfg.moe, ctx=ctx, dropless=dropless)
    else:
        f = L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
    if cfg.sandwich_norm:
        f = L.rmsnorm(f, bp["ln2_post"], cfg.norm_eps)
    x = x + f
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    return x, (k, v), aux


def _apply_block_cached(cfg, spec, bp, x, kc, vc, pos, *, local, ctx):
    """Self-attn + FFN for new tokens against a KV cache (decode/verify)."""
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    att, (kc, vc) = L.cached_attention(
        bp["attn"], h, spec, kc, vc, pos, local=local, ctx=ctx
    )
    if cfg.sandwich_norm:
        att = L.rmsnorm(att, bp["ln1_post"], cfg.norm_eps)
    x = x + att
    h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        # dropless: verification must not depend on microbatch composition
        f, _ = moe_apply(bp["moe"], h, cfg.moe, ctx=ctx, dropless=True)
    else:
        f = L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
    if cfg.sandwich_norm:
        f = L.rmsnorm(f, bp["ln2_post"], cfg.norm_eps)
    return x + f, kc, vc


def _apply_cross_block(cfg, spec, cp, x, k_img, v_img, *, ctx):
    h = L.rmsnorm(x, cp["ln1"], cfg.norm_eps)
    att = L.cross_attention(cp["attn"], h, spec, k_img, v_img)
    # gates are f32 scalars; cast so the residual keeps the activation dtype
    x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * att.astype(x.dtype)
    h = L.rmsnorm(x, cp["ln2"], cfg.norm_eps)
    f = L.mlp_apply(cp["mlp"], h, cfg.gated_mlp, ctx=ctx)
    return x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * f.astype(x.dtype)


def _is_local_flags(cfg: ArchConfig, n):
    if cfg.local_global_alternate:
        return (jnp.arange(n) % 2 == 0)
    return jnp.zeros((n,), bool)


def _embed_in(cfg, params, tokens):
    x = L.embed(params["embed"], tokens)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def _logits(cfg, params, x):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return L.logits_out(x, params["embed"], tied=True, softcap=cfg.final_softcap)
    return L.logits_out(x, params["unembed"], tied=False, softcap=cfg.final_softcap)


def chunked_ce_loss(cfg, params, x, targets, *, ctx=NULL_CTX, chunk=512):
    """Fused final-norm + unembed + cross-entropy, scanned over sequence
    chunks so the (B, S, V) logits tensor is never materialized (vocab-heavy
    archs would need TBs otherwise).  Returns (loss_sum, n_tokens)."""
    B, S, D = x.shape
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    Sc = min(chunk, S)
    pad = (-S) % Sc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // Sc
    xs = jnp.moveaxis(x.reshape(B, nc, Sc, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, Sc), 1, 0)

    def body(acc, inp):
        xc, tc = inp
        lg = L.logits_out(
            xc, table, tied=cfg.tie_embeddings, softcap=cfg.final_softcap
        )                                                  # (B, Sc, V) f32
        lg = ctx.cs(lg, ("act_batch", None, "vocab"))
        lse = jax.nn.logsumexp(lg, axis=-1)
        tgt = jnp.take_along_axis(
            lg, jnp.maximum(tc, 0)[..., None], axis=-1
        )[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        acc = acc + jnp.sum((lse - tgt) * valid)
        return acc, None

    loss_sum, _ = loops.scan(body, jnp.zeros((), jnp.float32), (xs, ts))
    n_tok = jnp.maximum((targets >= 0).sum(), 1)
    return loss_sum, n_tok


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _run_layers_full(cfg, params, x, *, ctx, collect_kv, image_embeds=None,
                     dropless=False, remat=False):
    """Returns (x, kv_stack or None, cross_kv or None, aux)."""
    spec = attn_spec(cfg)
    ckpt = (
        (lambda f: jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable))
        if remat
        else (lambda f: f)
    )

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every

        @ckpt
        def group_body(carry, gp):
            x, aux_acc = carry
            flags = jnp.zeros((per,), bool)

            def self_body(xc, inp):
                bp, loc = inp
                xo, (k, v), aux = _apply_block_full(
                    cfg, spec, bp, xc, local=loc, ctx=ctx, dropless=dropless
                )
                return xo, (k, v)

            x, kvs = loops.scan(self_body, x, (gp["self"], flags))
            kimg, vimg = L.cross_kv(gp["cross"]["attn"], image_embeds, spec)
            x = _apply_cross_block(cfg, spec, gp["cross"], x, kimg, vimg, ctx=ctx)
            return (x, aux_acc), (kvs, (kimg, vimg))

        (x, _), (kv_stack, cross_kv) = loops.scan(
            group_body, (x, 0.0), params["groups"]
        )
        return x, kv_stack, cross_kv, {}

    flags = _is_local_flags(cfg, cfg.n_layers)

    @ckpt
    def body(carry, inp):
        x, lb = carry
        bp, loc = inp
        x, (k, v), aux = _apply_block_full(
            cfg, spec, bp, x, local=loc, ctx=ctx, dropless=dropless
        )
        lb = lb + aux.get("load_balance", 0.0)
        return (x, lb), ((k, v) if collect_kv else None)

    (x, lb), kv_stack = loops.scan(body, (x, 0.0), (params["blocks"], flags))
    return x, kv_stack, None, {"load_balance": lb / cfg.n_layers}


def forward_train(cfg: ArchConfig, params, batch, *, ctx=NULL_CTX, remat=False):
    """batch: {'tokens': (B,S) [, 'image_embeds', 'targets']}.

    Returns (logits, aux) — or (mean_ce_loss, aux) when 'targets' is present
    (fused chunked loss: full logits never materialized)."""
    tokens = batch["tokens"]
    x = _embed_in(cfg, params, tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    x, _, _, aux = _run_layers_full(
        cfg, params, x, ctx=ctx, collect_kv=False,
        image_embeds=batch.get("image_embeds"), remat=remat,
    )
    if "targets" in batch:
        loss_sum, n = chunked_ce_loss(cfg, params, x, batch["targets"], ctx=ctx)
        return loss_sum / n.astype(jnp.float32), aux
    return _logits(cfg, params, x), aux


# ---------------------------------------------------------------------------
# cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, B, max_len, dtype=jnp.bfloat16):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kv = lambda: jnp.zeros((cfg.n_layers, B, max_len, hkv, hd), dtype)
    c = {"k": kv(), "v": kv()}
    if cfg.cross_attn_every:
        n_groups = cfg.n_layers // cfg.cross_attn_every
        c["k"] = c["k"].reshape(
            n_groups, cfg.cross_attn_every, B, max_len, hkv, hd
        )
        c["v"] = c["v"].reshape(
            n_groups, cfg.cross_attn_every, B, max_len, hkv, hd
        )
        c["k_img"] = jnp.zeros((n_groups, B, cfg.num_image_tokens, hkv, hd), dtype)
        c["v_img"] = jnp.zeros((n_groups, B, cfg.num_image_tokens, hkv, hd), dtype)
    return c


def cache_axes(cfg: ArchConfig):
    if cfg.cross_attn_every:
        kv = ("layers", "layers_inner", "act_batch", "act_cache", "act_kv", None)
        return {
            "k": kv,
            "v": kv,
            "k_img": ("layers", "act_batch", None, "act_kv", None),
            "v_img": ("layers", "act_batch", None, "act_kv", None),
        }
    kv = ("layers", "act_batch", "act_cache", "act_kv", None)
    return {"k": kv, "v": kv}


def prefill(cfg: ArchConfig, params, batch, cache, *, ctx=NULL_CTX,
            last_only: bool = False):
    """Run the prompt through the model, filling cache[: S]. Returns
    (logits, cache); ``last_only`` keeps only the final position's logits
    (serving prefill — avoids materializing the (B, S, V) tensor)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_in(cfg, params, tokens)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    # MoE prefill uses GShard capacity semantics, NOT dropless: dropless
    # capacity C=S inflates dispatch buffers by E/topk (8x for grok —
    # §Perf cell B) and is unnecessary for WISP's composition-independence:
    # routing groups are batch rows, so capacity ranking depends only on
    # the request's own tokens either way.  The verify path (decode, T
    # small) stays exact-dropless where determinism is load-bearing.
    x, kv_stack, cross_kv, _ = _run_layers_full(
        cfg, params, x, ctx=ctx, collect_kv=True,
        image_embeds=batch.get("image_embeds"),
        dropless=False,
    )
    k_new, v_new = kv_stack
    upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), 0, axis=c.ndim - 3
    )
    cache = dict(cache)
    cache["k"] = upd(cache["k"], k_new)
    cache["v"] = upd(cache["v"], v_new)
    if cross_kv is not None:
        cache["k_img"] = cross_kv[0].astype(cache["k_img"].dtype)
        cache["v_img"] = cross_kv[1].astype(cache["v_img"].dtype)
    if last_only:
        x = x[:, -1:]
    return _logits(cfg, params, x), cache


def _apply_block_paged(
    cfg, spec, bp, x, kp_l, vp_l, block_table, base_lens, t_lens, *, ctx,
    dropless=True,
):
    """Self-attn + FFN for new tokens against one layer's KV *pages*
    (paged decode/verify; DESIGN.md §2).  New K/V are scattered through the
    block table before attention so causality within the new block falls
    out of the per-row length pointers."""
    B, T, _ = x.shape
    h = L.rmsnorm(x, bp["ln1"], cfg.norm_eps)
    positions = base_lens[:, None] + jnp.arange(T)[None, :]
    q, k, v = L.qkv_proj(bp["attn"], h, spec, positions)
    kp_l, vp_l = scatter_kv_pages(
        kp_l, vp_l, k, v, block_table, base_lens, t_lens
    )
    o = paged_verify_attention_op(
        q, kp_l, vp_l, block_table, base_lens, softcap=spec.softcap
    )
    att = L.attn_out(bp["attn"], o)
    if cfg.sandwich_norm:
        att = L.rmsnorm(att, bp["ln1_post"], cfg.norm_eps)
    x = x + att
    h = L.rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        # verify: dropless, so results cannot depend on microbatch
        # composition; prefill passes dropless=False (GShard capacity,
        # matching the dense prefill path — see `prefill`'s rationale)
        f, _ = moe_apply(bp["moe"], h, cfg.moe, ctx=ctx, dropless=dropless)
    else:
        f = L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
    if cfg.sandwich_norm:
        f = L.rmsnorm(f, bp["ln2_post"], cfg.norm_eps)
    return x + f, kp_l, vp_l


def decode_paged(
    cfg: ArchConfig,
    params,
    tokens,            # (B, T) new tokens at positions base_lens[b] + t
    k_pages,           # (n_layers, n_pages, P, Hkv, hd)
    v_pages,
    block_table,       # (B, n_max) int32 page ids per row
    base_lens,         # (B,) int32 committed kv tokens per row
    t_lens,            # (B,) int32 valid new tokens per row (<= T)
    cross_cache=None,  # vlm: {'k_img','v_img'} (n_groups, B, Ni, Hkv, hd)
    *,
    dropless: bool = True,
    ctx=NULL_CTX,
):
    """Paged-cache analogue of ``decode``: serves ragged prefill (T = prompt
    suffix) and speculative verification (T = K+1) against `PagedKV` storage.
    Returns (logits, (k_pages, v_pages)) with the new tokens' K/V scattered
    into the pages.  Requires full (non-windowed) attention — the paged
    kernel has no sliding-window mask (engine falls back to dense
    otherwise).  ``dropless`` controls MoE routing: True for verification
    (composition independence), False for prompt prefill (GShard capacity,
    matching the dense prefill path)."""
    spec = attn_spec(cfg)
    if spec.window:
        raise ValueError("decode_paged does not support sliding-window attn")
    base_lens = jnp.asarray(base_lens, jnp.int32)
    t_lens = jnp.asarray(t_lens, jnp.int32)
    block_table = jnp.asarray(block_table, jnp.int32)
    x = _embed_in(cfg, params, tokens)
    x = ctx.cs(x, ("act_batch", None, "act_embed"))

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        n_groups = cfg.n_layers // per
        kp = k_pages.reshape(n_groups, per, *k_pages.shape[1:])
        vp = v_pages.reshape(n_groups, per, *v_pages.shape[1:])

        def group_body(x, inp):
            gp, kpg, vpg, kimg, vimg = inp

            def self_body(xc, inner):
                bp, kpl, vpl = inner
                xo, kpl, vpl = _apply_block_paged(
                    cfg, spec, bp, xc, kpl, vpl, block_table, base_lens,
                    t_lens, ctx=ctx, dropless=dropless,
                )
                return xo, (kpl, vpl)

            x, (kpg, vpg) = loops.scan(self_body, x, (gp["self"], kpg, vpg))
            x = _apply_cross_block(cfg, spec, gp["cross"], x, kimg, vimg, ctx=ctx)
            return x, (kpg, vpg)

        x, (kp, vp) = loops.scan(
            group_body, x,
            (params["groups"], kp, vp,
             cross_cache["k_img"], cross_cache["v_img"]),
        )
        k_pages = kp.reshape(cfg.n_layers, *kp.shape[2:])
        v_pages = vp.reshape(cfg.n_layers, *vp.shape[2:])
        return _logits(cfg, params, x), (k_pages, v_pages)

    def body(x, inp):
        bp, kpl, vpl = inp
        x, kpl, vpl = _apply_block_paged(
            cfg, spec, bp, x, kpl, vpl, block_table, base_lens, t_lens,
            ctx=ctx, dropless=dropless,
        )
        return x, (kpl, vpl)

    x, (k_pages, v_pages) = loops.scan(body, x, (params["blocks"], k_pages, v_pages))
    return _logits(cfg, params, x), (k_pages, v_pages)


def vlm_cross_kv(cfg: ArchConfig, params, image_embeds):
    """Per-group gated-cross-attention K/V over the image embeddings —
    computed once at session open for the paged engine (the dense path
    computes these inside ``prefill``).  Returns (k, v) of shape
    (n_groups, B, Ni, Hkv, hd)."""
    spec = attn_spec(cfg)

    def body(c, gp):
        return c, L.cross_kv(gp["cross"]["attn"], image_embeds, spec)

    _, (k, v) = loops.scan(body, 0, params["groups"])
    return k, v


def decode(cfg: ArchConfig, params, tokens, cache, pos, *, ctx=NULL_CTX):
    """tokens: (B, T) new tokens at absolute positions pos..pos+T-1."""
    spec = attn_spec(cfg)
    x = _embed_in(cfg, params, tokens)
    x = ctx.cs(x, ("act_batch", None, "act_embed"))

    if cfg.cross_attn_every:
        def group_body(x, inp):
            gp, kc, vc, kimg, vimg = inp

            def self_body(xc, inner):
                bp, kci, vci = inner
                loc = jnp.asarray(False)
                xo, kci, vci = _apply_block_cached(
                    cfg, spec, bp, xc, kci, vci, pos, local=loc, ctx=ctx
                )
                return xo, (kci, vci)

            x, (kc, vc) = loops.scan(self_body, x, (gp["self"], kc, vc))
            x = _apply_cross_block(cfg, spec, gp["cross"], x, kimg, vimg, ctx=ctx)
            return x, (kc, vc)

        x, (k_new, v_new) = loops.scan(
            group_body,
            x,
            (params["groups"], cache["k"], cache["v"], cache["k_img"], cache["v_img"]),
        )
        cache = dict(cache, k=k_new, v=v_new)
        return _logits(cfg, params, x), cache

    flags = _is_local_flags(cfg, cfg.n_layers)

    def body(x, inp):
        bp, kc, vc, loc = inp
        x, kc, vc = _apply_block_cached(
            cfg, spec, bp, x, kc, vc, pos, local=loc, ctx=ctx
        )
        return x, (kc, vc)

    x, (k_new, v_new) = loops.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], flags)
    )
    cache = dict(cache, k=k_new, v=v_new)
    return _logits(cfg, params, x), cache
