"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, F, D).  Sinusoidal positions are
added on both sides (parameter-free, so decode positions are unbounded).
Decoder blocks: causal self-attention (cached) + cross-attention over the
encoder memory (KV computed once at prefill) + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import loops

from repro.common.sharding import NULL_CTX
from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.transformer import _stack_init, _stack_axes, attn_spec


def _sinusoid(positions, d):
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (9.210340371976184 / (half - 1)))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _spec(cfg):
    s = attn_spec(cfg)
    return L.AttnSpec(
        d_model=s.d_model,
        n_heads=s.n_heads,
        n_kv_heads=s.n_kv_heads,
        head_dim=s.head_dim,
        qkv_bias=s.qkv_bias,
        softcap=s.softcap,
        window=0,
        use_rope=False,          # whisper: absolute sinusoidal positions
    )


def _init_enc_block(cfg, rng, dtype):
    ka, km = jax.random.split(rng)
    return {
        "ln1": L.init_layernorm(cfg.d_model, dtype),
        "attn": L.init_attention(ka, _spec(cfg), dtype),
        "ln2": L.init_layernorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp),
    }


def _init_dec_block(cfg, rng, dtype):
    ka, kx, km = jax.random.split(rng, 3)
    return {
        "ln1": L.init_layernorm(cfg.d_model, dtype),
        "attn": L.init_attention(ka, _spec(cfg), dtype),
        "lnx": L.init_layernorm(cfg.d_model, dtype),
        "xattn": L.init_attention(kx, _spec(cfg), dtype),
        "ln2": L.init_layernorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, dtype, cfg.gated_mlp),
    }


def encdec_init(cfg: ArchConfig, rng, dtype=jnp.bfloat16):
    ke, k1, k2, kp = jax.random.split(rng, 4)
    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "frame_proj": L.dense_param(kp, cfg.d_model, (cfg.d_model,), dtype),
        "enc_blocks": _stack_init(
            lambda kk: _init_enc_block(cfg, kk, dtype), k1, cfg.encoder_layers
        ),
        "enc_norm": L.init_layernorm(cfg.d_model, dtype),
        "dec_blocks": _stack_init(
            lambda kk: _init_dec_block(cfg, kk, dtype), k2, cfg.n_layers
        ),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }


def encdec_axes(cfg: ArchConfig):
    ln = {"scale": ("embed",), "bias": ("embed",)}
    spec = _spec(cfg)
    enc = {
        "ln1": ln,
        "attn": L.attention_axes(spec),
        "ln2": ln,
        "mlp": L.mlp_axes(cfg.gated_mlp),
    }
    dec = {
        "ln1": ln,
        "attn": L.attention_axes(spec),
        "lnx": ln,
        "xattn": L.attention_axes(spec),
        "ln2": ln,
        "mlp": L.mlp_axes(cfg.gated_mlp),
    }
    return {
        "embed": ("vocab", "embed"),
        "frame_proj": ("embed", "embed2"),
        "enc_blocks": _stack_axes(enc),
        "enc_norm": ln,
        "dec_blocks": _stack_axes(dec),
        "final_norm": ("embed",),
    }


def encode(cfg, params, frames, *, ctx=NULL_CTX):
    """frames: (B, F, D) precomputed embeddings (stub frontend)."""
    spec = _spec(cfg)
    B, F, D = frames.shape
    x = jnp.einsum("bfd,de->bfe", frames, params["frame_proj"])
    x = x + _sinusoid(jnp.arange(F), D)[None].astype(x.dtype)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))

    def body(x, bp):
        h = L.layernorm(x, bp["ln1"], cfg.norm_eps)
        att, _ = L.self_attention(bp["attn"], h, spec, causal=False, ctx=ctx)
        x = x + att
        h = L.layernorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
        return x, None

    x, _ = loops.scan(body, x, params["enc_blocks"])
    return L.layernorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, spec, bp, x, kc, vc, pos, kx, vx, *, ctx):
    h = L.layernorm(x, bp["ln1"], cfg.norm_eps)
    att, (kc, vc) = L.cached_attention(bp["attn"], h, spec, kc, vc, pos, ctx=ctx)
    x = x + att
    h = L.layernorm(x, bp["lnx"], cfg.norm_eps)
    x = x + L.cross_attention(bp["xattn"], h, spec, kx, vx)
    h = L.layernorm(x, bp["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
    return x, kc, vc


def encdec_init_cache(cfg: ArchConfig, B, max_len, dtype=jnp.bfloat16):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    Ld = cfg.n_layers
    return {
        "k": jnp.zeros((Ld, B, max_len, hkv, hd), dtype),
        "v": jnp.zeros((Ld, B, max_len, hkv, hd), dtype),
        "k_mem": jnp.zeros((Ld, B, cfg.encoder_frames, hkv, hd), dtype),
        "v_mem": jnp.zeros((Ld, B, cfg.encoder_frames, hkv, hd), dtype),
    }


def encdec_cache_axes(cfg: ArchConfig):
    kv = ("layers", "act_batch", "act_cache", "act_kv", None)
    mem = ("layers", "act_batch", None, "act_kv", None)   # frames are short
    return {"k": kv, "v": kv, "k_mem": mem, "v_mem": mem}


def _embed_tokens(cfg, params, tokens, pos0):
    x = L.embed(params["embed"], tokens)
    pos0 = jnp.asarray(pos0)
    if pos0.ndim == 0:
        pos = pos0 + jnp.arange(tokens.shape[1])
        pe = _sinusoid(pos, cfg.d_model)[None]
    else:  # per-row positions (ragged serving batches)
        pos = pos0[:, None] + jnp.arange(tokens.shape[1])[None, :]
        pe = _sinusoid(pos, cfg.d_model)
    return x + pe.astype(x.dtype)


def encdec_forward_train(cfg, params, batch, *, ctx=NULL_CTX, remat=False):
    """batch: {'tokens': (B,S), 'frames': (B,F,D)}."""
    spec = _spec(cfg)
    mem = encode(cfg, params, batch["frames"], ctx=ctx)
    x = _embed_tokens(cfg, params, batch["tokens"], 0)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))
    ckpt = (
        (lambda f: jax.checkpoint(f, policy=jax.checkpoint_policies.nothing_saveable))
        if remat
        else (lambda f: f)
    )

    @ckpt
    def body(x, bp):
        h = L.layernorm(x, bp["ln1"], cfg.norm_eps)
        att, _ = L.self_attention(bp["attn"], h, spec, causal=True, ctx=ctx)
        x = x + att
        h = L.layernorm(x, bp["lnx"], cfg.norm_eps)
        kx, vx = L.cross_kv(bp["xattn"], mem, spec)
        x = x + L.cross_attention(bp["xattn"], h, spec, kx, vx)
        h = L.layernorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
        return x, None

    x, _ = loops.scan(body, x, params["dec_blocks"])
    if "targets" in batch:
        from repro.models.transformer import chunked_ce_loss

        loss_sum, n = chunked_ce_loss(cfg, params, x, batch["targets"], ctx=ctx)
        return loss_sum / n.astype(jnp.float32), {}
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.logits_out(x, params["embed"], tied=True), {}


def encdec_prefill(cfg, params, batch, cache, *, ctx=NULL_CTX,
                   last_only: bool = False):
    spec = _spec(cfg)
    mem = encode(cfg, params, batch["frames"], ctx=ctx)
    x = _embed_tokens(cfg, params, batch["tokens"], 0)
    x = ctx.cs(x, ("act_batch", "act_seq", "act_embed"))

    def body(x, inp):
        bp, kc, vc = inp
        kx, vx = L.cross_kv(bp["xattn"], mem, spec)
        x, kc, vc = _dec_block(cfg, spec, bp, x, kc, vc, 0, kx, vx, ctx=ctx)
        return x, (kc, vc, kx, vx)

    x, (k, v, kx, vx) = loops.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"])
    )
    cache = {
        "k": k,
        "v": v,
        "k_mem": kx.astype(cache["k_mem"].dtype),
        "v_mem": vx.astype(cache["v_mem"].dtype),
    }
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.logits_out(x, params["embed"], tied=True), cache


def encdec_cross_kv(cfg, params, frames, *, ctx=NULL_CTX):
    """Run the encoder and project per-decoder-layer cross-attention K/V —
    computed once at session open for the paged engine.  Returns (k, v) of
    shape (n_layers, B, F, Hkv, hd)."""
    spec = _spec(cfg)
    mem = encode(cfg, params, frames, ctx=ctx)

    def body(c, bp):
        return c, L.cross_kv(bp["xattn"], mem, spec)

    _, (k, v) = loops.scan(body, 0, params["dec_blocks"])
    return k, v


def encdec_decode_paged(
    cfg,
    params,
    tokens,            # (B, T) new tokens at positions base_lens[b] + t
    k_pages,           # (n_layers, n_pages, P, Hkv, hd) decoder self-attn KV
    v_pages,
    block_table,       # (B, n_max) int32
    base_lens,         # (B,) int32
    t_lens,            # (B,) int32 valid new tokens per row
    mem_cache,         # {'k_mem','v_mem'}: (n_layers, B, F, Hkv, hd)
    *,
    ctx=NULL_CTX,
):
    """Paged analogue of ``encdec_decode``: decoder self-attention runs over
    `PagedKV` pages; cross-attention memory stays dense (bounded by
    encoder_frames, not part of the growing KV)."""
    from repro.kernels.paged_attention.ops import (
        paged_verify_attention_op,
        scatter_kv_pages,
    )

    spec = _spec(cfg)
    base_lens = jnp.asarray(base_lens, jnp.int32)
    t_lens = jnp.asarray(t_lens, jnp.int32)
    block_table = jnp.asarray(block_table, jnp.int32)
    x = _embed_tokens(cfg, params, tokens, base_lens)
    T = tokens.shape[1]

    def body(x, inp):
        bp, kpl, vpl, kx, vx = inp
        h = L.layernorm(x, bp["ln1"], cfg.norm_eps)
        positions = base_lens[:, None] + jnp.arange(T)[None, :]
        q, k, v = L.qkv_proj(bp["attn"], h, spec, positions)
        kpl, vpl = scatter_kv_pages(
            kpl, vpl, k, v, block_table, base_lens, t_lens
        )
        o = paged_verify_attention_op(
            q, kpl, vpl, block_table, base_lens, softcap=spec.softcap
        )
        x = x + L.attn_out(bp["attn"], o)
        h = L.layernorm(x, bp["lnx"], cfg.norm_eps)
        x = x + L.cross_attention(bp["xattn"], h, spec, kx, vx)
        h = L.layernorm(x, bp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h, cfg.gated_mlp, ctx=ctx)
        return x, (kpl, vpl)

    x, (k_pages, v_pages) = loops.scan(
        body, x,
        (params["dec_blocks"], k_pages, v_pages,
         mem_cache["k_mem"], mem_cache["v_mem"]),
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.logits_out(x, params["embed"], tied=True), (k_pages, v_pages)


def encdec_decode(cfg, params, tokens, cache, pos, *, ctx=NULL_CTX):
    spec = _spec(cfg)
    x = _embed_tokens(cfg, params, tokens, pos)

    def body(x, inp):
        bp, kc, vc, kx, vx = inp
        x, kc, vc = _dec_block(cfg, spec, bp, x, kc, vc, pos, kx, vx, ctx=ctx)
        return x, (kc, vc)

    x, (k, v) = loops.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["k_mem"], cache["v_mem"])
    )
    cache = dict(cache, k=k, v=v)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.logits_out(x, params["embed"], tied=True), cache
